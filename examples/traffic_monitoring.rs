//! END-TO-END DRIVER — real-time traffic-speed prediction, the paper's
//! motivating AIMPEAK scenario, exercising all three layers:
//!
//!   L1/L2  AOT artifacts (Pallas SE-Gram inside the JAX graphs, lowered
//!          to HLO text) executed via PJRT on the request path;
//!   L3     rust coordinator: clustering partition, support selection,
//!          pPIC fit over the simulated 20-node cluster, then a serving
//!          loop (router + dynamic batcher) under an open-loop request
//!          stream.
//!
//!     make artifacts && cargo run --release --example traffic_monitoring
//!
//! Reports: protocol fit metrics, serving latency/throughput, and RMSE /
//! MNLP against the exact FGP baseline. Recorded in EXPERIMENTS.md
//! §End-to-end.

use std::sync::Arc;

use pgpr::api::{Gp, Method, PredictSpec};
use pgpr::bench_support::table::{fmt3, Table};
use pgpr::data::aimpeak::{self, AimpeakConfig};
use pgpr::data::partition::cluster_partition;
use pgpr::gp::likelihood::{learn_hyperparameters, MleConfig};
use pgpr::gp::support::support_matrix;
use pgpr::kernel::SeArd;
use pgpr::metrics::{mnlp, rmse};
use pgpr::runtime::{ArtifactManifest, Backend, NativeBackend, PjrtBackend};
use pgpr::server::{DynamicBatcher, PredictRequest};
use pgpr::util::{Pcg64, Stopwatch};

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg64::seed(420);

    // ---- artifacts: the aimpeak profile pins B=200, S=128, U=150, d=5
    let manifest = ArtifactManifest::load(
        pgpr::runtime::artifacts::default_dir())?;
    let profile = manifest.profile("aimpeak")?.clone();
    let m = 4; // machines; |D| = m * B exactly (AOT block shape)
    let n = profile.block * m; // 800
    let n_test = profile.pred_block * m; // 600

    println!("== generating urban road network + traffic field ==");
    let (net, ds) = aimpeak::generate(&AimpeakConfig {
        grid_w: 10,
        grid_h: 8,
        seed: 420,
        ..Default::default()
    });
    println!("   {} segments x 54 slots = {} records",
             net.n_segments(), ds.len());
    assert!(ds.len() >= n + n_test, "need more records");
    let idx = rng.sample_indices(ds.len(), n + n_test);
    let (test_idx, train_idx) = idx.split_at(n_test);
    let train = ds.select(train_idx);
    let test = ds.select(test_idx);

    // ---- hyperparameters: MLE on a subset (Section 6's procedure)
    println!("== learning hyperparameters (MLE, Adam, 192-pt subset) ==");
    let init = SeArd {
        log_ls: vec![0.3, 0.3, 0.3, 0.3, -0.2],
        log_sf2: (420.0f64).ln(),
        log_sn2: (30.0f64).ln(),
    };
    let (mle, mle_secs) = Stopwatch::time(|| {
        learn_hyperparameters(&init, &train.x, &train.y, &MleConfig {
            iters: 25,
            subset: 192,
            seed: 7,
            ..Default::default()
        })
    });
    let hyp = mle.hyp;
    println!("   NLML {} -> {} in {:.1}s", fmt3(mle.nlml_trace[0]),
             fmt3(*mle.nlml_trace.last().unwrap()), mle_secs);

    // ---- support set + clustering partition
    let xs = support_matrix(&hyp, &train.x, profile.support);
    let part = cluster_partition(&train.x, &test.x, m, &mut rng);

    // ---- PJRT backend (the three-layer hot path)
    println!("== loading AOT artifacts (PJRT CPU) ==");
    let pjrt: Arc<PjrtBackend> =
        Arc::new(PjrtBackend::load(&manifest, "aimpeak")?);

    // ---- one facade recipe for everything downstream
    let base = Gp::builder()
        .hyp(hyp.clone())
        .data(train.x.clone(), train.y.clone())
        .machines(m)
        .support(xs.clone())
        .partition(part.d_blocks.clone())
        .backend(pjrt.clone());

    // ---- pPIC protocol over the simulated cluster, PJRT on the blocks
    println!("== running pPIC over the simulated {m}-node cluster ==");
    let ppic_gp = base.clone().method(Method::PPic).fit()?;
    let out = ppic_gp.predict_full(
        &PredictSpec::new(test.x.clone()).with_blocks(part.u_blocks.clone()))?;
    let metrics = out.metrics.expect("distributed run reports metrics");
    let ppic_rmse = rmse(&test.y, &out.prediction.mean);
    let ppic_mnlp = mnlp(&test.y, &out.prediction.mean, &out.prediction.var);

    // ---- exact FGP baseline (the accuracy anchor), native linalg
    let (fgp_pred, fgp_secs) = Stopwatch::time(|| {
        base.clone()
            .method(Method::Fgp)
            .backend(Arc::new(NativeBackend))
            .fit()
            .and_then(|gp| gp.predict(&test.x))
            .expect("FGP baseline")
    });

    let mut t = Table::new(
        &format!("traffic monitoring: |D|={n}, |U|={n_test}, M={m}, \
                  |S|={}", profile.support),
        &["method", "RMSE (km/h)", "MNLP", "time_s"],
    );
    t.row(vec!["pPIC (pjrt)".into(), fmt3(ppic_rmse), fmt3(ppic_mnlp),
               fmt3(metrics.makespan)]);
    t.row(vec!["FGP (exact)".into(), fmt3(rmse(&test.y, &fgp_pred.mean)),
               fmt3(mnlp(&test.y, &fgp_pred.mean, &fgp_pred.var)),
               fmt3(fgp_secs)]);
    println!("{}", t.render());

    // ---- real-time serving: open-loop stream through router + batcher
    println!("== serving 600 speed queries (router + dynamic batcher) ==");
    let model = base.serve()?;
    let n_req = n_test;
    let requests: Vec<PredictRequest> = (0..n_req)
        .map(|i| PredictRequest {
            id: i as u64,
            x: test.x.row(i).to_vec(),
            arrival_s: i as f64 * 5e-4, // 2000 req/s offered
        })
        .collect();
    for (name, backend) in [("pjrt", pjrt.as_ref() as &dyn Backend),
                            ("native", &NativeBackend as &dyn Backend)] {
        let mut batcher = DynamicBatcher::new(m, profile.d,
                                              profile.pred_block, 5e-3);
        let report = model.serve(backend, &requests, &mut batcher);
        let serve_rmse = rmse(
            &test.y[..n_req],
            &report.responses.iter().map(|r| r.mean).collect::<Vec<_>>(),
        );
        println!("  [{name:6}] {}  | stream RMSE {}", report.summary(),
                 fmt3(serve_rmse));
    }
    println!("\nall layers composed: Pallas kernel -> JAX graph -> HLO \
              artifact -> PJRT -> rust coordinator -> served predictions");
    Ok(())
}
