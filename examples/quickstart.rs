//! Quickstart: fit the paper's parallel GPs on a small 1-D problem and
//! compare them with the exact FGP baseline — all through the unified
//! `api` facade.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API surface in ~40 lines of user code:
//! data → builder (partition + support owned by it) → predict → metrics.

use pgpr::api::{Gp, Method, PredictSpec};
use pgpr::bench_support::table::{fmt3, Table};
use pgpr::data::partition::cluster_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::metrics::{mnlp, rmse};
use pgpr::util::Pcg64;

fn main() {
    let mut rng = Pcg64::seed(2013);

    // --- a small noisy 1-D regression problem -------------------------
    let n = 400; // training points
    let u = 80; // test points
    let truth = |x: f64| (2.0 * x).sin() + 0.5 * (0.7 * x).cos();
    let xd = Mat::from_vec(n, 1, (0..n).map(|_| rng.uniform_in(-4.0, 4.0)).collect());
    let y: Vec<f64> = (0..n)
        .map(|i| truth(xd[(i, 0)]) + 0.1 * rng.normal())
        .collect();
    let xu = Mat::from_vec(u, 1, (0..u).map(|_| rng.uniform_in(-4.0, 4.0)).collect());
    let yu: Vec<f64> = (0..u).map(|i| truth(xu[(i, 0)])).collect();

    // --- model setup ---------------------------------------------------
    let hyp = SeArd::isotropic(1, 0.8, 1.0, 0.01);
    let m = 8; // simulated machines
    // the paper's clustering scheme co-locates correlated train/test rows
    let part = cluster_partition(&xd, &xu, m, &mut rng);

    // One builder, every method. `.threads(t)` would run the 8 machines'
    // work on t real host threads — identical predictions, lower wall.
    let base = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(24) // greedy entropy selection, owned by the builder
        .partition(part.d_blocks)
        .rank(24);
    let ps = PredictSpec::new(xu).with_blocks(part.u_blocks);

    // --- run every method through the same door ------------------------
    let mut table = Table::new(
        "quickstart: 1-D regression, |D|=400, M=8, |S|=24, R=24",
        &["method", "RMSE", "MNLP", "sim time"],
    );
    for method in [Method::Fgp, Method::PPitc, Method::PPic, Method::PIcf] {
        let gp = base.clone().method(method).fit().expect("fit");
        let out = gp.predict_full(&ps).expect("predict");
        let p = out.prediction;
        table.row(vec![
            if method == Method::Fgp { "FGP (exact)".into() }
            else { method.name().into() },
            fmt3(rmse(&yu, &p.mean)),
            fmt3(mnlp(&yu, &p.mean, &p.var)),
            out.metrics
                .map(|ms| fmt3(ms.makespan))
                .unwrap_or_else(|| "-".into()),
        ]);
    }

    println!("{}", table.render());
    println!("(pPIC should sit closest to FGP — it adds each machine's \
              local data to the shared summary; see the paper's Def. 5.)");
}
