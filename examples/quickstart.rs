//! Quickstart: fit the paper's parallel GPs on a small 1-D problem and
//! compare them with the exact FGP baseline.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API surface in ~40 lines of user code:
//! data → partition → support set → protocol run → metrics.

use pgpr::bench_support::table::{fmt3, Table};
use pgpr::data::partition::cluster_partition;
use pgpr::gp::support::support_matrix;
use pgpr::gp::FullGp;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::metrics::{mnlp, rmse};
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec};
use pgpr::runtime::NativeBackend;
use pgpr::util::Pcg64;

fn main() {
    let mut rng = Pcg64::seed(2013);

    // --- a small noisy 1-D regression problem -------------------------
    let n = 400; // training points
    let u = 80; // test points
    let truth = |x: f64| (2.0 * x).sin() + 0.5 * (0.7 * x).cos();
    let xd = Mat::from_vec(n, 1, (0..n).map(|_| rng.uniform_in(-4.0, 4.0)).collect());
    let y: Vec<f64> = (0..n)
        .map(|i| truth(xd[(i, 0)]) + 0.1 * rng.normal())
        .collect();
    let xu = Mat::from_vec(u, 1, (0..u).map(|_| rng.uniform_in(-4.0, 4.0)).collect());
    let yu: Vec<f64> = (0..u).map(|i| truth(xu[(i, 0)])).collect();

    // --- model setup ---------------------------------------------------
    let hyp = SeArd::isotropic(1, 0.8, 1.0, 0.01);
    let m = 8; // simulated machines
    let xs = support_matrix(&hyp, &xd, 24); // greedy entropy selection
    let part = cluster_partition(&xd, &xu, m, &mut rng);
    // ClusterSpec::with_threads(m, n) would run the 8 machines' work on
    // n real host threads — identical predictions, lower wall time.
    let spec = ClusterSpec::new(m);
    let backend = NativeBackend;

    // --- run every method ----------------------------------------------
    let mut table = Table::new(
        "quickstart: 1-D regression, |D|=400, M=8, |S|=24, R=24",
        &["method", "RMSE", "MNLP", "sim time"],
    );

    let fgp = FullGp::fit(&hyp, &xd, &y);
    let p = fgp.predict(&xu);
    table.row(vec!["FGP (exact)".into(), fmt3(rmse(&yu, &p.mean)),
                   fmt3(mnlp(&yu, &p.mean, &p.var)), "-".into()]);

    let out = ppitc::run(&hyp, &xd, &y, &xs, &xu, &part.d_blocks,
                         &part.u_blocks, &backend, &spec);
    table.row(vec!["pPITC".into(), fmt3(rmse(&yu, &out.prediction.mean)),
                   fmt3(mnlp(&yu, &out.prediction.mean, &out.prediction.var)),
                   fmt3(out.metrics.makespan)]);

    let out = ppic::run_with_partition(&hyp, &xd, &y, &xs, &xu,
                                       &part.d_blocks, &part.u_blocks,
                                       &backend, &spec);
    table.row(vec!["pPIC".into(), fmt3(rmse(&yu, &out.prediction.mean)),
                   fmt3(mnlp(&yu, &out.prediction.mean, &out.prediction.var)),
                   fmt3(out.metrics.makespan)]);

    let out = picf::run(&hyp, &xd, &y, &xu, &part.d_blocks, 24, &backend,
                        &spec);
    table.row(vec!["pICF".into(), fmt3(rmse(&yu, &out.prediction.mean)),
                   fmt3(mnlp(&yu, &out.prediction.mean, &out.prediction.var)),
                   fmt3(out.metrics.makespan)]);

    println!("{}", table.render());
    println!("(pPIC should sit closest to FGP — it adds each machine's \
              local data to the shared summary; see the paper's Def. 5.)");
}
