//! Online/incremental learning (§5.2): traffic data streams in every few
//! minutes; pPITC/pPIC assimilate only the *new* blocks' summaries
//! instead of recomputing history — absorb cost stays flat while a naive
//! refit grows.
//!
//!     cargo run --release --example online_streaming
//!
//! The absorb/predict streaming loop below is the §5.2 protocol verbatim:
//!
//! 1. **absorb** — each machine computes the local summary of *only* its
//!    newly arrived block (Definition 2); one reduce assimilates those
//!    into the running global summary (Definition 3). Nothing about the
//!    already-absorbed history is recomputed — that is why the
//!    `absorb_s` column stays flat while `refit_s` grows with |D|.
//! 2. **predict** — pPITC predictions come straight from the current
//!    global summary; pPIC predictions additionally use each machine's
//!    latest block as local data (`OnlineGp::predict_ppic`).
//!
//! See the `OnlineGp` rustdoc for a minimal copy-pastable version of the
//! same loop. To run each machine's absorb work on real host threads,
//! construct the model with `ClusterSpec::with_threads(m, n)` — results
//! are identical (Theorem 1), only wall time changes. pICF has no such
//! incremental form (paper §5.2, last sentence).

use pgpr::bench_support::table::{fmt3, Table};
use pgpr::data::aimpeak::{self, AimpeakConfig};
use pgpr::data::partition::random_partition;
use pgpr::gp::support::support_matrix;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::metrics::rmse;
use pgpr::parallel::online::OnlineGp;
use pgpr::parallel::{ppitc, ClusterSpec};
use pgpr::runtime::NativeBackend;
use pgpr::util::{Pcg64, Stopwatch};

fn main() {
    let mut rng = Pcg64::seed(99);
    let m = 4; // machines
    let per_block = 60; // new points per machine per batch
    let n_batches = 6;
    let n_test = 80;

    // stream source: AIMPEAK-like records arriving in time order
    let (_, ds) = aimpeak::generate(&AimpeakConfig {
        grid_w: 8, grid_h: 6, seed: 99, ..Default::default()
    });
    let need = n_batches * m * per_block + n_test;
    assert!(ds.len() >= need);
    let idx = rng.sample_indices(ds.len(), need);
    let (test_idx, stream_idx) = idx.split_at(n_test);
    let test = ds.select(test_idx);

    let hyp = SeArd {
        log_ls: vec![0.3, 0.3, 0.3, 0.3, -0.2],
        log_sf2: (420.0f64).ln(),
        log_sn2: (30.0f64).ln(),
    };
    let first = ds.select(&stream_idx[..m * per_block]);
    let xs = support_matrix(&hyp, &first.x, 48);

    let mut online = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend),
                                   ClusterSpec::new(m));
    let u_blocks = random_partition(n_test, m, &mut rng);

    let mut t = Table::new(
        "online streaming: absorb cost (incremental) vs naive refit",
        &["batch", "|D| so far", "absorb_s", "refit_s", "RMSE"],
    );
    let mut seen: Vec<usize> = Vec::new();
    for b in 0..n_batches {
        let lo = b * m * per_block;
        let batch_idx = &stream_idx[lo..lo + m * per_block];
        seen.extend_from_slice(batch_idx);

        // split the arriving batch among machines
        let blocks: Vec<(Mat, Vec<f64>)> = (0..m)
            .map(|k| {
                let rows: Vec<usize> =
                    batch_idx[k * per_block..(k + 1) * per_block].to_vec();
                let part = ds.select(&rows);
                (part.x, part.y)
            })
            .collect();
        let absorb_s = online.absorb(&blocks);

        // naive alternative: rerun the full batch protocol over history
        let hist = ds.select(&seen);
        let d_blocks = random_partition(hist.len(), m, &mut rng);
        let (_, refit_s) = Stopwatch::time(|| {
            ppitc::run(&hyp, &hist.x, &hist.y, &xs, &test.x, &d_blocks,
                       &u_blocks, &NativeBackend, &ClusterSpec::new(m))
        });

        let pred = online.predict_ppitc(&test.x, &u_blocks);
        t.row(vec![
            (b + 1).to_string(),
            hist.len().to_string(),
            fmt3(absorb_s),
            fmt3(refit_s),
            fmt3(rmse(&test.y, &pred.prediction.mean)),
        ]);
    }
    println!("{}", t.render());
    println!("absorb stays ~flat (one new block per machine) while the \
              naive refit grows with |D| — the §5.2 advantage. pICF has \
              no such incremental form (paper §5.2, last sentence).");
}
