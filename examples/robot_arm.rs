//! Robot-arm inverse dynamics — the paper's SARCOS scenario: learn the
//! 21-d (position, velocity, acceleration) → joint-1 torque map with all
//! seven methods and compare accuracy/time/speedup.
//!
//!     cargo run --release --example robot_arm
//!
//! The workload comes from an actual rigid-body simulator (recursive
//! Newton-Euler over a 7-DoF chain, `data::sarcos`), which produces the
//! short-length-scale, locally-structured regression problem where pPIC's
//! local blocks visibly beat pPITC's pure summaries.

use pgpr::bench_support::experiments::{
    run_methods, speedup_order, ExperimentConfig, Method,
};
use pgpr::bench_support::table::{fmt3, Table};
use pgpr::bench_support::workloads::{prepare, Domain};
use pgpr::runtime::NativeBackend;

fn main() {
    let (n, n_test, m, s) = (1200, 240, 12, 64);
    println!("== SARCOS-like workload: RNE inverse dynamics, \
              |D|={n}, |U|={n_test} ==");
    let w = prepare(Domain::Sarcos, n, n_test, 42, false);
    println!("   torque stats: mean {:.1}, sd {:.1} (paper: 13.7 / 20.5)",
             w.train.y_mean(), w.train.y_std());

    let cfg = ExperimentConfig {
        machines: m,
        support_size: s,
        rank: 2 * s, // paper: R = 2|S| in the SARCOS domain
        seed: 42,
        threads: 0,
    };
    let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                              std::sync::Arc::new(NativeBackend));

    let mut t = Table::new(
        &format!("robot arm: M={m}, |S|={s}, R={}", 2 * s),
        &["method", "RMSE", "MNLP", "time_s", "speedup", "bad_var%"],
    );
    for r in &results {
        t.row(vec![
            r.method.name().into(),
            fmt3(r.rmse),
            fmt3(r.mnlp),
            fmt3(r.time_s),
            r.speedup.map(fmt3).unwrap_or_else(|| "-".into()),
            fmt3(100.0 * r.bad_var),
        ]);
    }
    println!("{}", t.render());

    let get = |m: Method| results.iter().find(|r| r.method == m).unwrap();
    println!("observations (cf. paper §6.2):");
    println!("  pPIC vs pPITC RMSE: {} vs {} (local data helps)",
             fmt3(get(Method::PPic).rmse), fmt3(get(Method::PPitc).rmse));
    println!("  FGP time {}s vs pPIC {}s — the cubic wall the paper breaks",
             fmt3(get(Method::Fgp).time_s), fmt3(get(Method::PPic).time_s));
}
