//! Integration: the serve-path fast predictions (fit-staged predictive
//! operators, `Regressor::predict_fast`) reproduce the seed solve-based
//! predict paths to ≤1e-12 for ALL 8 `api::Method` variants, driven
//! boxed through the `Regressor` trait (`Gp`), at M ∈ {1, 4, 8}.

use pgpr::api::{Gp, GpBuilder, Method};
use pgpr::data::partition::random_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::testkit::assert_all_close;
use pgpr::util::Pcg64;

const ALL_METHODS: [Method; 8] = [
    Method::Fgp,
    Method::Pitc,
    Method::Pic,
    Method::Icf,
    Method::PPitc,
    Method::PPic,
    Method::PIcf,
    Method::Online,
];

fn builder(n: usize, d: usize, m: usize, seed: u64) -> (GpBuilder, Mat) {
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(d, 0.9, 1.0, 0.08);
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let y = rng.normals(n);
    let xs = Mat::from_vec(6, d, rng.normals(6 * d));
    let xu = Mat::from_vec(10, d, rng.normals(10 * d));
    let d_blocks = random_partition(n, m, &mut rng);
    let b = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support(xs)
        .partition(d_blocks)
        .rank(12)
        .seed(seed);
    (b, xu)
}

/// The headline serve-path contract: fast ≡ seed solve path ≤1e-12,
/// every method, boxed through `Regressor`, at M ∈ {1, 4, 8}.
#[test]
fn fast_path_equals_seed_path_all_methods() {
    let (n, d) = (24, 2);
    for m in [1usize, 4, 8] {
        for method in ALL_METHODS {
            let (b, xu) = builder(n, d, m, 7 + m as u64);
            let gp = b.method(method).fit().unwrap_or_else(|e| {
                panic!("{} fit M={m}: {e}", method.name())
            });
            let want = gp.predict(&xu).expect("seed predict");
            let got = gp.predict_fast(&xu).expect("fast predict");
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        }
    }
}

/// Repeated fast predictions reuse the staged operators without drift:
/// two calls on the same model are bitwise identical, and a different
/// batch still matches the seed path.
#[test]
fn staged_operators_are_stable_across_calls() {
    let (b, xu) = builder(24, 2, 4, 31);
    let gp = b.method(Method::PPic).fit().unwrap();
    let p1 = gp.predict_fast(&xu).unwrap();
    let p2 = gp.predict_fast(&xu).unwrap();
    assert_eq!(p1.mean, p2.mean);
    assert_eq!(p1.var, p2.var);
    let mut rng = Pcg64::seed(99);
    let xu2 = Mat::from_vec(5, 2, rng.normals(10));
    let want = gp.predict(&xu2).unwrap();
    let got = gp.predict_fast(&xu2).unwrap();
    assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
    assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
}

/// `refit` rebuilds the staged operators under the new hypers: the
/// refit model's fast path equals its own seed path (and differs from
/// the original model's predictions).
#[test]
fn refit_restages_operators() {
    for method in [Method::PPitc, Method::PPic, Method::Pitc] {
        let (b, xu) = builder(24, 2, 4, 13);
        let gp = b.method(method).fit().unwrap();
        let before = gp.predict_fast(&xu).unwrap();
        let hyp2 = SeArd::isotropic(2, 1.4, 1.3, 0.04);
        let refit = gp.refit(&hyp2).unwrap();
        let want = refit.predict(&xu).unwrap();
        let got = refit.predict_fast(&xu).unwrap();
        assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
        assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        assert!(got.mean != before.mean, "{}: hypers took effect",
                method.name());
    }
}

/// An online session invalidates its staged operators on absorb: the
/// fast path tracks the stream, matching the seed path after every
/// batch.
#[test]
fn online_absorb_invalidates_staged_operators() {
    let mut rng = Pcg64::seed(57);
    let (n, d, m) = (16, 2, 2);
    let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let y = rng.normals(n);
    let xs = Mat::from_vec(4, d, rng.normals(4 * d));
    let xu = Mat::from_vec(6, d, rng.normals(6 * d));
    let d_blocks = random_partition(n, m, &mut rng);
    let mut sess = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support(xs)
        .partition(d_blocks)
        .online()
        .unwrap();

    use pgpr::api::{PredictSpec, Regressor};
    let check = |sess: &pgpr::api::OnlineSession, xu: &Mat| {
        let want = sess.predict(&PredictSpec::new(xu.clone())).unwrap();
        let got = sess.predict_fast(xu).unwrap();
        assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
        assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        got
    };
    let before = check(&sess, &xu);
    let batch: Vec<(Mat, Vec<f64>)> = (0..m)
        .map(|_| (Mat::from_vec(3, d, rng.normals(3 * d)), rng.normals(3)))
        .collect();
    sess.absorb(&batch).unwrap();
    let after = check(&sess, &xu);
    assert!(after.mean != before.mean,
            "absorb must change the staged predictions");
}
