//! End-to-end acceptance for the telemetry subsystem (ISSUE 8).
//!
//! One fit + predict + serve pass recorded into a scoped registry must
//! yield a snapshot with:
//!
//! 1. nested phase spans under all three protocol spans
//!    (`protocol.pPITC` / `protocol.pPIC` / `protocol.pICF`, each with
//!    `phase.*` children carrying collective events),
//! 2. per-method request counters (`api.requests.<Method>`),
//! 3. a `serve.latency_s` histogram whose interpolated p50/p99 agree
//!    with a sort-based oracle over the actual response latencies to
//!    within one log-scale bucket width,
//! 4. a `serve.queue_depth` gauge that has drained back to zero,
//! 5. a Prometheus rendering that scrapes cleanly for the same names.
//!
//! The serve pass uses the *serial* executor so every record lands on
//! this thread's scoped registry (thread-pool workers would record to
//! the process-global one).

use std::sync::Arc;

use pgpr::api::{Gp, Method, PredictSpec};
use pgpr::cluster::ParallelExecutor;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::obsv::hist::BUCKET_LO;
use pgpr::obsv::{Registry, SnapshotMode, SpanNode, RELATIVE_BUCKET_WIDTH};
use pgpr::server::{DynamicBatcher, PredictRequest, ServeReport};
use pgpr::util::Pcg64;

/// Depth-first search for a span by name anywhere in the tree.
fn find<'a>(nodes: &'a [SpanNode], name: &str) -> Option<&'a SpanNode> {
    for n in nodes {
        if n.name == name {
            return Some(n);
        }
        if let Some(hit) = find(&n.children, name) {
            return Some(hit);
        }
    }
    None
}

/// The recorded workload: fit + predict with each protocol, then a
/// serve stream through the dynamic batcher on the serial executor.
fn fit_predict_serve(m: usize, n: usize, s: usize, seed: u64) -> ServeReport {
    let d = 2usize;
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.05);
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let y = rng.normals(n);
    let u = m * 4;
    let xu = Mat::from_vec(u, d, rng.normals(u * d));
    let base = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(s)
        .seed(seed);
    for method in [Method::PPitc, Method::PPic, Method::PIcf] {
        let gp = base.clone().method(method).fit().unwrap();
        let out = gp.predict_full(&PredictSpec::new(xu.clone())).unwrap();
        assert_eq!(out.prediction.mean.len(), u, "{}", method.name());
    }
    let model = base.serve().unwrap();
    let requests: Vec<PredictRequest> = (0..16 * m)
        .map(|i| PredictRequest {
            id: i as u64,
            x: rng.normals(d),
            arrival_s: i as f64 * 1e-4,
        })
        .collect();
    let mut batcher = DynamicBatcher::new(model.machines(), d, 4, 5e-4);
    let exec = ParallelExecutor::serial();
    model.serve_fast(&requests, &mut batcher, &exec)
}

#[test]
fn fit_predict_serve_snapshot_is_complete() {
    let m = 4usize;
    let reg = Arc::new(Registry::new());
    let report;
    {
        let _scope = reg.install();
        report = fit_predict_serve(m, 48, 12, 7);
    }
    let snap = reg.snapshot(SnapshotMode::Full);

    // 1. protocol spans, each with nested phase children that in turn
    //    carry collective events.
    for proto in ["protocol.pPITC", "protocol.pPIC", "protocol.pICF"] {
        let node = find(&snap.spans, proto)
            .unwrap_or_else(|| panic!("missing span {proto}"));
        let phases: Vec<&SpanNode> = node
            .children
            .iter()
            .filter(|c| c.name.starts_with("phase."))
            .collect();
        assert!(!phases.is_empty(), "{proto}: no phase.* children");
        assert!(
            phases.iter().any(|p| p
                .children
                .iter()
                .any(|c| c.name.starts_with("collective."))),
            "{proto}: no collective events under any phase"
        );
    }
    assert!(find(&snap.spans, "serve.stream").is_some(),
            "missing serve.stream span");

    // 2. per-method request counters.
    for method in ["pPITC", "pPIC", "pICF"] {
        let key = format!("api.requests.{method}");
        assert_eq!(snap.counters.get(&key).copied(), Some(1), "{key}");
    }
    assert!(snap.counters.get("cluster.runs").copied().unwrap_or(0) >= 3);

    // 3. latency histogram vs the sort oracle over the real responses.
    let h = snap.hists.get("serve.latency_s").expect("latency hist");
    let mut lat: Vec<f64> =
        report.responses.iter().map(|r| r.latency_s).collect();
    lat.sort_by(f64::total_cmp);
    assert_eq!(h.count as usize, lat.len(), "one record per response");
    for (q, got) in [(0.50, h.p50), (0.99, h.p99)] {
        let idx =
            ((q * lat.len() as f64).ceil() as usize).clamp(1, lat.len()) - 1;
        let want = lat[idx];
        let tol = want.abs() * RELATIVE_BUCKET_WIDTH + BUCKET_LO;
        assert!(
            (got - want).abs() <= tol,
            "p{}: hist {got} vs oracle {want} (tol {tol})",
            (q * 100.0) as u32
        );
    }
    assert_eq!(h.min, lat[0], "hist min is exact");
    assert_eq!(h.max, lat[lat.len() - 1], "hist max is exact");

    // 4. queue depth gauge drained back to zero.
    assert_eq!(snap.gauges.get("serve.queue_depth").copied().unwrap_or(0), 0);

    // 5. Prometheus text carries the same names, mangled.
    let prom = snap.to_prometheus();
    for needle in
        ["pgpr_api_requests_pPITC", "pgpr_serve_latency_s", "pgpr_cluster_runs"]
    {
        assert!(prom.contains(needle), "prometheus missing {needle}:\n{prom}");
    }

    // JSON round-trip sanity: the export parses and declares schema v1.
    let doc = pgpr::util::json::Json::parse(&snap.to_json().to_string_pretty())
        .expect("snapshot JSON parses");
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(),
               "pgpr-telemetry/1");
}

/// The scoped run leaves nothing behind: a second empty registry
/// installed afterwards snapshots clean, proving test isolation.
#[test]
fn scoped_registries_do_not_leak_between_runs() {
    {
        let reg = Arc::new(Registry::new());
        let _scope = reg.install();
        fit_predict_serve(2, 16, 6, 11);
    }
    let reg = Arc::new(Registry::new());
    let _scope = reg.install();
    let snap = reg.snapshot(SnapshotMode::Full);
    assert!(snap.counters.is_empty(), "counters leaked: {:?}", snap.counters);
    assert!(snap.spans.is_empty(), "spans leaked");
    assert!(snap.hists.is_empty(), "hists leaked");
}
