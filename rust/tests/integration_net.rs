//! Integration: the TCP serving front-end over real loopback sockets.
//!
//! Covers the net/ subsystem end to end: HTTP hardening against
//! malformed/oversized input arriving over actual sockets, keep-alive
//! pipelining, socket-vs-direct bitwise prediction equivalence,
//! admission control under overload (bounded queues, 429/503 sheds,
//! counters in `/stats`), deadline expiry, `lose_machine` under live
//! traffic, graceful drain, and the `loadgen` smoke sweep writing a
//! parseable `BENCH_e2e.json`.
//!
//! Every test binds `127.0.0.1:0` (kernel-assigned port), so the suite
//! is safe under the default parallel test runner.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use pgpr::api::Gp;
use pgpr::kernel::SeArd;
use pgpr::linalg::{LinalgCtx, Mat};
use pgpr::net::loadgen::{run_loadgen, HttpClient, LoadgenConfig};
use pgpr::net::{NodeConfig, NodeHandle, NodeServer};
use pgpr::runtime::NativeBackend;
use pgpr::server::{ServeScratch, ServedModel};
use pgpr::util::json::{self, Json};
use pgpr::util::Pcg64;

const D: usize = 2;

/// Deterministic tiny model: two builds with the same knobs are
/// bitwise-identical (pinned by `service.rs` tests), which is what
/// lets these tests compare socket responses against a local twin.
fn model(n: usize, m: usize, s: usize, seed: u64) -> ServedModel {
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(D, 1.0, 1.0, 0.05);
    let xd = Mat::from_vec(n, D, rng.normals(n * D));
    let y = rng.normals(n);
    Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(s)
        .seed(seed)
        .serve()
        .expect("fit")
}

/// Fast-drain config so tests never wait on the 5 s default read
/// timeout.
fn quick_cfg() -> NodeConfig {
    NodeConfig {
        workers: 4,
        read_timeout_s: 0.25,
        idle_close_s: 1.0,
        ..NodeConfig::default()
    }
}

fn start(m: usize, seed: u64, cfg: NodeConfig) -> NodeHandle {
    NodeServer::start(model(48, m, 8, seed), "127.0.0.1:0", cfg)
        .expect("bind")
}

fn predict_body(x: &[f64]) -> String {
    json::obj(vec![(
        "x",
        Json::Arr(x.iter().map(|&v| Json::Num(v)).collect()),
    )])
    .to_string_compact()
}

/// Send raw bytes, read until the server closes, return the response
/// text (parser-level errors always close the connection).
fn raw_roundtrip(addr: &str, req: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(req).expect("write");
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

// ---------------------------------------------------------------------

#[test]
fn healthz_stats_and_routing() {
    let h = start(3, 5, quick_cfg());
    let t = h.addr().to_string();
    let mut c = HttpClient::connect(&t, 10.0).unwrap();

    let doc = c.get_json("/healthz").unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(doc.get("d").and_then(Json::as_usize), Some(D));
    assert_eq!(doc.get("machines").and_then(Json::as_usize), Some(3));
    assert!(doc.get("queue_cap").and_then(Json::as_usize).unwrap() > 0);

    // JSON scrape: the shared telemetry schema, with net counters live
    let stats = c.get_json("/stats?format=json").unwrap();
    assert_eq!(stats.get("schema").and_then(Json::as_str),
               Some("pgpr-telemetry/1"));
    let requests = stats
        .get("counters")
        .and_then(|cs| cs.get("net.requests"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(requests >= 2, "net.requests = {requests}");

    // prometheus scrape: mangled name present
    let (status, body) = c.get("/stats").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("pgpr_net_requests"), "prometheus:\n{text}");

    // unknown path and wrong method
    assert_eq!(c.get("/nope").unwrap().0, 404);
    assert_eq!(c.get("/v1/predict").unwrap().0, 405);
    assert_eq!(c.post("/healthz", b"").unwrap().0, 405);

    h.shutdown_and_join();
}

#[test]
fn socket_predictions_match_direct_calls_bitwise() {
    let h = start(3, 9, quick_cfg());
    let t = h.addr().to_string();
    let twin = model(48, 3, 8, 9);
    let lctx = LinalgCtx::serial();
    let mut scratch = ServeScratch::new();
    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    let mut rng = Pcg64::seed(77);
    for _ in 0..20 {
        let x = rng.normals(D);
        let (status, body) =
            c.post("/v1/predict", predict_body(&x).as_bytes()).unwrap();
        assert_eq!(status, 200, "{}",
                   String::from_utf8_lossy(&body));
        let doc = Json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap();
        let got_mean = doc.get("mean").and_then(Json::as_f64).unwrap();
        let got_var = doc.get("var").and_then(Json::as_f64).unwrap();
        let m = twin.router.route(&x);
        let (mean, var) =
            twin.predict_batch_fast(m, &x, 1, 1, &lctx, &mut scratch);
        // bitwise: padding transparency + shortest-roundtrip JSON f64
        assert_eq!(got_mean.to_bits(), mean[0].to_bits());
        assert_eq!(got_var.to_bits(), var[0].to_bits());
    }
    h.shutdown_and_join();
}

#[test]
fn malformed_inputs_over_real_sockets() {
    let h = start(2, 3, quick_cfg());
    let t = h.addr().to_string();

    let cases: &[(&[u8], &str)] = &[
        (b"GARBAGE\r\n\r\n", "HTTP/1.1 400"),
        (b"GET /healthz HTTP/2.0\r\n\r\n", "HTTP/1.1 400"),
        (b"DELETE /healthz HTTP/1.1\r\n\r\n", "HTTP/1.1 501"),
        (b"POST /v1/predict HTTP/1.1\r\nhost: a\r\n\r\n",
         "HTTP/1.1 411"),
        (b"POST /v1/predict HTTP/1.1\r\ncontent-length: 99999999\r\n\r\n",
         "HTTP/1.1 413"),
        (b"POST /v1/predict HTTP/1.1\r\ncontent-length: ten\r\n\r\n",
         "HTTP/1.1 400"),
        (b"GET /healthz HTTP/1.1\r\nbroken header line\r\n\r\n",
         "HTTP/1.1 400"),
        (b"POST /v1/predict HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
         "HTTP/1.1 501"),
    ];
    for (req, want) in cases {
        let resp = raw_roundtrip(&t, req);
        assert!(resp.starts_with(want),
                "request {:?} → {:?}, want {want}",
                String::from_utf8_lossy(req), resp);
    }

    // oversized request line → 414
    let mut long = b"GET /".to_vec();
    long.extend(std::iter::repeat_n(b'a', 9000));
    long.extend_from_slice(b" HTTP/1.1\r\n\r\n");
    assert!(raw_roundtrip(&t, &long).starts_with("HTTP/1.1 414"));

    // too many headers → 431
    let mut many = b"GET /healthz HTTP/1.1\r\n".to_vec();
    for i in 0..70 {
        many.extend_from_slice(format!("x-h{i}: v\r\n").as_bytes());
    }
    many.extend_from_slice(b"\r\n");
    assert!(raw_roundtrip(&t, &many).starts_with("HTTP/1.1 431"));

    // premature close mid-request never wedges the node...
    {
        let mut s = TcpStream::connect(&t).unwrap();
        s.write_all(b"POST /v1/predict HTTP/1.1\r\ncontent-le").unwrap();
        // drop: peer disappears mid-header
    }
    // ...and a bad predict body is a 400 on a *kept-alive* connection
    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    assert_eq!(c.post("/v1/predict", b"{\"x\":[1.0]}").unwrap().0, 400);
    assert_eq!(c.post("/v1/predict", b"not json").unwrap().0, 400);
    let doc = c.get_json("/healthz").unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));

    h.shutdown_and_join();
}

#[test]
fn pipelined_keep_alive_requests_all_answered() {
    let h = start(2, 3, quick_cfg());
    let t = h.addr().to_string();
    let mut s = TcpStream::connect(&t).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let one = b"GET /healthz HTTP/1.1\r\nhost: a\r\n\r\n";
    let mut pipelined = Vec::new();
    for _ in 0..3 {
        pipelined.extend_from_slice(one);
    }
    s.write_all(&pipelined).unwrap();
    let mut text = String::new();
    let mut buf = [0u8; 4096];
    while text.matches("HTTP/1.1 200").count() < 3 {
        let n = s.read(&mut buf).expect("read");
        assert!(n > 0, "server closed early:\n{text}");
        text.push_str(&String::from_utf8_lossy(&buf[..n]));
    }
    assert_eq!(text.matches("\"status\"").count(), 3);
    h.shutdown_and_join();
}

#[test]
fn overload_sheds_bounded_and_observable() {
    // tiny doors + slow batching: saturation is certain
    let cfg = NodeConfig {
        queue_cap: 4,
        max_inflight: 2,
        batch_wait_s: 0.05,
        deadline_s: 10.0,
        conn_backlog: 64,
        workers: 8,
        ..quick_cfg()
    };
    let h = start(2, 3, cfg);
    let t = h.addr().to_string();

    let (ok, shed, other) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|ti| {
                let t = &t;
                s.spawn(move || {
                    let mut rng = Pcg64::seed(100 + ti);
                    let mut c = HttpClient::connect(t, 10.0).unwrap();
                    let (mut ok, mut shed, mut other) = (0u32, 0u32, 0u32);
                    for _ in 0..25 {
                        let body = predict_body(&rng.normals(D));
                        match c.post("/v1/predict", body.as_bytes()) {
                            Ok((200, _)) => ok += 1,
                            Ok((429, _)) | Ok((503, _)) => shed += 1,
                            _ => other += 1,
                        }
                    }
                    (ok, shed, other)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).fold(
            (0, 0, 0),
            |(a, b, c), (x, y, z)| (a + x, b + y, c + z),
        )
    });
    assert!(ok > 0, "no request survived admission");
    assert!(shed > 0, "overload never shed (ok={ok}, other={other})");
    assert_eq!(other, 0, "unexpected statuses/transport errors");

    // sheds and peaks are observable in /stats, and the peaks honor
    // the configured bounds: backpressure stayed bounded
    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    let stats = c.get_json("/stats?format=json").unwrap();
    let counter = |name: &str| {
        stats
            .get("counters")
            .and_then(|cs| cs.get(name))
            .and_then(Json::as_usize)
            .unwrap_or(0)
    };
    assert!(counter("net.shed.inflight") + counter("net.shed.queue")
                >= shed as usize,
            "shed counters under-report");
    let gauge = |name: &str| {
        stats
            .get("gauges")
            .and_then(|g| g.get(name))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    assert!(gauge("net.queue_depth_peak") <= 4.0);
    assert!(gauge("net.inflight_peak") <= 2.0);
    h.shutdown_and_join();
}

#[test]
fn zero_deadline_expires_every_predict() {
    let cfg = NodeConfig { deadline_s: 0.0, ..quick_cfg() };
    let h = start(2, 3, cfg);
    let t = h.addr().to_string();
    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    let mut rng = Pcg64::seed(4);
    for _ in 0..5 {
        let (status, body) = c
            .post("/v1/predict", predict_body(&rng.normals(D)).as_bytes())
            .unwrap();
        assert_eq!(status, 503);
        assert!(String::from_utf8_lossy(&body).contains("deadline"));
    }
    let stats = c.get_json("/stats?format=json").unwrap();
    let expired = stats
        .get("counters")
        .and_then(|cs| cs.get("net.shed.deadline"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(expired >= 5, "net.shed.deadline = {expired}");
    // non-predict endpoints are unaffected
    assert_eq!(c.get("/healthz").unwrap().0, 200);
    h.shutdown_and_join();
}

#[test]
fn lose_machine_under_live_traffic() {
    let cfg = NodeConfig { deadline_s: 5.0, ..quick_cfg() };
    let h = start(3, 21, cfg);
    let t = h.addr().to_string();

    let statuses = std::thread::scope(|s| {
        let t2 = &t;
        // live traffic: sequential predicts throughout the rebalance
        let traffic = s.spawn(move || {
            let mut rng = Pcg64::seed(55);
            let mut c = HttpClient::connect(t2, 10.0).unwrap();
            let mut statuses = Vec::new();
            for _ in 0..120 {
                let body = predict_body(&rng.normals(D));
                let (status, _) =
                    c.post("/v1/predict", body.as_bytes()).unwrap();
                statuses.push(status);
                std::thread::sleep(Duration::from_millis(1));
            }
            statuses
        });
        std::thread::sleep(Duration::from_millis(40));
        let mut admin = HttpClient::connect(&t, 30.0).unwrap();
        // out-of-range machine is a clean 409, cluster unchanged
        assert_eq!(
            admin.post("/v1/admin/lose_machine", b"{\"machine\":9}")
                .unwrap().0,
            409
        );
        let (status, body) = admin
            .post("/v1/admin/lose_machine", b"{\"machine\":1}")
            .unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let doc =
            Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(doc.get("machines").and_then(Json::as_usize),
                   Some(2));
        traffic.join().unwrap()
    });
    // continued 2xx from survivors: no request saw an error
    assert!(statuses.iter().all(|&s| s == 200),
            "non-200 during rebalance: {statuses:?}");

    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    let doc = c.get_json("/healthz").unwrap();
    assert_eq!(doc.get("machines").and_then(Json::as_usize), Some(2));

    // post-loss predictions are bitwise those of a twin that lost the
    // same machine (lose_machine ≡ fresh fit on the merged partition)
    let mut twin = model(48, 3, 8, 21);
    twin.lose_machine(1, &NativeBackend).unwrap();
    let lctx = LinalgCtx::serial();
    let mut scratch = ServeScratch::new();
    let mut rng = Pcg64::seed(91);
    for _ in 0..10 {
        let x = rng.normals(D);
        let (status, body) =
            c.post("/v1/predict", predict_body(&x).as_bytes()).unwrap();
        assert_eq!(status, 200);
        let doc = Json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap();
        let m = twin.router.route(&x);
        let (mean, var) =
            twin.predict_batch_fast(m, &x, 1, 1, &lctx, &mut scratch);
        assert_eq!(doc.get("mean").and_then(Json::as_f64).unwrap()
                       .to_bits(),
                   mean[0].to_bits());
        assert_eq!(doc.get("var").and_then(Json::as_f64).unwrap()
                       .to_bits(),
                   var[0].to_bits());
    }
    h.shutdown_and_join();
}

#[test]
fn graceful_drain_stops_listening_and_joins() {
    let h = start(2, 3, quick_cfg());
    let t = h.addr().to_string();

    std::thread::scope(|s| {
        let t2 = &t;
        let traffic: Vec<_> = (0..4)
            .map(|ti| {
                s.spawn(move || {
                    let mut rng = Pcg64::seed(200 + ti);
                    let mut c = match HttpClient::connect(t2, 5.0) {
                        Ok(c) => c,
                        Err(_) => return,
                    };
                    for _ in 0..10 {
                        let body = predict_body(&rng.normals(D));
                        // responses may stop mid-stream once the drain
                        // begins; transport errors are expected then
                        if let Ok((status, _)) =
                            c.post("/v1/predict", body.as_bytes())
                        {
                            assert!(
                                matches!(status, 200 | 429 | 503),
                                "unexpected status {status}"
                            );
                        } else {
                            return;
                        }
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        let mut admin = HttpClient::connect(&t, 10.0).unwrap();
        let (status, body) =
            admin.post("/v1/admin/shutdown", b"").unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("draining"));
        for th in traffic {
            th.join().unwrap();
        }
    });

    // every thread exits: drain flushed all open work
    h.join();
    // and the final snapshot is still scrapeable in-process
    let snap = h.registry()
        .snapshot(pgpr::obsv::SnapshotMode::Full);
    assert!(snap.to_json().to_string_compact()
        .contains("net.requests"));
}

#[test]
fn loadgen_smoke_writes_bench_e2e_report() {
    let h = start(2, 11, quick_cfg());
    let t = h.addr().to_string();
    let cfg = LoadgenConfig {
        target: t.clone(),
        qps_steps: vec![50.0],
        duration_s: 0.3,
        conns: 2,
        seed: 1,
    };
    let report = run_loadgen(&cfg).expect("loadgen");
    assert_eq!(report.steps.len(), 1);
    let st = &report.steps[0];
    assert!(st.ok > 0, "no successful request in smoke sweep");
    assert!(st.ok + st.shed_429 + st.shed_503 + st.http_errors
                + st.io_errors
                <= st.offered + 1);

    let path = std::env::temp_dir().join("pgpr_bench_e2e_test.json");
    let path_s = path.to_str().unwrap().to_string();
    report.write(&path_s).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_str),
               Some("pgpr-bench-e2e/1"));
    assert_eq!(
        doc.get("steps").and_then(Json::as_arr).map(<[Json]>::len),
        Some(1)
    );
    let _ = std::fs::remove_file(&path);
    h.shutdown_and_join();
}
