//! Facade equivalence suite — the api-redesign acceptance oracle.
//!
//! Drives all seven batch methods {FGP, PITC, PIC, ICF, pPITC, pPIC,
//! pICF} through the *same* `Regressor`-trait code path (a boxed
//! `api::Gp` built by `GpBuilder`) and asserts the facade's predictions
//! match the pre-existing direct calls — inherent model constructors and
//! protocol free functions — to ≤ 1e-12, for M ∈ {1, 4, 8}.
//!
//! This is what makes the facade safe to build on: it adds a door, not
//! a new numerical path.

use pgpr::api::{Gp, Method, PredictSpec};
use pgpr::data::partition::random_partition;
use pgpr::gp::icf_gp::IcfGp;
use pgpr::gp::pic::PicGp;
use pgpr::gp::pitc::PitcGp;
use pgpr::gp::{FullGp, Prediction};
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec};
use pgpr::runtime::NativeBackend;
use pgpr::testkit::assert_all_close;
use pgpr::util::Pcg64;

const TOL: f64 = 1e-12;
const N: usize = 40; // divisible by 1, 4, 8
const U: usize = 16; // divisible by 1, 4, 8
const D: usize = 2;
const RANK: usize = 10;

struct Problem {
    hyp: SeArd,
    xd: Mat,
    y: Vec<f64>,
    xs: Mat,
    xu: Mat,
}

fn problem(seed: u64) -> Problem {
    let mut rng = Pcg64::seed(seed);
    Problem {
        hyp: SeArd::isotropic(D, 0.9, 1.1, 0.08),
        xd: Mat::from_vec(N, D, rng.normals(N * D)),
        y: rng.normals(N),
        xs: Mat::from_vec(6, D, rng.normals(6 * D)),
        xu: Mat::from_vec(U, D, rng.normals(U * D)),
    }
}

/// Fit `method` through the facade — one code path for all seven.
fn facade(p: &Problem, method: Method, m: usize,
          d_blocks: &[Vec<usize>]) -> Gp {
    Gp::builder()
        .method(method)
        .hyp(p.hyp.clone())
        .data(p.xd.clone(), p.y.clone())
        .machines(m)
        .support(p.xs.clone())
        .partition(d_blocks.to_vec())
        .rank(RANK)
        .fit()
        .unwrap_or_else(|e| panic!("{} fit failed: {e}", method.name()))
}

fn check(tag: &str, got: &Prediction, want: &Prediction) {
    assert_all_close(&got.mean, &want.mean, TOL, TOL);
    assert_all_close(&got.var, &want.var, TOL, TOL);
    assert_eq!(got.len(), want.len(), "{tag}: length");
}

/// THE acceptance test: facade == direct calls, ≤1e-12, M ∈ {1,4,8},
/// every method through the identical `Regressor` path.
#[test]
fn facade_matches_direct_calls_for_all_methods() {
    let p = problem(2013);
    let mut rng = Pcg64::seed(7);
    for m in [1usize, 4, 8] {
        let d_blocks = random_partition(N, m, &mut rng);
        let u_blocks = random_partition(U, m, &mut rng);
        let ps = PredictSpec::new(p.xu.clone()).with_blocks(u_blocks.clone());
        let spec = ClusterSpec::new(m);

        // the same PredictSpec drives every facade model
        let preds: Vec<(Method, Prediction)> = Method::ALL
            .iter()
            .map(|&method| {
                let gp = facade(&p, method, m, &d_blocks);
                assert_eq!(gp.method(), method, "introspection");
                (method, gp.predict_spec(&ps).unwrap())
            })
            .collect();
        let get = |method: Method| -> &Prediction {
            &preds.iter().find(|(mm, _)| *mm == method).unwrap().1
        };

        // --- centralized: inherent constructors are the oracle
        let want = FullGp::fit(&p.hyp, &p.xd, &p.y).predict(&p.xu);
        check("FGP", get(Method::Fgp), &want);

        let want = PitcGp::fit(&p.hyp, &p.xd, &p.y, &p.xs, &d_blocks)
            .predict(&p.xu);
        check("PITC", get(Method::Pitc), &want);

        let want = PicGp::fit(&p.hyp, &p.xd, &p.y, &p.xs, &d_blocks)
            .predict(&p.xu, &u_blocks);
        check("PIC", get(Method::Pic), &want);

        let want = IcfGp::fit(&p.hyp, &p.xd, &p.y, RANK, &d_blocks)
            .predict(&p.xu);
        check("ICF", get(Method::Icf), &want);

        // --- distributed: protocol free functions are the oracle
        let want = ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &d_blocks,
                              &u_blocks, &NativeBackend, &spec);
        check("pPITC", get(Method::PPitc), &want.prediction);

        let want = ppic::run_with_partition(&p.hyp, &p.xd, &p.y, &p.xs,
                                            &p.xu, &d_blocks, &u_blocks,
                                            &NativeBackend, &spec);
        check("pPIC", get(Method::PPic), &want.prediction);

        let want = picf::run(&p.hyp, &p.xd, &p.y, &p.xu, &d_blocks, RANK,
                             &NativeBackend, &spec);
        check("pICF", get(Method::PIcf), &want.prediction);

        // --- Theorems 1–3 inside the facade: the parallel methods equal
        // their centralized counterparts through the same trait path
        for parallel in Method::PARALLEL {
            let central = parallel.centralized_counterpart().unwrap();
            let (a, b) = (get(parallel), get(central));
            assert_all_close(&a.mean, &b.mean, 1e-9, 1e-9);
            assert_all_close(&a.var, &b.var, 1e-9, 1e-9);
        }
    }
}

/// Thread-parallel execution through the facade changes nothing —
/// the PR-1/PR-2 executor oracle holds behind the new door too.
#[test]
fn facade_predictions_executor_independent() {
    let p = problem(77);
    let mut rng = Pcg64::seed(3);
    let m = 4;
    let d_blocks = random_partition(N, m, &mut rng);
    let u_blocks = random_partition(U, m, &mut rng);
    let ps = PredictSpec::new(p.xu.clone()).with_blocks(u_blocks);
    for method in Method::ALL {
        let serial = facade(&p, method, m, &d_blocks)
            .predict_spec(&ps)
            .unwrap();
        let threaded = Gp::builder()
            .method(method)
            .hyp(p.hyp.clone())
            .data(p.xd.clone(), p.y.clone())
            .machines(m)
            .support(p.xs.clone())
            .partition(d_blocks.clone())
            .rank(RANK)
            .threads(3)
            .fit()
            .unwrap()
            .predict_spec(&ps)
            .unwrap();
        assert_eq!(serial.mean, threaded.mean, "{}", method.name());
        assert_eq!(serial.var, threaded.var, "{}", method.name());
    }
}

/// Refit through the trait object == fresh facade fit (per method).
#[test]
fn boxed_refit_matches_fresh_fit() {
    let p = problem(41);
    let mut rng = Pcg64::seed(9);
    let m = 4;
    let d_blocks = random_partition(N, m, &mut rng);
    let u_blocks = random_partition(U, m, &mut rng);
    let ps = PredictSpec::new(p.xu.clone()).with_blocks(u_blocks);
    let hyp2 = SeArd::isotropic(D, 1.3, 0.9, 0.04);
    for method in Method::ALL {
        let gp = facade(&p, method, m, &d_blocks);
        let refit = gp.refit(&hyp2)
            .unwrap_or_else(|e| panic!("{} refit: {e}", method.name()));
        assert_eq!(refit.method(), method);
        let got = refit.predict_spec(&ps).unwrap();
        let p2 = Problem { hyp: hyp2.clone(), ..clone_problem(&p) };
        let want = facade(&p2, method, m, &d_blocks)
            .predict_spec(&ps)
            .unwrap();
        assert_eq!(got.mean, want.mean, "{}", method.name());
        assert_eq!(got.var, want.var, "{}", method.name());
    }
}

fn clone_problem(p: &Problem) -> Problem {
    Problem {
        hyp: p.hyp.clone(),
        xd: p.xd.clone(),
        y: p.y.clone(),
        xs: p.xs.clone(),
        xu: p.xu.clone(),
    }
}
