//! Integration: full pipeline on both synthetic domains — workload
//! generation → support selection → clustering partition → all methods →
//! metrics — asserting the paper's qualitative orderings hold end to end.

use pgpr::bench_support::experiments::{
    run_methods, speedup_order, ExperimentConfig, Method,
};
use pgpr::bench_support::workloads::{prepare, Domain};
use pgpr::runtime::NativeBackend;
use std::sync::Arc;

fn baseline_rmse(y: &[f64]) -> f64 {
    // predicting the train mean — the floor any model must beat
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    (y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
        / y.len() as f64)
        .sqrt()
}

#[test]
fn aimpeak_pipeline_beats_mean_baseline() {
    let w = prepare(Domain::Aimpeak, 600, 120, 5, false);
    let cfg = ExperimentConfig { machines: 6, support_size: 48, rank: 48,
                                 seed: 5, threads: 0 };
    let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                              Arc::new(NativeBackend));
    let floor = baseline_rmse(&w.test.y);
    for r in &results {
        if r.method == Method::Icf || r.method == Method::PIcf {
            continue; // rank 48 may be in the pathological regime
        }
        assert!(
            r.rmse < floor,
            "{:?} rmse {} not better than mean-baseline {floor}",
            r.method, r.rmse
        );
    }
}

#[test]
fn sarcos_pipeline_orderings() {
    let w = prepare(Domain::Sarcos, 480, 96, 6, false);
    let cfg = ExperimentConfig { machines: 4, support_size: 32, rank: 64,
                                 seed: 6, threads: 0 };
    let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                              Arc::new(NativeBackend));
    let get = |m: Method| results.iter().find(|r| r.method == m).unwrap();

    // paper §6.2: pPIC ≥ pPITC in accuracy (local data helps)
    assert!(get(Method::PPic).rmse <= get(Method::PPitc).rmse * 1.05);
    // FGP is the accuracy anchor
    assert!(get(Method::Fgp).rmse <= get(Method::PPic).rmse * 1.2 + 0.5);
    // theorem equivalences at the pipeline level
    assert!((get(Method::PPitc).rmse - get(Method::Pitc).rmse).abs() < 1e-8);
    assert!((get(Method::PPic).rmse - get(Method::Pic).rmse).abs() < 1e-8);
    assert!((get(Method::PIcf).rmse - get(Method::Icf).rmse).abs() < 1e-8);
    // parallel methods are faster than FGP (the scalability claim)
    assert!(get(Method::PPitc).time_s < get(Method::Fgp).time_s);
    assert!(get(Method::PPic).time_s < get(Method::Fgp).time_s);
}

#[test]
fn speedup_grows_with_data_size() {
    // paper observation (c): pPITC/pPIC speedups grow with |D|
    let cfg = ExperimentConfig { machines: 4, support_size: 24, rank: 24, threads: 0,
                                 seed: 7 };
    let methods = [Method::Pitc, Method::PPitc];
    let w_small = prepare(Domain::Sarcos, 240, 48, 7, false);
    let w_big = prepare(Domain::Sarcos, 960, 48, 7, false);
    let r_small = run_methods(&w_small, &cfg, &methods, Arc::new(NativeBackend));
    let r_big = run_methods(&w_big, &cfg, &methods, Arc::new(NativeBackend));
    let s_small = r_small.last().unwrap().speedup.unwrap();
    let s_big = r_big.last().unwrap().speedup.unwrap();
    assert!(
        s_big > s_small * 0.8,
        "speedup should grow (or hold) with |D|: {s_small} -> {s_big}"
    );
}
