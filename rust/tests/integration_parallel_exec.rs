//! Equivalence of thread-parallel and serial cluster execution.
//!
//! The `ParallelExecutor` runs each simulated machine's work on a real
//! thread pool. The paper's Theorems 1–3 give a hard oracle: whatever
//! the executor, pPITC / pPIC / pICF predictions must equal the serial
//! simulated run AND the centralized references (PitcGp / PicGp / IcfGp)
//! — asserted here to ≤1e-10 for M ∈ {1, 4, 8}. A final test checks the
//! acceptance criterion that ≥4 threads yield real wall-clock speedup on
//! a multicore host.

use pgpr::data::partition::random_partition;
use pgpr::gp::icf_gp::IcfGp;
use pgpr::gp::pic::PicGp;
use pgpr::gp::pitc::PitcGp;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec, ProtocolOutput};
use pgpr::runtime::NativeBackend;
use pgpr::testkit::assert_all_close;
use pgpr::util::Pcg64;

const TOL: f64 = 1e-10;

struct Problem {
    hyp: SeArd,
    xd: Mat,
    y: Vec<f64>,
    xs: Mat,
    xu: Mat,
    d_blocks: Vec<Vec<usize>>,
    u_blocks: Vec<Vec<usize>>,
}

/// A problem with `per` training points per machine and a fixed random
/// partition, sized so every M in {1,4,8} divides evenly.
fn problem(m: usize, per: usize, seed: u64) -> Problem {
    let d = 2;
    let n = m * per;
    let u = m * 3;
    let s = 6;
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(d, 0.9, 1.1, 0.1);
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let xs = Mat::from_vec(s, d, rng.normals(s * d));
    let xu = Mat::from_vec(u, d, rng.normals(u * d));
    let y = rng.normals(n);
    let d_blocks = random_partition(n, m, &mut rng);
    let u_blocks = random_partition(u, m, &mut rng);
    Problem { hyp, xd, y, xs, xu, d_blocks, u_blocks }
}

fn assert_same_prediction(tag: &str, got: &ProtocolOutput, want_mean: &[f64],
                          want_var: &[f64]) {
    assert_all_close(&got.prediction.mean, want_mean, TOL, TOL);
    assert_all_close(&got.prediction.var, want_var, TOL, TOL);
    assert!(got.metrics.wall_s > 0.0, "{tag}: wall clock not recorded");
}

#[test]
fn ppitc_thread_parallel_equals_serial_and_centralized() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 5, 100 + m as u64);
        let serial = ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu,
                                &p.d_blocks, &p.u_blocks, &NativeBackend,
                                &ClusterSpec::new(m));
        let centralized =
            PitcGp::fit(&p.hyp, &p.xd, &p.y, &p.xs, &p.d_blocks)
                .predict(&p.xu);
        for threads in [4usize, 8] {
            let par = ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu,
                                 &p.d_blocks, &p.u_blocks, &NativeBackend,
                                 &ClusterSpec::with_threads(m, threads));
            let tag = format!("ppitc m={m} threads={threads}");
            assert_same_prediction(&tag, &par, &serial.prediction.mean,
                                   &serial.prediction.var);
            assert_same_prediction(&tag, &par, &centralized.mean,
                                   &centralized.var);
            assert_eq!(par.metrics.threads, threads, "{tag}");
        }
    }
}

#[test]
fn ppic_thread_parallel_equals_serial_and_centralized() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 5, 200 + m as u64);
        let serial = ppic::run_with_partition(
            &p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks, &p.u_blocks,
            &NativeBackend, &ClusterSpec::new(m));
        let centralized = PicGp::fit(&p.hyp, &p.xd, &p.y, &p.xs, &p.d_blocks)
            .predict(&p.xu, &p.u_blocks);
        for threads in [4usize, 8] {
            let par = ppic::run_with_partition(
                &p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks, &p.u_blocks,
                &NativeBackend, &ClusterSpec::with_threads(m, threads));
            let tag = format!("ppic m={m} threads={threads}");
            assert_same_prediction(&tag, &par, &serial.prediction.mean,
                                   &serial.prediction.var);
            assert_same_prediction(&tag, &par, &centralized.mean,
                                   &centralized.var);
        }
    }
}

#[test]
fn picf_thread_parallel_equals_serial_and_centralized() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 5, 300 + m as u64);
        let rank = (p.xd.rows / 2).max(1);
        let serial = picf::run(&p.hyp, &p.xd, &p.y, &p.xu, &p.d_blocks, rank,
                               &NativeBackend, &ClusterSpec::new(m));
        let centralized = IcfGp::fit(&p.hyp, &p.xd, &p.y, rank, &p.d_blocks)
            .predict(&p.xu);
        for threads in [4usize, 8] {
            let par = picf::run(&p.hyp, &p.xd, &p.y, &p.xu, &p.d_blocks, rank,
                                &NativeBackend,
                                &ClusterSpec::with_threads(m, threads));
            let tag = format!("picf m={m} threads={threads}");
            assert_same_prediction(&tag, &par, &serial.prediction.mean,
                                   &serial.prediction.var);
            // centralized ICF reaches the same numbers via a different
            // factorization path; 1e-8 matches the seed's Theorem 3 test
            assert_all_close(&par.prediction.mean, &centralized.mean,
                             1e-8, 1e-8);
            assert_all_close(&par.prediction.var, &centralized.var,
                             1e-8, 1e-8);
        }
    }
}

/// The online absorb/predict loop is executor-independent too.
#[test]
fn online_thread_parallel_equals_serial() {
    use pgpr::parallel::online::OnlineGp;
    let m = 4;
    let per = 6;
    let mut rng = Pcg64::seed(77);
    let d = 2;
    let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
    let xs = Mat::from_vec(4, d, rng.normals(4 * d));
    let batches: Vec<Vec<(Mat, Vec<f64>)>> = (0..3)
        .map(|_| {
            (0..m)
                .map(|_| {
                    (Mat::from_vec(per, d, rng.normals(per * d)),
                     rng.normals(per))
                })
                .collect()
        })
        .collect();
    let xu = Mat::from_vec(8, d, rng.normals(8 * d));
    let u_blocks = random_partition(8, m, &mut rng);

    let run = |spec: ClusterSpec| {
        let mut gp = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend), spec);
        for b in &batches {
            gp.absorb(b);
        }
        (gp.predict_ppitc(&xu, &u_blocks), gp.predict_ppic(&xu, &u_blocks))
    };
    let (s_pitc, s_pic) = run(ClusterSpec::new(m));
    let (p_pitc, p_pic) = run(ClusterSpec::with_threads(m, 4));
    assert_all_close(&p_pitc.prediction.mean, &s_pitc.prediction.mean, TOL, TOL);
    assert_all_close(&p_pitc.prediction.var, &s_pitc.prediction.var, TOL, TOL);
    assert_all_close(&p_pic.prediction.mean, &s_pic.prediction.mean, TOL, TOL);
    assert_all_close(&p_pic.prediction.var, &s_pic.prediction.var, TOL, TOL);
}

/// Acceptance criterion: with >= 4 threads on a multicore host, the
/// thread-parallel run beats the serial executor's wall clock. Skipped
/// on hosts with < 4 cores (the speedup physically cannot exist there).
/// `PGPR_LENIENT_PERF=1` downgrades the assert to a warning — for
/// shared/oversubscribed CI runners where `available_parallelism`
/// counts SMT siblings and timing assertions flake; an idle dedicated
/// host should leave it unset.
#[test]
fn thread_parallel_reports_wall_clock_speedup() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping speedup check: only {cores} host cores");
        return;
    }
    let m = 4;
    // per-machine blocks big enough that the O((|D|/M)^3) local-summary
    // cholesky dwarfs thread-pool overhead (tens of ms per block)
    let p = problem(m, 400, 999);
    let best_serial = (0..3)
        .map(|_| {
            ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks,
                       &p.u_blocks, &NativeBackend, &ClusterSpec::new(m))
                .metrics
                .wall_s
        })
        .fold(f64::INFINITY, f64::min);
    let spec = ClusterSpec::with_threads(m, 4);
    let best_par = (0..3)
        .map(|_| {
            ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks,
                       &p.u_blocks, &NativeBackend, &spec)
                .metrics
                .wall_s
        })
        .fold(f64::INFINITY, f64::min);
    eprintln!(
        "wall-clock: serial {best_serial:.4}s, 4-thread {best_par:.4}s \
         (ratio {:.2}) on {cores} cores",
        best_serial / best_par
    );
    // On an idle >= 4-core host the min-of-3 parallel run beats serial by
    // ~2-3x, so requiring a genuine >10% win still leaves wide margin —
    // and catches a regression that silently degrades the executor to
    // serial (ratio 1.0), which a slower-than-serial check would miss.
    if best_par >= best_serial * 0.9 {
        let msg = format!(
            "no real wall-clock speedup: parallel {best_par:.4}s vs serial \
             {best_serial:.4}s on {cores} cores"
        );
        if std::env::var_os("PGPR_LENIENT_PERF").is_some() {
            eprintln!("PGPR_LENIENT_PERF set — not failing: {msg}");
        } else {
            panic!("{msg}");
        }
    }
}
