//! Chaos suite pinning the fault-injecting cluster transport.
//!
//! Four contracts, asserted over pPITC / pPIC / pICF (and the online
//! path for the first):
//!
//! 1. **Zero-fault equivalence oracle** — running through the fault
//!    transport with [`FaultPlan::none`] is *bitwise* identical to the
//!    direct path (predictions AND traffic), for M ∈ {1, 4, 8}.
//! 2. **Deterministic replay** — the same non-trivial plan produces
//!    bitwise-identical predictions, fault counters, traffic and final
//!    block ownership on every run.
//! 3. **Machine death at every phase** — the run completes, the dead
//!    machine ends up owning nothing, the survivors' blocks cover all
//!    data rows exactly once, and held-out RMSE stays within the
//!    documented degradation factor (≤ 3× the fault-free RMSE + 1e-6).
//!    Only when *every* machine dies does the run return the typed
//!    [`MachinesLost`] error.
//! 4. **Random plans never hang** — property-generated fault plans
//!    (drops, stragglers, random deaths) always either complete or
//!    return the typed error, under a watchdog that turns a deadlock
//!    into a test failure.

use std::sync::Arc;
use std::time::Duration;

use pgpr::cluster::{FaultPlan, MachinesLost};
use pgpr::obsv::{Registry, SnapshotMode};
use pgpr::data::partition::random_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::online::OnlineGp;
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec, FaultRun,
                     ProtocolOutput};
use pgpr::runtime::NativeBackend;
use pgpr::testkit::prop::{prop_check, with_watchdog};
use pgpr::util::Pcg64;

/// Documented degradation bound for runs that lose machines: held-out
/// RMSE at most this factor times the fault-free RMSE (README
/// "Fault tolerance").
const RMSE_FACTOR: f64 = 3.0;

#[derive(Clone)]
struct Problem {
    hyp: SeArd,
    xd: Mat,
    y: Vec<f64>,
    xs: Mat,
    xu: Mat,
    /// noiseless target values at `xu` (held-out truth for RMSE)
    truth: Vec<f64>,
    d_blocks: Vec<Vec<usize>>,
    u_blocks: Vec<Vec<usize>>,
}

fn target(x: &[f64]) -> f64 {
    (1.3 * x[0]).sin() + (0.7 * x[1]).cos()
}

/// A problem with `per` training rows per machine drawn around a smooth
/// target, so held-out RMSE is meaningful.
fn problem(m: usize, per: usize, seed: u64) -> Problem {
    let d = 2;
    let n = m * per;
    let u = m * 3;
    let s = 6;
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(d, 0.9, 1.1, 0.1);
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let xs = Mat::from_vec(s, d, rng.normals(s * d));
    let xu = Mat::from_vec(u, d, rng.normals(u * d));
    let y: Vec<f64> =
        (0..n).map(|i| target(xd.row(i)) + 0.05 * rng.normal()).collect();
    let truth: Vec<f64> = (0..u).map(|i| target(xu.row(i))).collect();
    let d_blocks = random_partition(n, m, &mut rng);
    let u_blocks = random_partition(u, m, &mut rng);
    Problem { hyp, xd, y, xs, xu, truth, d_blocks, u_blocks }
}

fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let sse: f64 = pred.iter().zip(truth).map(|(p, t)| (p - t).powi(2)).sum();
    (sse / pred.len() as f64).sqrt()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Proto {
    PPitc,
    PPic,
    PIcf,
}

const PROTOS: [Proto; 3] = [Proto::PPitc, Proto::PPic, Proto::PIcf];

impl Proto {
    fn name(self) -> &'static str {
        match self {
            Proto::PPitc => "ppitc",
            Proto::PPic => "ppic",
            Proto::PIcf => "picf",
        }
    }

    /// Phases at which this protocol polls for scheduled deaths.
    fn kill_phases(self) -> &'static [&'static str] {
        match self {
            Proto::PPitc => &["local_summary", "global_summary", "predict"],
            Proto::PPic => {
                &["partition", "local_summary", "global_summary", "predict"]
            }
            Proto::PIcf => &["parallel_icf", "icf_local", "icf_global",
                             "icf_components", "finalize"],
        }
    }

    fn rank(self, p: &Problem) -> usize {
        (p.xd.rows / 2).max(1)
    }

    fn run_plain(self, p: &Problem, spec: &ClusterSpec) -> ProtocolOutput {
        match self {
            Proto::PPitc => ppitc::run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu,
                                       &p.d_blocks, &p.u_blocks,
                                       &NativeBackend, spec),
            Proto::PPic => ppic::run_with_partition(
                &p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks, &p.u_blocks,
                &NativeBackend, spec),
            Proto::PIcf => picf::run(&p.hyp, &p.xd, &p.y, &p.xu, &p.d_blocks,
                                     self.rank(p), &NativeBackend, spec),
        }
    }

    fn run_ft(self, p: &Problem, spec: &ClusterSpec)
              -> Result<FaultRun, MachinesLost> {
        match self {
            Proto::PPitc => ppitc::try_run(&p.hyp, &p.xd, &p.y, &p.xs, &p.xu,
                                           &p.d_blocks, &p.u_blocks,
                                           &NativeBackend, spec),
            Proto::PPic => ppic::try_run_with_partition(
                &p.hyp, &p.xd, &p.y, &p.xs, &p.xu, &p.d_blocks, &p.u_blocks,
                &NativeBackend, spec),
            Proto::PIcf => picf::try_run(&p.hyp, &p.xd, &p.y, &p.xu,
                                         &p.d_blocks, self.rank(p),
                                         &NativeBackend, spec),
        }
    }
}

/// Every data row owned by exactly one (surviving) machine.
fn assert_exact_coverage(tag: &str, d_blocks: &[Vec<usize>], n: usize) {
    let mut all: Vec<usize> =
        d_blocks.iter().flat_map(|b| b.iter().copied()).collect();
    all.sort_unstable();
    assert_eq!(all, (0..n).collect::<Vec<_>>(),
               "{tag}: blocks must cover every row exactly once");
}

/// Contract 1: with a zero plan, the fault-aware path reproduces the
/// direct path bit for bit — predictions, bytes and message counts —
/// at M ∈ {1, 4, 8}, and reports all-zero fault counters.
#[test]
fn zero_fault_transport_is_bitwise_identical() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 5, 1000 + m as u64);
        for proto in PROTOS {
            let tag = format!("{} m={m}", proto.name());
            let plain = proto.run_plain(&p, &ClusterSpec::new(m));
            let ft = proto
                .run_ft(&p, &ClusterSpec::new(m).with_faults(FaultPlan::none()))
                .unwrap_or_else(|e| panic!("{tag}: zero plan errored: {e}"));
            assert_eq!(bits(&plain.prediction.mean),
                       bits(&ft.output.prediction.mean), "{tag}: mean");
            assert_eq!(bits(&plain.prediction.var),
                       bits(&ft.output.prediction.var), "{tag}: var");
            assert_eq!(plain.metrics.bytes_sent, ft.output.metrics.bytes_sent,
                       "{tag}: bytes");
            assert_eq!(plain.metrics.messages, ft.output.metrics.messages,
                       "{tag}: messages");
            assert!(ft.output.metrics.faults.is_zero(),
                    "{tag}: zero plan must count no faults");
            assert_eq!(ft.survivors, (0..m).collect::<Vec<_>>(), "{tag}");
            assert_eq!(ft.d_blocks, p.d_blocks, "{tag}: ownership moved");
        }
    }
}

/// Contract 1 for the online path: absorb/predict through a zero-plan
/// fault transport matches the direct transport bitwise.
#[test]
fn zero_fault_online_is_bitwise_identical() {
    let m = 4;
    let per = 6;
    let d = 2;
    let mut rng = Pcg64::seed(4242);
    let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
    let xs = Mat::from_vec(4, d, rng.normals(4 * d));
    let batches: Vec<Vec<(Mat, Vec<f64>)>> = (0..3)
        .map(|_| {
            (0..m)
                .map(|_| {
                    (Mat::from_vec(per, d, rng.normals(per * d)),
                     rng.normals(per))
                })
                .collect()
        })
        .collect();
    let xu = Mat::from_vec(8, d, rng.normals(8 * d));
    let u_blocks = random_partition(8, m, &mut rng);

    let run = |spec: ClusterSpec| {
        let mut gp = OnlineGp::new(&hyp, &xs,
                                   std::sync::Arc::new(NativeBackend), spec);
        for b in &batches {
            gp.absorb(b);
        }
        gp.predict_ppitc(&xu, &u_blocks)
    };
    let direct = run(ClusterSpec::new(m));
    let fault = run(ClusterSpec::new(m).with_faults(FaultPlan::none()));
    assert_eq!(bits(&direct.prediction.mean), bits(&fault.prediction.mean));
    assert_eq!(bits(&direct.prediction.var), bits(&fault.prediction.var));
    assert_eq!(direct.metrics.bytes_sent, fault.metrics.bytes_sent);
    assert_eq!(direct.metrics.messages, fault.metrics.messages);
    assert!(fault.metrics.faults.is_zero());
}

/// Contract 2: a non-trivial chaos plan (drops + stragglers + one
/// scheduled death) replays bitwise — predictions, fault counters,
/// traffic, survivors and final ownership all identical across runs.
#[test]
fn chaos_runs_replay_bitwise() {
    let m = 4;
    let p = problem(m, 5, 77);
    for proto in PROTOS {
        let tag = proto.name();
        let kill_phase = proto.kill_phases()[1];
        // max_retries 6 keeps retry-exhaustion deaths out of this plan
        // (per-exchange death prob 0.15⁷ ≈ 2e-6) so the only death is
        // the scheduled one.
        let plan = FaultPlan::seeded(0xC4A05)
            .with_drops(0.15, 6)
            .with_stragglers(0.3, 1e-4)
            .with_timeout(1e-4, 2.0)
            .kill(2, kill_phase);
        let spec = ClusterSpec::new(m).with_faults(plan);
        let a = proto.run_ft(&p, &spec)
            .unwrap_or_else(|e| panic!("{tag}: run A errored: {e}"));
        let b = proto.run_ft(&p, &spec)
            .unwrap_or_else(|e| panic!("{tag}: run B errored: {e}"));
        assert_eq!(bits(&a.output.prediction.mean),
                   bits(&b.output.prediction.mean), "{tag}: mean");
        assert_eq!(bits(&a.output.prediction.var),
                   bits(&b.output.prediction.var), "{tag}: var");
        assert_eq!(a.output.metrics.faults, b.output.metrics.faults,
                   "{tag}: counters");
        assert_eq!(a.output.metrics.bytes_sent, b.output.metrics.bytes_sent,
                   "{tag}: bytes");
        assert_eq!(a.output.metrics.messages, b.output.metrics.messages,
                   "{tag}: messages");
        assert_eq!(a.survivors, b.survivors, "{tag}: survivors");
        assert_eq!(a.d_blocks, b.d_blocks, "{tag}: ownership");
        assert!(a.output.metrics.faults.deaths >= 1, "{tag}: death missing");
        assert!(!a.survivors.contains(&2), "{tag}: machine 2 must be dead");
    }
}

/// Contract 2, telemetry side: the same seeded chaos plan exports a
/// *bitwise-identical* deterministic telemetry snapshot on every
/// replay. Each replay records into a fresh scoped [`Registry`];
/// [`SnapshotMode::Deterministic`] drops measured time (span
/// timestamps, seconds-unit histograms) so what remains — counters,
/// span structure, traffic fields — must be a pure function of the
/// seed.
#[test]
fn chaos_telemetry_snapshot_replays_bitwise() {
    let m = 4;
    let p = problem(m, 5, 77);
    for proto in PROTOS {
        let tag = proto.name();
        let plan = || {
            FaultPlan::seeded(0xC4A05)
                .with_drops(0.15, 6)
                .with_stragglers(0.3, 1e-4)
                .with_timeout(1e-4, 2.0)
                .kill(2, proto.kill_phases()[1])
        };
        let replay = || {
            let reg = Arc::new(Registry::new());
            let _scope = reg.install();
            let spec = ClusterSpec::new(m).with_faults(plan());
            proto.run_ft(&p, &spec)
                .unwrap_or_else(|e| panic!("{tag}: replay errored: {e}"));
            reg.snapshot(SnapshotMode::Deterministic)
                .to_json()
                .to_string_compact()
        };
        let a = replay();
        let b = replay();
        assert_eq!(a, b, "{tag}: deterministic snapshots must be bitwise \
                          identical across replays");
        assert!(a.contains("\"phase."),
                "{tag}: snapshot missing phase spans: {a}");
        assert!(a.contains("cluster.faults.deaths"),
                "{tag}: snapshot missing death counter");
    }
}

/// Contract 3: killing a machine (worker or master) at each
/// death-polling phase still completes the run; the dead machine owns
/// nothing afterwards, survivors cover all data exactly once, and
/// held-out RMSE stays within the documented factor of fault-free.
#[test]
fn machine_death_at_each_phase_completes_with_coverage() {
    let m = 4;
    let p = problem(m, 5, 99);
    for proto in PROTOS {
        let base =
            proto.run_ft(&p, &ClusterSpec::new(m)
                .with_faults(FaultPlan::none()))
                .unwrap();
        let base_rmse = rmse(&base.output.prediction.mean, &p.truth);
        for &phase in proto.kill_phases() {
            for victim in [0usize, 1] {
                let tag = format!("{} kill {victim} at {phase}",
                                  proto.name());
                let plan = FaultPlan::seeded(5).kill(victim, phase);
                let fr = proto
                    .run_ft(&p, &ClusterSpec::new(m).with_faults(plan))
                    .unwrap_or_else(|e| panic!("{tag}: errored: {e}"));
                assert_eq!(fr.output.metrics.faults.deaths, 1, "{tag}");
                assert!(fr.output.metrics.faults.rebalances >= 1, "{tag}");
                assert_eq!(fr.survivors.len(), m - 1, "{tag}");
                assert!(!fr.survivors.contains(&victim), "{tag}");
                assert!(fr.d_blocks[victim].is_empty(),
                        "{tag}: dead machine still owns rows");
                assert_exact_coverage(&tag, &fr.d_blocks, p.xd.rows);
                let pred = &fr.output.prediction;
                assert_eq!(pred.len(), p.xu.rows, "{tag}");
                assert!(pred.mean.iter().all(|v| v.is_finite())
                            && pred.var.iter().all(|v| v.is_finite()),
                        "{tag}: non-finite prediction");
                let r = rmse(&pred.mean, &p.truth);
                assert!(r <= RMSE_FACTOR * base_rmse + 1e-6,
                        "{tag}: rmse {r} vs fault-free {base_rmse}");
            }
        }
    }
}

/// Contract 3, negative side: losing *every* machine is the typed
/// [`MachinesLost`] error naming the phase — never a panic.
#[test]
fn losing_every_machine_is_a_typed_error() {
    let m = 4;
    let p = problem(m, 5, 11);
    for proto in PROTOS {
        let phase = proto.kill_phases()[0];
        let mut plan = FaultPlan::none();
        for mid in 0..m {
            plan = plan.kill(mid, phase);
        }
        let err = proto
            .run_ft(&p, &ClusterSpec::new(m).with_faults(plan))
            .expect_err("all machines dead must error");
        assert_eq!(err.machines, m, "{}", proto.name());
        assert_eq!(err.phase, phase, "{}", proto.name());
    }
}

/// Contract 4: property-generated fault plans — arbitrary drops,
/// stragglers and deaths — always complete with sane invariants or
/// return the typed error. A watchdog converts any deadlock or
/// livelock into a test failure.
#[test]
fn random_fault_plans_complete_or_error() {
    let m = 4;
    let p = problem(m, 5, 333);
    prop_check("chaos-plans", 10, |g| {
        for proto in PROTOS {
            let plan = g.fault_plan(m, proto.kill_phases());
            let pc = p.clone();
            let case = g.case;
            let result = with_watchdog(Duration::from_secs(60), move || {
                proto.run_ft(&pc, &ClusterSpec::new(m).with_faults(plan))
            });
            match result {
                Ok(fr) => {
                    let tag = format!("{} case {case}", proto.name());
                    assert!(!fr.survivors.is_empty(), "{tag}");
                    assert_exact_coverage(&tag, &fr.d_blocks, p.xd.rows);
                    let pred = &fr.output.prediction;
                    assert_eq!(pred.len(), p.xu.rows, "{tag}");
                    assert!(pred.mean.iter().all(|v| v.is_finite()),
                            "{tag}: non-finite mean");
                }
                Err(e) => {
                    assert_eq!(e.machines, m);
                    assert!(!e.phase.is_empty());
                }
            }
        }
    });
}
