//! The distributed-training equivalence and gradient suite (ISSUE 3).
//!
//! * **Exactness** (training analogue of Theorem 1): the M-machine
//!   distributed NLML/gradient equals the single-machine centralized
//!   PITC evaluation to ≤1e-10, for M ∈ {1, 4, 8}, serial and
//!   thread-parallel — mirroring `integration_parallel_exec.rs`.
//! * **Gradient correctness**: the distributed analytic gradient
//!   matches central finite differences of the distributed value to
//!   ≤1e-5 relative error across the same machine counts.
//! * **End-to-end recovery**: distributed PITC training on a synthetic
//!   RFF ground-truth dataset improves held-out RMSE over the init and
//!   lands within 10% of the exact-subset-MLE baseline (the strict 5%
//!   gate at n≈8k runs in `cargo bench --bench train_bench`).

use pgpr::bench_support::workloads::{pitc_heldout_rmse, rff_recovery};
use pgpr::data::partition::random_partition;
use pgpr::gp::likelihood::{learn_hyperparameters, MleConfig};
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::ClusterSpec;
use pgpr::testkit::assert_all_close;
use pgpr::train::dist::{nlml_and_grad_dist, train_pitc};
use pgpr::train::nlml::pitc_nlml_and_grad;
use pgpr::train::optim::AdamConfig;
use pgpr::util::Pcg64;

const TOL: f64 = 1e-10;

struct Problem {
    hyp: SeArd,
    xd: Mat,
    y: Vec<f64>,
    xs: Mat,
    blocks: Vec<Vec<usize>>,
}

/// `per` training points per machine, centered outputs, fixed random
/// partition — sized so every M in {1, 4, 8} divides evenly.
fn problem(m: usize, per: usize, seed: u64) -> Problem {
    let d = 2;
    let n = m * per;
    let s = 6;
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd {
        log_ls: vec![0.15, -0.2],
        log_sf2: 0.2,
        log_sn2: -1.8,
    };
    let xd = Mat::from_vec(n, d, rng.normals(n * d));
    let xs = Mat::from_vec(s, d, rng.normals(s * d));
    let mut y = rng.normals(n);
    let mean = y.iter().sum::<f64>() / n as f64;
    for v in y.iter_mut() {
        *v -= mean;
    }
    let blocks = random_partition(n, m, &mut rng);
    Problem { hyp, xd, y, xs, blocks }
}

/// Training analogue of Theorem 1: distributed == centralized, ≤1e-10,
/// for every machine count and executor.
#[test]
fn distributed_nlml_equals_centralized() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 5, 500 + m as u64);
        let (want_v, want_g) =
            pitc_nlml_and_grad(&p.hyp, &p.xd, &p.y, &p.xs, &p.blocks);
        for threads in [0usize, 4, 8] {
            let spec = ClusterSpec::with_threads(m, threads);
            let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                        &p.blocks, &spec);
            let tag = format!("m={m} threads={threads}");
            assert!((ev.value - want_v).abs() <= TOL * want_v.abs().max(1.0),
                    "{tag}: value {} vs {}", ev.value, want_v);
            assert_all_close(&ev.grad, &want_g, TOL, TOL);
            assert!(ev.metrics.wall_s > 0.0, "{tag}: wall clock missing");
        }
    }
}

/// Thread-parallel execution reproduces the serial distributed run
/// exactly (pooled ≡ serial engine guarantee, end to end).
#[test]
fn thread_parallel_training_eval_matches_serial() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 6, 600 + m as u64);
        let serial = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                        &p.blocks, &ClusterSpec::new(m));
        for threads in [4usize, 8] {
            let par = nlml_and_grad_dist(
                &p.hyp, &p.xd, &p.y, &p.xs, &p.blocks,
                &ClusterSpec::with_threads(m, threads));
            assert_eq!(serial.value.to_bits(), par.value.to_bits(),
                       "m={m} threads={threads}: value drifted");
            assert_eq!(serial.grad, par.grad,
                       "m={m} threads={threads}: gradient drifted");
            // identical traffic model whatever the executor
            assert_eq!(serial.metrics.bytes_sent, par.metrics.bytes_sent);
            assert_eq!(serial.metrics.messages, par.metrics.messages);
        }
    }
}

/// Distributed analytic gradient vs central finite differences of the
/// distributed NLML value: relative error ≤ 1e-5 for M ∈ {1, 4, 8}.
#[test]
fn distributed_gradient_matches_finite_differences() {
    for m in [1usize, 4, 8] {
        let p = problem(m, 4, 700 + m as u64);
        let spec = ClusterSpec::new(m);
        let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs, &p.blocks,
                                    &spec);
        let theta = p.hyp.to_vec();
        let eps = 1e-6;
        for k in 0..theta.len() {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            let vp = nlml_and_grad_dist(&SeArd::from_vec(&tp), &p.xd, &p.y,
                                        &p.xs, &p.blocks, &spec)
                .value;
            let vm = nlml_and_grad_dist(&SeArd::from_vec(&tm), &p.xd, &p.y,
                                        &p.xs, &p.blocks, &spec)
                .value;
            let fd = (vp - vm) / (2.0 * eps);
            let err = (ev.grad[k] - fd).abs() / fd.abs().max(1e-2);
            assert!(err <= 1e-5,
                    "m={m} hyper {k}: analytic {} vs fd {fd} (rel {err:.2e})",
                    ev.grad[k]);
        }
    }
}

/// End-to-end: distributed PITC training on an RFF ground-truth
/// dataset recovers hypers that beat the init on held-out RMSE and sit
/// within 10% of the exact-subset-MLE baseline; the backtracking trace
/// is monotone.
#[test]
fn training_recovers_hyperparameters_end_to_end() {
    let m = 4usize;
    // the canonical recovery problem (same truth/init/support/partition
    // construction as `pgpr train --dataset rff` and train_bench)
    let r = rff_recovery(512, 128, 2, 48, m, 2024);

    let spec = ClusterSpec::with_threads(m, 4);
    let cfg = AdamConfig { iters: 25, backtrack: true, ..Default::default() };
    let trained = train_pitc(&r.init, &r.train.x, &r.train.y, &r.xs,
                             &r.d_blocks, &spec, &cfg);
    for w in trained.nlml_trace.windows(2) {
        assert!(w[1] <= w[0] + 1e-12, "NLML increased: {w:?}");
    }
    assert!(*trained.nlml_trace.last().unwrap() < trained.nlml_trace[0],
            "training made no NLML progress");

    let mle_cfg = MleConfig { iters: 25, subset: 256, seed: 5,
                              ..Default::default() };
    let mle = learn_hyperparameters(&r.init, &r.train.x, &r.train.y,
                                    &mle_cfg);

    let lctx = spec.exec.linalg_ctx();
    let heldout = |hyp: &SeArd| -> f64 {
        pitc_heldout_rmse(&lctx, hyp, &r.train, &r.test, &r.xs, &r.d_blocks)
    };
    let r_init = heldout(&r.init);
    let r_dist = heldout(&trained.hyp);
    let r_mle = heldout(&mle.hyp);
    eprintln!("held-out RMSE: init {r_init:.4}, distributed {r_dist:.4}, \
               exact-subset {r_mle:.4}");
    assert!(r_dist < r_init,
            "training did not improve held-out RMSE: {r_dist} vs {r_init}");
    assert!(r_dist <= 1.10 * r_mle,
            "distributed-PITC hypers more than 10% behind exact-subset: \
             {r_dist} vs {r_mle}");

    // the per-iteration message is the paper-shaped O(|S|²) payload
    let s2 = r.xs.rows * r.xs.rows;
    assert!(trained.bytes_per_eval >= 8 * s2 * (m - 1),
            "comm below the O(|S|^2) floor");
    assert!(trained.bytes_per_eval <= 8 * (6 * s2) * (m - 1),
            "comm above the O(|S|^2) envelope");
}
