//! Integration: the PJRT backend (AOT artifacts) must agree with the
//! native backend through the *full protocols*, not just per-op — this
//! is the three-layer composition guarantee.
//!
//! Requires `make artifacts` (the Makefile runs it before cargo test).

use pgpr::data::partition::random_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec};
use pgpr::runtime::{ArtifactManifest, NativeBackend, PjrtBackend};
use pgpr::testkit::assert_all_close;
use pgpr::util::Pcg64;

fn load_tiny() -> Option<PjrtBackend> {
    let dir = pgpr::runtime::artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = ArtifactManifest::load(dir).expect("manifest");
    match PjrtBackend::load(&manifest, "tiny") {
        Ok(b) => Some(b),
        // without the `pjrt` feature the stub's load always errors: skip.
        // WITH the feature a load failure is a real regression
        // (corrupt/incompatible artifacts) and must fail loudly.
        Err(e) if cfg!(not(feature = "pjrt")) => {
            eprintln!("skipping: pjrt backend unavailable: {e}");
            None
        }
        Err(e) => panic!("pjrt tiny failed to load: {e:#}"),
    }
}

struct Problem {
    hyp: SeArd,
    xd: Mat,
    y: Vec<f64>,
    xs: Mat,
    xu: Mat,
    d_blocks: Vec<Vec<usize>>,
    u_blocks: Vec<Vec<usize>>,
    m: usize,
    rank: usize,
}

/// Build a problem whose shapes match the tiny profile exactly
/// (B=32, S=16, U=24 per machine).
fn tiny_problem(pjrt: &PjrtBackend, m: usize, seed: u64) -> Problem {
    let p = &pjrt.profile;
    let mut rng = Pcg64::seed(seed);
    let n = p.block * m;
    let u = p.pred_block * m;
    let hyp = SeArd::isotropic(p.d, 1.0, 1.2, 0.05);
    let xd = Mat::from_vec(n, p.d, rng.normals(n * p.d));
    let y = rng.normals(n);
    let xs = Mat::from_vec(p.support, p.d, rng.normals(p.support * p.d));
    let xu = Mat::from_vec(u, p.d, rng.normals(u * p.d));
    let d_blocks = random_partition(n, m, &mut rng);
    let u_blocks = random_partition(u, m, &mut rng);
    Problem { hyp, xd, y, xs, xu, d_blocks, u_blocks, m, rank: p.rank }
}

#[test]
fn ppitc_protocol_native_equals_pjrt() {
    let Some(pjrt) = load_tiny() else { return };
    let pb = tiny_problem(&pjrt, 3, 1);
    let spec = ClusterSpec::new(pb.m);
    let a = ppitc::run(&pb.hyp, &pb.xd, &pb.y, &pb.xs, &pb.xu,
                       &pb.d_blocks, &pb.u_blocks, &NativeBackend, &spec);
    let b = ppitc::run(&pb.hyp, &pb.xd, &pb.y, &pb.xs, &pb.xu,
                       &pb.d_blocks, &pb.u_blocks, &pjrt, &spec);
    assert_all_close(&a.prediction.mean, &b.prediction.mean, 1e-9, 1e-9);
    assert_all_close(&a.prediction.var, &b.prediction.var, 1e-9, 1e-9);
}

#[test]
fn ppic_protocol_native_equals_pjrt() {
    let Some(pjrt) = load_tiny() else { return };
    let pb = tiny_problem(&pjrt, 2, 2);
    let spec = ClusterSpec::new(pb.m);
    let a = ppic::run_with_partition(&pb.hyp, &pb.xd, &pb.y, &pb.xs, &pb.xu,
                                     &pb.d_blocks, &pb.u_blocks,
                                     &NativeBackend, &spec);
    let b = ppic::run_with_partition(&pb.hyp, &pb.xd, &pb.y, &pb.xs, &pb.xu,
                                     &pb.d_blocks, &pb.u_blocks, &pjrt, &spec);
    assert_all_close(&a.prediction.mean, &b.prediction.mean, 1e-9, 1e-9);
    assert_all_close(&a.prediction.var, &b.prediction.var, 1e-9, 1e-9);
}

#[test]
fn picf_protocol_native_equals_pjrt() {
    let Some(pjrt) = load_tiny() else { return };
    // pICF's icf_local graph expects xu of pred_block rows and F of
    // rank x block: single machine keeps the shapes exact.
    let pb = tiny_problem(&pjrt, 1, 3);
    let spec = ClusterSpec::new(pb.m);
    let a = picf::run(&pb.hyp, &pb.xd, &pb.y, &pb.xu, &pb.d_blocks, pb.rank,
                      &NativeBackend, &spec);
    let b = picf::run(&pb.hyp, &pb.xd, &pb.y, &pb.xu, &pb.d_blocks, pb.rank,
                      &pjrt, &spec);
    assert_all_close(&a.prediction.mean, &b.prediction.mean, 1e-8, 1e-8);
    assert_all_close(&a.prediction.var, &b.prediction.var, 1e-8, 1e-8);
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let Some(pjrt) = load_tiny() else { return };
    use pgpr::runtime::Backend;
    let p = pjrt.profile.clone();
    let mut rng = Pcg64::seed(4);
    let hyp = SeArd::isotropic(p.d, 1.0, 1.0, 0.1);
    // wrong block size must panic with a shape message, not corrupt
    let xm = Mat::from_vec(p.block + 1, p.d, rng.normals((p.block + 1) * p.d));
    let ym = rng.normals(p.block + 1);
    let xs = Mat::from_vec(p.support, p.d, rng.normals(p.support * p.d));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pjrt.local_summary(&hyp, &xm, &ym, &xs)
    }));
    assert!(result.is_err());
}
