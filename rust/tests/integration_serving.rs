//! Integration: the serving stack (router + batcher + served model) over
//! both backends — responses must match the direct protocol predictions
//! and the two backends must agree request-by-request.

use pgpr::data::partition::random_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::runtime::{ArtifactManifest, Backend, NativeBackend, PjrtBackend};
use pgpr::server::{DynamicBatcher, PredictRequest, ServedModel};
use pgpr::util::Pcg64;

fn load_tiny() -> Option<PjrtBackend> {
    let dir = pgpr::runtime::artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = ArtifactManifest::load(dir).expect("manifest");
    match PjrtBackend::load(&manifest, "tiny") {
        Ok(b) => Some(b),
        // without the `pjrt` feature the stub's load always errors: skip.
        // WITH the feature a load failure is a real regression
        // (corrupt/incompatible artifacts) and must fail loudly.
        Err(e) if cfg!(not(feature = "pjrt")) => {
            eprintln!("skipping: pjrt backend unavailable: {e}");
            None
        }
        Err(e) => panic!("pjrt tiny failed to load: {e:#}"),
    }
}

#[test]
fn serving_pjrt_equals_native_per_request() {
    let Some(pjrt) = load_tiny() else { return };
    let p = pjrt.profile.clone();
    let m = 2;
    let n = p.block * m;
    let mut rng = Pcg64::seed(31);
    let hyp = SeArd::isotropic(p.d, 1.0, 1.0, 0.05);
    let xd = Mat::from_vec(n, p.d, rng.normals(n * p.d));
    let y = rng.normals(n);
    let xs = Mat::from_vec(p.support, p.d, rng.normals(p.support * p.d));
    let d_blocks = random_partition(n, m, &mut rng);

    // fit through native (fitting path is identical math; mixing proves
    // state compatibility across backends)
    let model = ServedModel::fit(&hyp, &xd, &y, &xs, &d_blocks,
                                 &NativeBackend)
        .expect("serving fit");

    let requests: Vec<PredictRequest> = (0..30)
        .map(|i| PredictRequest {
            id: i as u64,
            x: rng.normals(p.d),
            arrival_s: i as f64 * 1e-4,
        })
        .collect();

    let run = |backend: &dyn Backend| {
        let mut batcher =
            DynamicBatcher::new(m, p.d, p.pred_block, 1e-3);
        model.serve(backend, &requests, &mut batcher)
    };
    let rep_native = run(&NativeBackend);
    let rep_pjrt = run(&pjrt);
    assert_eq!(rep_native.responses.len(), rep_pjrt.responses.len());
    for (a, b) in rep_native.responses.iter().zip(rep_pjrt.responses.iter()) {
        assert_eq!(a.id, b.id);
        assert!((a.mean - b.mean).abs() < 1e-9,
                "req {}: {} vs {}", a.id, a.mean, b.mean);
        assert!((a.var - b.var).abs() < 1e-9);
    }
}

#[test]
fn served_predictions_match_protocol_math() {
    let Some(pjrt) = load_tiny() else { return };
    let p = pjrt.profile.clone();
    let m = 2;
    let n = p.block * m;
    let mut rng = Pcg64::seed(32);
    let hyp = SeArd::isotropic(p.d, 0.9, 1.1, 0.05);
    let xd = Mat::from_vec(n, p.d, rng.normals(n * p.d));
    let y = rng.normals(n);
    let xs = Mat::from_vec(p.support, p.d, rng.normals(p.support * p.d));
    let d_blocks = random_partition(n, m, &mut rng);
    let model = ServedModel::fit(&hyp, &xd, &y, &xs, &d_blocks, &pjrt)
        .expect("serving fit");

    // one query through serve() vs the direct backend call
    let q: Vec<f64> = rng.normals(p.d);
    let machine = model.router.route(&q);
    let (mean, var) = model.predict_batch(&pjrt, machine, &q, 1, p.pred_block);

    let requests = vec![PredictRequest { id: 0, x: q, arrival_s: 0.0 }];
    let mut batcher = DynamicBatcher::new(m, p.d, p.pred_block, 1e-6);
    let report = model.serve(&pjrt, &requests, &mut batcher);
    assert!((report.responses[0].mean - mean[0]).abs() < 1e-12);
    assert!((report.responses[0].var - var[0]).abs() < 1e-12);
}
