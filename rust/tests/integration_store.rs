//! Integration: durable model state — `store/` checkpoints end to end.
//!
//! Pins the PR's durability contract:
//! * **Round-trip**: every `api::Method` (7 batch + online) at
//!   M ∈ {1, 4, 8} saves, loads, and predicts bitwise what the original
//!   predicted — and re-serializing the loaded model reproduces the
//!   on-disk image byte for byte (checkpoints are deterministic).
//! * **Crash recovery**: an online session checkpointed mid-stream and
//!   restored "in a new process" continues bitwise-identically to a run
//!   that was never interrupted.
//! * **Hot-swap under live traffic**: a `pgpr node` snapshots and
//!   reloads while predicts stream in; every admitted request is
//!   answered, every answer matches exactly one model, and the swap is
//!   visible in `/healthz`.
//! * **Corruption**: bit flips, truncations, wrong magic, future
//!   versions, unknown tags and family mismatches all come back as
//!   typed `StoreError`s — never a panic.

use std::time::Duration;

use pgpr::api::{ApiError, Gp, Method, OnlineSession, PredictSpec,
                Regressor};
use pgpr::kernel::SeArd;
use pgpr::linalg::{LinalgCtx, Mat};
use pgpr::net::loadgen::HttpClient;
use pgpr::net::{NodeConfig, NodeServer};
use pgpr::server::{ServeScratch, ServedModel};
use pgpr::store::{crc32, Checkpoint, StoreError, FORMAT_VERSION};
use pgpr::util::json::{self, Json};
use pgpr::util::Pcg64;

const D: usize = 2;

fn problem(n: usize, seed: u64) -> (SeArd, Mat, Vec<f64>, Mat, Mat) {
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(D, 0.9, 1.0, 0.08);
    let xd = Mat::from_vec(n, D, rng.normals(n * D));
    let y = rng.normals(n);
    let xs = Mat::from_vec(6, D, rng.normals(6 * D));
    let xu = Mat::from_vec(5, D, rng.normals(5 * D));
    (hyp, xd, y, xs, xu)
}

/// Deterministic served model — two builds with the same knobs are
/// bitwise-identical (pinned by `service.rs` tests).
fn served_model(n: usize, m: usize, s: usize, seed: u64) -> ServedModel {
    let mut rng = Pcg64::seed(seed);
    let hyp = SeArd::isotropic(D, 1.0, 1.0, 0.05);
    let xd = Mat::from_vec(n, D, rng.normals(n * D));
    let y = rng.normals(n);
    Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(s)
        .seed(seed)
        .serve()
        .expect("fit")
}

fn tmp(name: &str) -> String {
    std::env::temp_dir().join(name).to_str().unwrap().to_string()
}

fn predict_body(x: &[f64]) -> String {
    json::obj(vec![(
        "x",
        Json::Arr(x.iter().map(|&v| Json::Num(v)).collect()),
    )])
    .to_string_compact()
}

// ---------------------------------------------------------------------

/// Save → load → predict is bitwise-identical for every batch method at
/// every machine count, and the loaded model re-serializes to the exact
/// on-disk bytes.
#[test]
fn roundtrip_pins_every_batch_method() {
    let (hyp, xd, y, xs, xu) = problem(24, 3);
    for m in [1usize, 4, 8] {
        for method in Method::ALL {
            let gp = Gp::builder()
                .method(method)
                .hyp(hyp.clone())
                .data(xd.clone(), y.clone())
                .machines(m)
                .support(xs.clone())
                .rank(12)
                .seed(5)
                .fit()
                .unwrap();
            let want = gp.predict(&xu).unwrap();
            let bytes0 = gp.checkpoint().unwrap().encode();

            let path =
                tmp(&format!("pgpr_store_rt_{}_{m}.bin", method.name()));
            let written = gp.save(&path).unwrap();
            let on_disk = std::fs::read(&path).unwrap();
            assert_eq!(written as usize, on_disk.len());
            assert_eq!(on_disk, bytes0,
                       "{} M={m}: file differs from encode()",
                       method.name());

            let loaded = Gp::load(&path).unwrap();
            assert_eq!(loaded.method(), method);
            let got = loaded.predict(&xu).unwrap();
            assert_eq!(got.mean, want.mean, "{} M={m} mean",
                       method.name());
            assert_eq!(got.var, want.var, "{} M={m} var", method.name());

            // the loaded model's own checkpoint is the same image
            let bytes1 = loaded.checkpoint().unwrap().encode();
            assert_eq!(bytes1, bytes0,
                       "{} M={m}: re-serialization drifted",
                       method.name());
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// The online session round-trips too: absorb a batch, save through the
/// `Regressor` trait, reload through the facade, predict bitwise.
#[test]
fn roundtrip_pins_online_session() {
    let (hyp, xd, y, xs, xu) = problem(24, 13);
    for m in [1usize, 4, 8] {
        let mut sess = Gp::builder()
            .hyp(hyp.clone())
            .data(xd.clone(), y.clone())
            .machines(m)
            .support(xs.clone())
            .seed(13)
            .online()
            .unwrap();
        let mut rng = Pcg64::seed(29 + m as u64);
        let batch: Vec<(Mat, Vec<f64>)> = (0..m)
            .map(|_| (Mat::from_vec(3, D, rng.normals(3 * D)),
                      rng.normals(3)))
            .collect();
        sess.absorb(&batch).unwrap();
        let want = sess.predict(&PredictSpec::new(xu.clone())).unwrap();

        let path = tmp(&format!("pgpr_store_rt_online_{m}.bin"));
        sess.save(&path).unwrap();
        let bytes0 = sess.checkpoint().unwrap().encode();
        assert_eq!(std::fs::read(&path).unwrap(), bytes0);

        let loaded = Gp::load(&path).unwrap();
        assert_eq!(loaded.method(), Method::Online);
        assert_eq!(loaded.machines(), m);
        let got = loaded.predict(&xu).unwrap();
        assert_eq!(got.mean, want.mean, "online M={m} mean");
        assert_eq!(got.var, want.var, "online M={m} var");
        assert_eq!(loaded.checkpoint().unwrap().encode(), bytes0,
                   "online M={m}: re-serialization drifted");
        let _ = std::fs::remove_file(&path);
    }
}

/// §5.2 crash recovery: checkpoint an online session mid-stream, drop
/// it ("the process dies"), restore from bytes alone, stream the rest —
/// predictions and the final checkpoint are bitwise those of a run that
/// was never interrupted.
#[test]
fn online_midstream_restore_matches_uninterrupted_run() {
    let (hyp, xd, y, xs, xu) = problem(16, 7);
    let m = 2;
    let b = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support(xs)
        .seed(7);
    // one fixed stream of four batch rounds, replayed on both paths
    let mut rng = Pcg64::seed(41);
    let rounds: Vec<Vec<(Mat, Vec<f64>)>> = (0..4)
        .map(|_| {
            (0..m)
                .map(|_| (Mat::from_vec(3, D, rng.normals(3 * D)),
                          rng.normals(3)))
                .collect()
        })
        .collect();

    let mut straight = b.online().unwrap();
    for round in &rounds {
        straight.absorb(round).unwrap();
    }

    let mut first = b.online().unwrap();
    for round in &rounds[..2] {
        first.absorb(round).unwrap();
    }
    let bytes = first.checkpoint().unwrap().encode();
    drop(first); // the crash: nothing survives but the bytes

    let ck = match Checkpoint::decode(&bytes).unwrap() {
        Checkpoint::Online(o) => o,
        other => panic!("wrong family {}", other.method_name()),
    };
    let mut resumed = OnlineSession::from_checkpoint(ck).unwrap();
    assert_eq!(resumed.batches(), 3); // fit batch + two absorbed
    for round in &rounds[2..] {
        resumed.absorb(round).unwrap();
    }
    assert_eq!(resumed.batches(), straight.batches());

    let ps = PredictSpec::new(xu);
    let want = straight.predict(&ps).unwrap();
    let got = resumed.predict(&ps).unwrap();
    assert_eq!(got.mean, want.mean);
    assert_eq!(got.var, want.var);
    // even the durable state re-converges byte for byte
    assert_eq!(resumed.checkpoint().unwrap().encode(),
               straight.checkpoint().unwrap().encode());
}

/// Hot-swap under live traffic: `POST /v1/admin/snapshot` then
/// `/v1/admin/reload` while predicts stream in. Every admitted request
/// is answered (200, or 503 inside the restore window — never dropped),
/// every answer is bitwise one model's, and `/healthz` reports the swap
/// with the new model's version hash.
#[test]
fn node_snapshot_reload_hot_swap_under_live_traffic() {
    let p = tmp("pgpr_store_node_ck.bin");
    let _ = std::fs::remove_file(&p);
    let twin = served_model(48, 3, 8, 17);
    let cfg = NodeConfig {
        workers: 4,
        read_timeout_s: 0.25,
        idle_close_s: 1.0,
        deadline_s: 5.0,
        checkpoint_path: Some(p.clone()),
        ..NodeConfig::default()
    };
    let h = NodeServer::start(served_model(48, 3, 8, 17),
                              "127.0.0.1:0", cfg)
        .expect("bind");
    let t = h.addr().to_string();

    let (answers, _shed) = std::thread::scope(|s| {
        let t2 = &t;
        let traffic = s.spawn(move || {
            let mut rng = Pcg64::seed(71);
            let mut c = HttpClient::connect(t2, 10.0).unwrap();
            let mut answers = Vec::new();
            let mut shed = 0u32;
            for _ in 0..150 {
                let x = rng.normals(D);
                let (status, resp) = c
                    .post("/v1/predict", predict_body(&x).as_bytes())
                    .unwrap();
                match status {
                    200 => {
                        let doc = Json::parse(
                            std::str::from_utf8(&resp).unwrap())
                            .unwrap();
                        let mean = doc.get("mean")
                            .and_then(Json::as_f64).unwrap();
                        let var = doc.get("var")
                            .and_then(Json::as_f64).unwrap();
                        answers.push((x, mean, var));
                    }
                    503 => shed += 1, // restore window: shed, not dropped
                    other => panic!("unexpected status {other}"),
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            (answers, shed)
        });
        std::thread::sleep(Duration::from_millis(30));
        let mut admin = HttpClient::connect(&t, 30.0).unwrap();

        let (status, body) = admin.post("/v1/admin/snapshot", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(doc.get("bytes").and_then(Json::as_usize).unwrap() > 0);
        // snapshots are deterministic across processes: the on-disk
        // image is bitwise the local twin's encoding
        assert_eq!(std::fs::read(&p).unwrap(),
                   twin.to_checkpoint().encode());

        let (status, body) = admin.post("/v1/admin/reload", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(doc.get("machines").and_then(Json::as_usize), Some(3));

        traffic.join().unwrap()
    });

    // every answered request matches the one model, bitwise — no
    // response came from a half-swapped state
    assert!(!answers.is_empty(), "no request was answered");
    let lctx = LinalgCtx::serial();
    let mut scratch = ServeScratch::new();
    for (x, mean, var) in &answers {
        let m = twin.router.route(x);
        let (mv, vv) =
            twin.predict_batch_fast(m, x, 1, 1, &lctx, &mut scratch);
        assert_eq!(mean.to_bits(), mv[0].to_bits());
        assert_eq!(var.to_bits(), vv[0].to_bits());
    }

    // the swap is visible in /healthz with the new model's identity
    let mut c = HttpClient::connect(&t, 10.0).unwrap();
    let doc = c.get_json("/healthz").unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(doc.get("method").and_then(Json::as_str), Some("served"));
    assert_eq!(doc.get("swaps").and_then(Json::as_usize), Some(1));
    let vh = doc.get("model_version").and_then(Json::as_str).unwrap();
    assert_eq!(vh.len(), 8, "model_version {vh:?} not 8 hex digits");
    assert_eq!(u32::from_str_radix(vh, 16).unwrap(),
               twin.to_checkpoint().version_hash());

    h.shutdown_and_join();
    let _ = std::fs::remove_file(&p);
}

/// Corrupt input is a typed error, never a panic: every single-bit flip
/// and every truncation of a valid image is rejected, and each header
/// field failure names itself.
#[test]
fn corrupt_checkpoints_fail_typed_never_panic() {
    let (hyp, xd, y, xs, _xu) = problem(16, 11);
    let gp = Gp::builder()
        .method(Method::PPitc)
        .hyp(hyp)
        .data(xd, y)
        .machines(2)
        .support(xs)
        .seed(11)
        .fit()
        .unwrap();
    let good = gp.checkpoint().unwrap().encode();
    assert!(Checkpoint::decode(&good).is_ok());

    // single-bit flips anywhere in the image: the CRC (or an earlier
    // header check) catches every one
    for i in 0..good.len() {
        for bit in [0x01u8, 0x80] {
            let mut bad = good.clone();
            bad[i] ^= bit;
            let err = Checkpoint::decode(&bad).expect_err(
                &format!("flip of byte {i} (mask {bit:#x}) accepted"));
            assert!(
                matches!(err,
                         StoreError::BadMagic
                         | StoreError::UnsupportedVersion { .. }
                         | StoreError::Checksum { .. }),
                "flip of byte {i}: unexpected error {err:?}"
            );
        }
    }

    // truncation at every prefix length
    for len in 0..good.len() {
        assert!(Checkpoint::decode(&good[..len]).is_err(),
                "truncation to {len} bytes accepted");
    }

    // restamp the trailing CRC so only the field under test is at fault
    fn restamp(bytes: &mut [u8]) {
        let n = bytes.len();
        let c = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&c.to_le_bytes());
    }

    let mut bad = good.clone();
    bad[0] = b'X';
    restamp(&mut bad);
    assert_eq!(Checkpoint::decode(&bad).unwrap_err(),
               StoreError::BadMagic);

    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&9u32.to_le_bytes());
    restamp(&mut bad);
    assert_eq!(Checkpoint::decode(&bad).unwrap_err(),
               StoreError::UnsupportedVersion {
                   found: 9,
                   supported: FORMAT_VERSION,
               });

    let mut bad = good.clone();
    bad[12] = 0xEE;
    restamp(&mut bad);
    assert_eq!(Checkpoint::decode(&bad).unwrap_err(),
               StoreError::UnknownMethodTag(0xEE));
}

/// Family mismatches are typed at both doors: a batch checkpoint won't
/// load as a served model, and a served checkpoint won't load through
/// the facade.
#[test]
fn family_mismatch_is_typed_at_both_doors() {
    let (hyp, xd, y, xs, _xu) = problem(16, 19);
    let gp = Gp::builder()
        .method(Method::PPitc)
        .hyp(hyp)
        .data(xd, y)
        .machines(2)
        .support(xs)
        .seed(19)
        .fit()
        .unwrap();

    let p = tmp("pgpr_store_family_batch.bin");
    gp.save(&p).unwrap();
    let Err(err) = ServedModel::load(&p) else {
        panic!("served load accepted a batch checkpoint");
    };
    assert_eq!(err,
               ApiError::Store(StoreError::MethodMismatch {
                   expected: "served",
                   found: "pPITC",
               }));
    let _ = std::fs::remove_file(&p);

    let p = tmp("pgpr_store_family_served.bin");
    served_model(32, 2, 6, 23).save(&p).unwrap();
    let Err(err) = Gp::load(&p) else {
        panic!("facade load accepted a served checkpoint");
    };
    assert_eq!(err,
               ApiError::Store(StoreError::MethodMismatch {
                   expected: "an api::Method model",
                   found: "served",
               }));
    let _ = std::fs::remove_file(&p);
}
