//! Regenerates Fig. 2 (a–h): metrics vs machine count M ∈ {4..20} at
//! fixed |D|=2000 (paper 32000), both domains.
//!
//!     cargo bench --bench fig2_vary_machines

use pgpr::bench_support::figures::{fig2, Scale};
use pgpr::bench_support::workloads::Domain;

fn main() {
    let scale = Scale::parse(
        &std::env::var("PGPR_BENCH_SCALE").unwrap_or_else(|_| "small".into()),
    )
    .expect("PGPR_BENCH_SCALE must be small|paper");
    let threads = pgpr::bench_support::threads_from_env();
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        let t = fig2(domain, scale, 1, threads);
        println!("{}", t.render());
    }
}
