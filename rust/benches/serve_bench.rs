//! Serving-layer sweep → `BENCH_serve.json` (per-batch predict
//! latency p50/p99 + qps, old solve-based path vs fit-staged operator
//! fast path, batch sizes × threads × |S|).
//!
//!     cargo bench --bench serve_bench                  # full sweep + gate
//!     PGPR_SERVE_SMOKE=1 cargo bench --bench serve_bench     # CI smoke
//!     cargo bench --bench serve_bench -- out.json      # custom output
//!
//! `PGPR_LENIENT_PERF=1` downgrades the ≥3× perf gate to advisory on
//! oversubscribed hosts (same convention as the other sweeps).
//! `--telemetry-out=PATH` (or `PGPR_TELEMETRY_OUT`) additionally
//! writes the run's full telemetry snapshot as JSON.

use pgpr::bench_support::serve_bench::{run, ServeBenchConfig};

fn main() {
    // skip cargo-bench's --bench flag if present; first real arg = path
    let out = pgpr::cli::args::process_out_path("BENCH_serve.json");
    let telemetry_out = pgpr::bench_support::telemetry_out_from_args();
    if telemetry_out.is_some() {
        pgpr::obsv::set_enabled(true);
    }
    let cfg = ServeBenchConfig::from_env();
    run(&cfg, &out);
    if let Some(p) = telemetry_out {
        pgpr::bench_support::write_telemetry_snapshot(&p);
    }
}
