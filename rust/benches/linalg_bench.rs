//! Blocked-engine sweep → `BENCH_linalg.json` (kernels × sizes ×
//! threads, GFLOP/s + wall seconds, vs the seed scalar baselines).
//!
//!     cargo bench --bench linalg_bench                 # full sweep + gates
//!     PGPR_LINALG_SMOKE=1 cargo bench --bench linalg_bench   # CI smoke
//!     cargo bench --bench linalg_bench -- out.json     # custom output
//!
//! `PGPR_LENIENT_PERF=1` downgrades the perf gates to advisory on
//! oversubscribed hosts (same convention as the integration suite).
//! `--telemetry-out=PATH` (or `PGPR_TELEMETRY_OUT`) additionally
//! writes the run's full telemetry snapshot as JSON.

use pgpr::bench_support::linalg_bench::{run, LinalgBenchConfig};

fn main() {
    // skip cargo-bench's --bench flag if present; first real arg = path
    let out = pgpr::cli::args::process_out_path("BENCH_linalg.json");
    let telemetry_out = pgpr::bench_support::telemetry_out_from_args();
    if telemetry_out.is_some() {
        pgpr::obsv::set_enabled(true);
    }
    let cfg = LinalgBenchConfig::from_env();
    run(&cfg, &out);
    if let Some(p) = telemetry_out {
        pgpr::bench_support::write_telemetry_snapshot(&p);
    }
}
