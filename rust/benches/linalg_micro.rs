//! Micro-benchmarks of the native linear-algebra hot paths (the inputs
//! to the §Perf optimization loop): Gram construction, matmul variants,
//! Cholesky, triangular solves, ICF, and the per-block summary ops.
//!
//!     cargo bench --bench linalg_micro

use pgpr::bench_support::harness::bench_fn;
use pgpr::gp::summaries::{local_summary, SupportContext};
use pgpr::gp::icf_gp::GramSource;
use pgpr::kernel::SeArd;
use pgpr::linalg::{cho_solve_mat, cholesky, icf, matmul, matmul_nt,
                   matmul_tn, Mat};
use pgpr::util::Pcg64;

fn main() {
    let mut rng = Pcg64::seed(1);
    let budget = 1.0; // seconds per case

    // dense products at summary-typical shapes
    for n in [128usize, 256, 512] {
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let b = Mat::from_vec(n, n, rng.normals(n * n));
        println!("{}", bench_fn(&format!("matmul {n}x{n}"), 50, budget,
                                || { let _ = matmul(&a, &b); }).report());
        println!("{}", bench_fn(&format!("matmul_tn {n}x{n}"), 50, budget,
                                || { let _ = matmul_tn(&a, &b); }).report());
        println!("{}", bench_fn(&format!("matmul_nt {n}x{n}"), 50, budget,
                                || { let _ = matmul_nt(&a, &b); }).report());
    }

    // SPD factorizations
    for n in [128usize, 256, 512] {
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let mut spd = matmul_nt(&a, &a);
        spd.add_diag(n as f64);
        println!("{}", bench_fn(&format!("cholesky {n}"), 50, budget,
                                || { let _ = cholesky(&spd).unwrap(); })
                 .report());
        let l = cholesky(&spd).unwrap();
        let rhs = Mat::from_vec(n, 64, rng.normals(n * 64));
        println!("{}", bench_fn(&format!("cho_solve_mat {n}x64"), 50, budget,
                                || { let _ = cho_solve_mat(&l, &rhs); })
                 .report());
    }

    // Gram matrix (the L1 kernel's native mirror)
    let hyp5 = SeArd::isotropic(5, 1.2, 1.0, 0.1);
    let hyp21 = SeArd::isotropic(21, 2.0, 1.0, 0.1);
    for (d, hyp) in [(5usize, &hyp5), (21usize, &hyp21)] {
        let x1 = Mat::from_vec(512, d, rng.normals(512 * d));
        let x2 = Mat::from_vec(512, d, rng.normals(512 * d));
        println!("{}", bench_fn(&format!("se_gram 512x512 d={d}"), 50, budget,
                                || { let _ = hyp.gram(&x1, &x2); }).report());
    }

    // pivoted ICF at serving-typical rank
    let xd = Mat::from_vec(1024, 5, rng.normals(1024 * 5));
    let src = GramSource { hyp: &hyp5, x: &xd };
    println!("{}", bench_fn("icf n=1024 R=128", 20, budget,
                            || { let _ = icf(&src, 128, 0.0); }).report());

    // the per-machine local summary (dominant protocol op)
    for (b, s) in [(100usize, 64usize), (200, 128)] {
        let xm = Mat::from_vec(b, 5, rng.normals(b * 5));
        let xs = Mat::from_vec(s, 5, rng.normals(s * 5));
        let ym = rng.normals(b);
        let ctx = SupportContext::new(&hyp5, &xs);
        println!("{}", bench_fn(&format!("local_summary B={b} S={s}"), 50,
                                budget,
                                || { let _ = local_summary(&hyp5, &xm, &ym,
                                                           &ctx); })
                 .report());
    }
}
