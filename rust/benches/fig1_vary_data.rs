//! Regenerates Fig. 1 (a–h): RMSE / MNLP / incurred time / speedup vs
//! data size |D| ∈ {500,1000,1500,2000} (paper: 8k–32k), M=20,
//! |S|=64 (paper 2048), R=64/128 (paper 2048/4096), both domains.
//!
//!     cargo bench --bench fig1_vary_data
//!
//! Scale selection: PGPR_BENCH_SCALE=small|paper (default small; see
//! DESIGN.md §Substitutions for the scaling rationale).
//! PGPR_BENCH_THREADS=N executes machine work on N real host threads.

use pgpr::bench_support::figures::{fig1, Scale};
use pgpr::bench_support::workloads::Domain;

fn main() {
    let scale = Scale::parse(
        &std::env::var("PGPR_BENCH_SCALE").unwrap_or_else(|_| "small".into()),
    )
    .expect("PGPR_BENCH_SCALE must be small|paper");
    let threads = pgpr::bench_support::threads_from_env();
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        let t = fig1(domain, scale, 1, threads);
        println!("{}", t.render());
    }
}
