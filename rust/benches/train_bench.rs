//! Distributed-training sweep → `BENCH_train.json` (one distributed
//! NLML+gradient evaluation timed across host thread counts, plus the
//! hyperparameter-recovery gate vs the exact-subset MLE baseline).
//!
//!     cargo bench --bench train_bench                 # full sweep + gates
//!     PGPR_TRAIN_SMOKE=1 cargo bench --bench train_bench   # CI smoke
//!     cargo bench --bench train_bench -- out.json     # custom output
//!
//! `PGPR_LENIENT_PERF=1` downgrades the gates to advisory on
//! oversubscribed hosts (same convention as `linalg_bench`).
//! `--telemetry-out=PATH` (or `PGPR_TELEMETRY_OUT`) additionally
//! writes the run's full telemetry snapshot as JSON.

use pgpr::bench_support::train_bench::{run, TrainBenchConfig};

fn main() {
    // skip cargo-bench's --bench flag if present; first real arg = path
    let out = pgpr::cli::args::process_out_path("BENCH_train.json");
    let telemetry_out = pgpr::bench_support::telemetry_out_from_args();
    if telemetry_out.is_some() {
        pgpr::obsv::set_enabled(true);
    }
    let cfg = TrainBenchConfig::from_env();
    run(&cfg, &out);
    if let Some(p) = telemetry_out {
        pgpr::bench_support::write_telemetry_snapshot(&p);
    }
}
