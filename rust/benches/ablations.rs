//! Ablations over the design choices DESIGN.md calls out:
//!
//!  1. **equivalence** — Theorems 1–3 as numbers: max |Δ| between each
//!     parallel protocol and its centralized counterpart;
//!  2. **clustering** — the paper's parallelized clustering scheme vs a
//!     random partition for pPIC (Remark 2 after Def. 5: clustering
//!     should improve RMSE) including its extra cost;
//!  3. **online** — §5.2: incremental absorb cost vs naive full refit;
//!  4. **support** — entropy-selected vs random support set for pPITC.
//!
//!     cargo bench --bench ablations

use pgpr::bench_support::table::{fmt3, Table};
use pgpr::bench_support::workloads::{prepare, Domain};
use pgpr::data::partition::{cluster_partition, random_partition};
use pgpr::gp::pic::PicGp;
use pgpr::gp::pitc::PitcGp;
use pgpr::gp::icf_gp::IcfGp;
use pgpr::gp::support::{select_support_random, support_matrix};
use pgpr::linalg::Mat;
use pgpr::metrics::rmse;
use pgpr::parallel::online::OnlineGp;
use pgpr::parallel::{picf, ppic, ppitc, ClusterSpec};
use pgpr::runtime::NativeBackend;
use pgpr::testkit::max_abs_diff;
use pgpr::util::{Pcg64, Stopwatch};

fn main() {
    equivalence();
    clustering();
    online();
    support_selection();
}

/// Theorems 1–3, numerically, at a non-trivial size.
fn equivalence() {
    let w = prepare(Domain::Sarcos, 600, 120, 21, false);
    let m = 6;
    let mut rng = Pcg64::seed(77);
    let xs = support_matrix(&w.hyp, &w.train.x, 48);
    let d_blocks = random_partition(600, m, &mut rng);
    let u_blocks = random_partition(120, m, &mut rng);
    let spec = ClusterSpec::new(m);

    let mut t = Table::new(
        "ablation: Theorem 1-3 equivalence (max |mean Δ| / max |var Δ|)",
        &["pair", "mean Δ", "var Δ"],
    );

    let par = ppitc::run(&w.hyp, &w.train.x, &w.train.y, &xs, &w.test.x,
                         &d_blocks, &u_blocks, &NativeBackend, &spec);
    let cen = PitcGp::fit(&w.hyp, &w.train.x, &w.train.y, &xs, &d_blocks)
        .predict(&w.test.x);
    t.row(vec!["pPITC vs PITC".into(),
               format!("{:.2e}", max_abs_diff(&par.prediction.mean, &cen.mean)),
               format!("{:.2e}", max_abs_diff(&par.prediction.var, &cen.var))]);

    let par = ppic::run_with_partition(&w.hyp, &w.train.x, &w.train.y, &xs,
                                       &w.test.x, &d_blocks, &u_blocks,
                                       &NativeBackend, &spec);
    let cen = PicGp::fit(&w.hyp, &w.train.x, &w.train.y, &xs, &d_blocks)
        .predict(&w.test.x, &u_blocks);
    t.row(vec!["pPIC vs PIC".into(),
               format!("{:.2e}", max_abs_diff(&par.prediction.mean, &cen.mean)),
               format!("{:.2e}", max_abs_diff(&par.prediction.var, &cen.var))]);

    let rank = 96;
    let par = picf::run(&w.hyp, &w.train.x, &w.train.y, &w.test.x, &d_blocks,
                        rank, &NativeBackend, &spec);
    let cen = IcfGp::fit(&w.hyp, &w.train.x, &w.train.y, rank, &d_blocks)
        .predict(&w.test.x);
    t.row(vec!["pICF vs ICF".into(),
               format!("{:.2e}", max_abs_diff(&par.prediction.mean, &cen.mean)),
               format!("{:.2e}", max_abs_diff(&par.prediction.var, &cen.var))]);
    println!("{}", t.render());
}

/// Clustered vs random partition for pPIC.
fn clustering() {
    let mut t = Table::new(
        "ablation: pPIC partitioning — clustered vs random (5 seeds)",
        &["domain", "RMSE clustered", "RMSE random", "partition cost_s"],
    );
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        let w = prepare(domain, 800, 160, 31, false);
        let m = 8;
        let xs = support_matrix(&w.hyp, &w.train.x, 48);
        let spec = ClusterSpec::new(m);
        let (mut rc, mut rr, mut cost) = (0.0, 0.0, 0.0);
        let seeds = 5;
        for seed in 0..seeds {
            let mut rng = Pcg64::seed(100 + seed);
            let (part, secs) = Stopwatch::time(|| {
                cluster_partition(&w.train.x, &w.test.x, m, &mut rng)
            });
            cost += secs;
            let out = ppic::run_with_partition(
                &w.hyp, &w.train.x, &w.train.y, &xs, &w.test.x,
                &part.d_blocks, &part.u_blocks, &NativeBackend, &spec);
            rc += rmse(&w.test.y, &out.prediction.mean);

            let d_blocks = random_partition(w.train.len(), m, &mut rng);
            let u_blocks = random_partition(w.test.len(), m, &mut rng);
            let out = ppic::run_with_partition(
                &w.hyp, &w.train.x, &w.train.y, &xs, &w.test.x,
                &d_blocks, &u_blocks, &NativeBackend, &spec);
            rr += rmse(&w.test.y, &out.prediction.mean);
        }
        let k = seeds as f64;
        t.row(vec![domain.name().into(), fmt3(rc / k), fmt3(rr / k),
                   fmt3(cost / k)]);
    }
    println!("{}", t.render());
}

/// §5.2 online absorb vs naive refit.
fn online() {
    let w = prepare(Domain::Aimpeak, 1280, 128, 41, false);
    let m = 4;
    let per = 64; // per machine per batch
    let xs = support_matrix(&w.hyp, &w.train.x, 48);
    let mut og = OnlineGp::new(&w.hyp, &xs, std::sync::Arc::new(NativeBackend),
                               ClusterSpec::new(m));
    let mut rng = Pcg64::seed(9);
    let u_blocks = random_partition(w.test.len(), m, &mut rng);

    let mut t = Table::new(
        "ablation: online absorb vs naive refit (§5.2)",
        &["batch", "|D|", "absorb_s", "refit_s", "RMSE online"],
    );
    let mut seen = 0usize;
    for b in 0..5 {
        let lo = b * m * per;
        let blocks: Vec<(Mat, Vec<f64>)> = (0..m)
            .map(|k| {
                let rows: Vec<usize> =
                    (lo + k * per..lo + (k + 1) * per).collect();
                let part = w.train.select(&rows);
                (part.x, part.y)
            })
            .collect();
        let absorb_s = og.absorb(&blocks);
        seen += m * per;
        let hist: Vec<usize> = (0..seen).collect();
        let hist_ds = w.train.select(&hist);
        let d_blocks = random_partition(seen, m, &mut rng);
        let (_, refit_s) = Stopwatch::time(|| {
            ppitc::run(&w.hyp, &hist_ds.x, &hist_ds.y, &xs, &w.test.x,
                       &d_blocks, &u_blocks, &NativeBackend,
                       &ClusterSpec::new(m))
        });
        let pred = og.predict_ppitc(&w.test.x, &u_blocks);
        t.row(vec![(b + 1).to_string(), seen.to_string(), fmt3(absorb_s),
                   fmt3(refit_s),
                   fmt3(rmse(&w.test.y, &pred.prediction.mean))]);
    }
    println!("{}", t.render());
}

/// Entropy vs random support selection.
fn support_selection() {
    let mut t = Table::new(
        "ablation: support selection — entropy vs random (pPITC RMSE)",
        &["domain", "|S|", "entropy", "random (avg 5)"],
    );
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        let w = prepare(domain, 800, 160, 51, false);
        let m = 8;
        let spec = ClusterSpec::new(m);
        let mut rng = Pcg64::seed(4);
        let d_blocks = random_partition(w.train.len(), m, &mut rng);
        let u_blocks = random_partition(w.test.len(), m, &mut rng);
        for s in [16usize, 48] {
            let xs = support_matrix(&w.hyp, &w.train.x, s);
            let out = ppitc::run(&w.hyp, &w.train.x, &w.train.y, &xs,
                                 &w.test.x, &d_blocks, &u_blocks,
                                 &NativeBackend, &spec);
            let ent = rmse(&w.test.y, &out.prediction.mean);
            let mut rnd = 0.0;
            for seed in 0..5 {
                let idx = select_support_random(
                    w.train.len(), s, &mut Pcg64::seed(200 + seed));
                let xs_r = w.train.x.select_rows(&idx);
                let out = ppitc::run(&w.hyp, &w.train.x, &w.train.y, &xs_r,
                                     &w.test.x, &d_blocks, &u_blocks,
                                     &NativeBackend, &spec);
                rnd += rmse(&w.test.y, &out.prediction.mean);
            }
            t.row(vec![domain.name().into(), s.to_string(), fmt3(ent),
                       fmt3(rnd / 5.0)]);
        }
    }
    println!("{}", t.render());
}
