//! Table 1 empirical check: measured time-scaling exponents in |D| per
//! method vs the table's dominant analytic terms, plus communication
//! accounting (bytes/messages vs the O(log M) collective model).
//!
//!     cargo bench --bench table1_complexity

use pgpr::bench_support::figures::table1;
use pgpr::bench_support::table::Table;
use pgpr::bench_support::workloads::Domain;
use pgpr::cluster::NetworkModel;
use pgpr::data::partition::random_partition;
use pgpr::kernel::SeArd;
use pgpr::linalg::Mat;
use pgpr::parallel::{ppitc, ClusterSpec};
use pgpr::runtime::NativeBackend;
use pgpr::util::Pcg64;

fn main() {
    let threads = pgpr::bench_support::threads_from_env();
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        println!("{}", table1(domain, 1, threads).render());
    }

    // communication column: pPITC bytes are O(|S|^2) independent of |D|
    // and of |U| (observation g), and messages grow linearly in M while
    // the modeled round count grows as ceil(log2 M).
    let mut t = Table::new(
        "Table 1 check — pPITC communication vs M (|S|=32 fixed)",
        &["M", "bytes", "messages", "log2 rounds"],
    );
    let mut rng = Pcg64::seed(3);
    let d = 2;
    let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
    let s = 32;
    let xs = Mat::from_vec(s, d, rng.normals(s * d));
    for m in [2usize, 4, 8, 16] {
        let n = 40 * m;
        let u = 4 * m;
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let d_blocks = random_partition(n, m, &mut rng);
        let u_blocks = random_partition(u, m, &mut rng);
        let out = ppitc::run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                             &NativeBackend, &ClusterSpec::new(m));
        t.row(vec![
            m.to_string(),
            out.metrics.bytes_sent.to_string(),
            out.metrics.messages.to_string(),
            NetworkModel::tree_rounds(m).to_string(),
        ]);
    }
    println!("{}", t.render());
}
