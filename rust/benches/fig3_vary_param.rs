//! Regenerates Fig. 3 (a–h): metrics vs the low-rank parameter
//! P = |S| = R (AIMPEAK) / |S| = R/2 (SARCOS), P ∈ {16..128}
//! (paper 256..2048), |D|=2000, M=20.
//!
//!     cargo bench --bench fig3_vary_param

use pgpr::bench_support::figures::{fig3, Scale};
use pgpr::bench_support::workloads::Domain;

fn main() {
    let scale = Scale::parse(
        &std::env::var("PGPR_BENCH_SCALE").unwrap_or_else(|_| "small".into()),
    )
    .expect("PGPR_BENCH_SCALE must be small|paper");
    let threads = pgpr::bench_support::threads_from_env();
    for domain in [Domain::Aimpeak, Domain::Sarcos] {
        let t = fig3(domain, scale, 1, threads);
        println!("{}", t.render());
    }
}
