//! Matrix products. The `matmul*` entry points route through the
//! cache-blocked engine in [`super::blocked`] (serial ctx — pass a
//! [`super::LinalgCtx`] to `gemm`/`gemm_tn`/`gemm_nt` for pooled
//! execution); the `*_scalar` variants are the seed's streaming
//! kernels, kept as the bitwise/numerical reference the property tests
//! and `linalg_bench` compare against.

use super::blocked;
use super::ctx::LinalgCtx;
use super::{axpy, dot, Mat};

/// C = A · B via the blocked engine (serial). Bitwise-identical to
/// [`matmul_scalar`]; ≈2× faster at 512²–1024² (see `BENCH_linalg.json`).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    blocked::gemm(&LinalgCtx::serial(), a, b)
}

/// C = Aᵀ · B (A stored untransposed) via the blocked engine.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    blocked::gemm_tn(&LinalgCtx::serial(), a, b)
}

/// C = A · Bᵀ (B stored untransposed) via the blocked engine.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    blocked::gemm_nt(&LinalgCtx::serial(), a, b)
}

/// Seed scalar kernel: i-k-j loop order with a 4-wide k-unrolled
/// microkernel. Kept as the reference implementation (the blocked
/// engine reproduces it bitwise) and as the `linalg_bench` baseline.
pub fn matmul_scalar(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut c = Mat::zeros(a.rows, b.cols);
    let n = b.cols;
    let kk = a.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        let mut k = 0;
        while k + 4 <= kk {
            let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
            let b0 = &b.data[k * n..(k + 1) * n];
            let b1 = &b.data[(k + 1) * n..(k + 2) * n];
            let b2 = &b.data[(k + 2) * n..(k + 3) * n];
            let b3 = &b.data[(k + 3) * n..(k + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            k += 4;
        }
        while k < kk {
            let aik = arow[k];
            if aik != 0.0 {
                axpy(aik, b.row(k), crow);
            }
            k += 1;
        }
    }
    c
}

/// Seed scalar C = Aᵀ · B (reference for [`matmul_tn`]).
pub fn matmul_tn_scalar(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn: {}x{}ᵀ · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.cols, b.cols);
    for k in 0..a.rows {
        let arow = a.row(k);
        let brow = b.row(k);
        for (i, &aki) in arow.iter().enumerate() {
            if aki != 0.0 {
                axpy(aki, brow, c.row_mut(i));
            }
        }
    }
    c
}

/// Seed scalar C = A · Bᵀ (reference for [`matmul_nt`]).
pub fn matmul_nt_scalar(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt: {}x{} · {}x{}ᵀ", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// y = A · x — four rows per pass (x is streamed once for all four
/// accumulators, matching the `matmul` microkernel style), with the
/// same 4-wide k-grouped accumulation per row as `matmul` on an n×1
/// right-hand side, so serve-time single-query predictions see the
/// same numbers whether they go through `matvec` or the GEMM path.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "matvec shape");
    let kk = a.cols;
    let mut y = vec![0.0; a.rows];
    let mut i = 0;
    while i + 4 <= a.rows {
        let (r0, r1, r2, r3) =
            (a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3));
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        let mut k = 0;
        while k + 4 <= kk {
            let (x0, x1, x2, x3) = (x[k], x[k + 1], x[k + 2], x[k + 3]);
            s0 += r0[k] * x0 + r0[k + 1] * x1 + r0[k + 2] * x2 + r0[k + 3] * x3;
            s1 += r1[k] * x0 + r1[k + 1] * x1 + r1[k + 2] * x2 + r1[k + 3] * x3;
            s2 += r2[k] * x0 + r2[k + 1] * x1 + r2[k + 2] * x2 + r2[k + 3] * x3;
            s3 += r3[k] * x0 + r3[k + 1] * x1 + r3[k + 2] * x2 + r3[k + 3] * x3;
            k += 4;
        }
        while k < kk {
            let xk = x[k];
            s0 += r0[k] * xk;
            s1 += r1[k] * xk;
            s2 += r2[k] * xk;
            s3 += r3[k] * xk;
            k += 1;
        }
        y[i] = s0;
        y[i + 1] = s1;
        y[i + 2] = s2;
        y[i + 3] = s3;
        i += 4;
    }
    while i < a.rows {
        let row = a.row(i);
        let mut s = 0.0;
        let mut k = 0;
        while k + 4 <= kk {
            s += row[k] * x[k]
                + row[k + 1] * x[k + 1]
                + row[k + 2] * x[k + 2]
                + row[k + 3] * x[k + 3];
            k += 4;
        }
        while k < kk {
            s += row[k] * x[k];
            k += 1;
        }
        y[i] = s;
        i += 1;
    }
    y
}

/// y = Aᵀ · x — four k-rows combined per pass (quartering the y-row
/// memory traffic, the same trick as the `matmul` microkernel).
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len(), "matvec_t shape");
    let n = a.cols;
    let mut y = vec![0.0; n];
    let mut k = 0;
    while k + 4 <= a.rows {
        let (x0, x1, x2, x3) = (x[k], x[k + 1], x[k + 2], x[k + 3]);
        let r0 = a.row(k);
        let r1 = a.row(k + 1);
        let r2 = a.row(k + 2);
        let r3 = a.row(k + 3);
        for j in 0..n {
            y[j] += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
        }
        k += 4;
    }
    while k < a.rows {
        let xk = x[k];
        if xk != 0.0 {
            axpy(xk, a.row(k), &mut y);
        }
        k += 1;
    }
    y
}

/// diag(A · B) without forming the product (A: m×k, B: k×m).
///
/// Streams both operands cache-friendly: k is tiled so the visited
/// rows of B stay resident while every row of A walks its contiguous
/// k-slice (the seed version strode down a full column of B per output
/// element, missing cache on every step for large k).
pub fn diag_of_product(a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols);
    let m = a.rows;
    let kdim = a.cols;
    let mut out = vec![0.0; m];
    if m == 0 || kdim == 0 {
        return out;
    }
    // Tile depth: keep the B tile (tk rows × b.cols) around 256 KiB.
    let tk = (32768 / b.cols.max(1)).clamp(8, 512);
    let mut k0 = 0;
    while k0 < kdim {
        let k1 = (k0 + tk).min(kdim);
        for (i, o) in out.iter_mut().enumerate() {
            let arow = &a.row(i)[k0..k1];
            let mut s = 0.0;
            for (t, &av) in arow.iter().enumerate() {
                s += av * b.data[(k0 + t) * b.cols + i];
            }
            *o += s;
        }
        k0 = k1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::prop_check;
    use crate::testkit::{assert_all_close, max_abs_diff};

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows, b.cols, |i, j| {
            (0..a.cols).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    fn rand_mat(g: &mut crate::testkit::prop::Gen, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c))
    }

    #[test]
    fn matmul_matches_naive() {
        prop_check("matmul-naive", 24, |g| {
            let (m, k, n) = (g.usize_in(1, 12), g.usize_in(1, 12), g.usize_in(1, 12));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
        });
    }

    /// The public entry points are bitwise-faithful to the seed scalar
    /// kernel (matmul) / agree to float precision (tn, nt).
    #[test]
    fn blocked_entry_points_match_scalar() {
        prop_check("matmul-vs-scalar", 16, |g| {
            let (m, k, n) =
                (g.usize_in(1, 30), g.usize_in(1, 60), g.usize_in(1, 30));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            assert_eq!(matmul(&a, &b), matmul_scalar(&a, &b));
            let at = rand_mat(g, k, m);
            assert!(matmul_tn(&at, &b)
                .max_abs_diff(&matmul_tn_scalar(&at, &b)) < 1e-12);
            let bt = rand_mat(g, n, k);
            assert!(matmul_nt(&a, &bt)
                .max_abs_diff(&matmul_nt_scalar(&a, &bt)) < 1e-12);
        });
    }

    #[test]
    fn transposed_variants_agree() {
        prop_check("matmul-trans", 24, |g| {
            let (m, k, n) = (g.usize_in(1, 10), g.usize_in(1, 10), g.usize_in(1, 10));
            let a = rand_mat(g, k, m); // used as Aᵀ
            let b = rand_mat(g, k, n);
            let via_tn = matmul_tn(&a, &b);
            let via_plain = matmul(&a.transpose(), &b);
            assert!(via_tn.max_abs_diff(&via_plain) < 1e-12);

            let c = rand_mat(g, n, k);
            let d = rand_mat(g, m, k);
            let via_nt = matmul_nt(&c, &d);
            let via_plain2 = matmul(&c, &d.transpose());
            assert!(via_nt.max_abs_diff(&via_plain2) < 1e-12);
        });
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        prop_check("matvec", 16, |g| {
            let (m, n) = (g.usize_in(1, 12), g.usize_in(1, 12));
            let a = rand_mat(g, m, n);
            let x = g.normal_vec(n);
            let xm = Mat::from_vec(n, 1, x.clone());
            let want = matmul(&a, &xm).data;
            assert!(max_abs_diff(&matvec(&a, &x), &want) < 1e-12);
            let y = g.normal_vec(m);
            let want_t = matmul_tn(&a, &Mat::from_vec(m, 1, y.clone())).data;
            assert!(max_abs_diff(&matvec_t(&a, &y), &want_t) < 1e-12);
        });
    }

    /// The unrolled matvec paths hit their row-remainder and
    /// k-remainder branches at every size mod 4.
    #[test]
    fn matvec_unroll_remainders() {
        prop_check("matvec-remainders", 12, |g| {
            for m in 1..=9usize {
                let n = g.usize_in(1, 11);
                let a = rand_mat(g, m, n);
                let x = g.normal_vec(n);
                let want: Vec<f64> = (0..m)
                    .map(|i| {
                        (0..n).map(|k| a[(i, k)] * x[k]).sum::<f64>()
                    })
                    .collect();
                assert_all_close(&matvec(&a, &x), &want, 1e-12, 1e-12);
                let z = g.normal_vec(m);
                let want_t: Vec<f64> = (0..n)
                    .map(|j| {
                        (0..m).map(|k| a[(k, j)] * z[k]).sum::<f64>()
                    })
                    .collect();
                assert_all_close(&matvec_t(&a, &z), &want_t, 1e-12, 1e-12);
            }
        });
    }

    /// Rectangular-shape property test for the cache-friendly
    /// diag_of_product, including k ≫ m and m ≫ k shapes that cross
    /// the tile boundary.
    #[test]
    fn diag_of_product_matches() {
        prop_check("diagprod", 16, |g| {
            let (m, k) = (g.usize_in(1, 40), g.usize_in(1, 600));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, m);
            let got = diag_of_product(&a, &b);
            let want = matmul(&a, &b).diag();
            assert_all_close(&got, &want, 1e-10, 1e-10);
        });
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        assert_eq!(matmul(&a, &Mat::identity(4)), a);
        assert_eq!(matmul(&Mat::identity(4), &a), a);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        matmul(&Mat::zeros(2, 3), &Mat::zeros(2, 3));
    }
}
