//! Matrix products, cache-aware for row-major storage.
//!
//! `matmul` uses the i-k-j loop order so the inner loop streams rows of B
//! and C contiguously (auto-vectorizes well); the transposed variants
//! avoid materializing transposes.

use super::{axpy, dot, Mat};

/// C = A · B.
///
/// i-k-j order with a 4-wide k-unrolled microkernel: four rows of B are
/// combined into C's row per pass, quartering the C-row memory traffic
/// (the §Perf log shows ~1.9× over the plain axpy loop at 512²).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    let n = b.cols;
    let kk = a.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        let mut k = 0;
        while k + 4 <= kk {
            let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
            let b0 = &b.data[k * n..(k + 1) * n];
            let b1 = &b.data[(k + 1) * n..(k + 2) * n];
            let b2 = &b.data[(k + 2) * n..(k + 3) * n];
            let b3 = &b.data[(k + 3) * n..(k + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            k += 4;
        }
        while k < kk {
            let aik = arow[k];
            if aik != 0.0 {
                axpy(aik, b.row(k), crow);
            }
            k += 1;
        }
    }
    c
}

/// C = Aᵀ · B (A is stored untransposed).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn: {}x{}ᵀ · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.cols, b.cols);
    for k in 0..a.rows {
        let arow = a.row(k);
        let brow = b.row(k);
        for (i, &aki) in arow.iter().enumerate() {
            if aki != 0.0 {
                axpy(aki, brow, c.row_mut(i));
            }
        }
    }
    c
}

/// C = A · Bᵀ (B is stored untransposed).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt: {}x{} · {}x{}ᵀ", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// y = A · x.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "matvec shape");
    (0..a.rows).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len(), "matvec_t shape");
    let mut y = vec![0.0; a.cols];
    for (k, &xk) in x.iter().enumerate() {
        if xk != 0.0 {
            axpy(xk, a.row(k), &mut y);
        }
    }
    y
}

/// diag(A · B) without forming the product (A: m×k, B: k×m).
pub fn diag_of_product(a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols);
    (0..a.rows)
        .map(|i| (0..a.cols).map(|k| a[(i, k)] * b[(k, i)]).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::prop_check;
    use crate::testkit::{assert_all_close, max_abs_diff};

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows, b.cols, |i, j| {
            (0..a.cols).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    fn rand_mat(g: &mut crate::testkit::prop::Gen, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c))
    }

    #[test]
    fn matmul_matches_naive() {
        prop_check("matmul-naive", 24, |g| {
            let (m, k, n) = (g.usize_in(1, 12), g.usize_in(1, 12), g.usize_in(1, 12));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
        });
    }

    #[test]
    fn transposed_variants_agree() {
        prop_check("matmul-trans", 24, |g| {
            let (m, k, n) = (g.usize_in(1, 10), g.usize_in(1, 10), g.usize_in(1, 10));
            let a = rand_mat(g, k, m); // used as Aᵀ
            let b = rand_mat(g, k, n);
            let via_tn = matmul_tn(&a, &b);
            let via_plain = matmul(&a.transpose(), &b);
            assert!(via_tn.max_abs_diff(&via_plain) < 1e-12);

            let c = rand_mat(g, n, k);
            let d = rand_mat(g, m, k);
            let via_nt = matmul_nt(&c, &d);
            let via_plain2 = matmul(&c, &d.transpose());
            assert!(via_nt.max_abs_diff(&via_plain2) < 1e-12);
        });
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        prop_check("matvec", 16, |g| {
            let (m, n) = (g.usize_in(1, 12), g.usize_in(1, 12));
            let a = rand_mat(g, m, n);
            let x = g.normal_vec(n);
            let xm = Mat::from_vec(n, 1, x.clone());
            let want = matmul(&a, &xm).data;
            assert!(max_abs_diff(&matvec(&a, &x), &want) < 1e-12);
            let y = g.normal_vec(m);
            let want_t = matmul_tn(&a, &Mat::from_vec(m, 1, y.clone())).data;
            assert!(max_abs_diff(&matvec_t(&a, &y), &want_t) < 1e-12);
        });
    }

    #[test]
    fn diag_of_product_matches() {
        prop_check("diagprod", 16, |g| {
            let (m, k) = (g.usize_in(1, 10), g.usize_in(1, 10));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, m);
            let got = diag_of_product(&a, &b);
            let want = matmul(&a, &b).diag();
            assert_all_close(&got, &want, 1e-12, 1e-12);
        });
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        assert_eq!(matmul(&a, &Mat::identity(4)), a);
        assert_eq!(matmul(&Mat::identity(4), &a), a);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        matmul(&Mat::zeros(2, 3), &Mat::zeros(2, 3));
    }
}
