//! Dense linear algebra substrate (built from scratch — no BLAS/LAPACK).
//!
//! Everything the GP methods need: a row-major [`Mat`], blocked matrix
//! products ([`matmul`] / [`gemm`]), Cholesky factorization +
//! triangular solves ([`cholesky`] / [`cholesky_blocked`]), the
//! paper's row-based incomplete Cholesky factorization ([`icf`]), a
//! Jacobi symmetric eigensolver ([`eigen`]) and classical
//! multi-dimensional scaling ([`mds`], used to embed the AIMPEAK road
//! network per the paper's footnote 2).
//!
//! # §Perf — the blocked, SIMD-dispatched, thread-parallel engine
//!
//! Every hot kernel routes through [`blocked`]: packed-tile GEMM
//! (KC=192-deep k-blocks × NC=256-wide B tiles, packed per-thread),
//! right-looking blocked Cholesky (scalar POTRF diagonal block +
//! row-parallel TRSM panel + pooled GEMM trailing update) and
//! column-band-parallel triangular solves. The innermost microkernel
//! is selected at runtime from the [`simd`] tier ladder — AVX-512
//! (8×8 f64 register block) → AVX2+FMA (4×8) → the portable seed
//! kernel — detected once and cached; the `PGPR_SIMD` env knob forces
//! a tier (`portable` reproduces the pre-SIMD engine bitwise). The
//! banded SE-kernel exponential shares a vectorized polynomial `exp`
//! ([`simd::exp`], ≤4 ulp of libm) and the mixed-precision serve mode
//! stores staged operators in f32 while accumulating in f64
//! ([`simd::mixed`]). Execution is controlled by [`LinalgCtx`] — a
//! factorization block size plus an optional
//! [`crate::util::pool::ThreadPool`] handle; the plain entry points
//! (`matmul`, `cholesky`, `solve_lower_mat`, …) use a serial ctx,
//! pool-nested calls degrade to serial automatically so the cluster
//! executor can share one pool with the engine, and problems below a
//! per-kernel flop cutoff skip the pool (dispatch overhead dominates
//! there).
//!
//! Measured on the 2-core AVX-512 dev host (see `BENCH_linalg.json`,
//! regenerated as a CI artifact on every push; build uses
//! `target-cpu=native` via `.cargo/config.toml`):
//!
//! * 1024² GEMM: 6.9 GFLOP/s seed scalar → 14.2 blocked-portable →
//!   ≈2× again with the AVX-512 microkernel single-thread, with
//!   per-thread packing lifting the 1→2 thread scaling.
//! * 1024² Cholesky: 3.0 → 10.6 GFLOP/s single-thread blocked; the
//!   AVX tiers accelerate the trailing update further.
//! * The seed kernels survive as `matmul_scalar` / `cholesky_scalar` /
//!   `solve_*_scalar` — the property-tested references (Portable-tier
//!   serial GEMM is bitwise-identical to `matmul_scalar`; pooled runs
//!   are bitwise-identical to serial within every tier by
//!   construction).

pub mod blocked;
pub mod cholesky;
pub mod ctx;
pub mod eigen;
pub mod icf;
pub mod matmul;
pub mod mds;
pub mod simd;

pub use blocked::{cho_solve_mat_ctx, cholesky_blocked, diag_quad_ctx,
                  diag_quad_into, gemm, gemm_into, gemm_nt, gemm_tn,
                  solve_lower_mat_ctx, solve_upper_t_mat_ctx};
pub use cholesky::{cho_solve_mat, cho_solve_vec, cholesky, cholesky_scalar,
                   solve_lower_mat, solve_lower_vec, solve_upper_t_mat,
                   solve_upper_t_vec};
pub use ctx::LinalgCtx;
pub use icf::{icf, icf_ctx, IcfFactor};
pub use matmul::{diag_of_product, matmul, matmul_nt, matmul_scalar,
                 matmul_tn, matvec, matvec_t};
pub use simd::{active_tier, force_tier, SimdTier};

/// Row-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build from row-major data (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a slice of rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        assert!(!rows.is_empty(), "from_rows: empty");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place without giving back capacity (the scratch-reuse
    /// primitive of the serve path: steady-state batches never
    /// reallocate). Grown cells are zero-filled; contents are otherwise
    /// unspecified — callers overwrite them.
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        if self.rows != rows || self.cols != cols {
            self.data.resize(rows * cols, 0.0);
            self.rows = rows;
            self.cols = cols;
        }
    }

    /// Extract a subset of rows (by index) into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Transposed copy, 32×32 cache-blocked: both the source rows and
    /// the destination rows of a tile stay resident, so neither side
    /// strides a full leading dimension per element (the naive double
    /// loop misses on every destination write once `rows·cols` exceeds
    /// the L2). Also the workhorse behind `matmul_tn`/`matmul_nt`.
    pub fn transpose(&self) -> Mat {
        const TB: usize = 32;
        let mut t = Mat::zeros(self.cols, self.rows);
        let mut i0 = 0;
        while i0 < self.rows {
            let i1 = (i0 + TB).min(self.rows);
            let mut j0 = 0;
            while j0 < self.cols {
                let j1 = (j0 + TB).min(self.cols);
                for i in i0..i1 {
                    let src = &self.data[i * self.cols..(i + 1) * self.cols];
                    for j in j0..j1 {
                        t.data[j * self.rows + i] = src[j];
                    }
                }
                j0 = j1;
            }
            i0 = i1;
        }
        t
    }

    pub fn diag(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self[(i, i)]).collect()
    }

    /// `self += alpha * I` (square only).
    pub fn add_diag(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols, "add_diag on non-square");
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
    }

    /// `self += other` elementwise.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// `self -= other` elementwise.
    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Max |self - other| (shape-checked).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Symmetrize in place: `self = (self + selfᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product of two equal-length slices, 4-wide unrolled so the
/// independent accumulators pipeline (f64 adds are not reassociable by
/// LLVM without fast-math; manual unrolling recovers the ILP).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    /// Round-trip + entry-wise property test for the tiled transpose at
    /// shapes straddling the 32×32 tile boundary (and degenerate rows
    /// and columns).
    #[test]
    fn transpose_tiled_roundtrip_prop() {
        crate::testkit::prop::prop_check("transpose-tiled", 20, |g| {
            let pick = |g: &mut crate::testkit::prop::Gen| {
                *g.choose(&[1usize, 2, 5, 31, 32, 33, 63, 64, 65, 100])
            };
            let (r, c) = (pick(g), pick(g));
            let m = Mat::from_vec(r, c, g.normal_vec(r * c));
            let t = m.transpose();
            assert_eq!((t.rows, t.cols), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[(j, i)], m[(i, j)]);
                }
            }
            assert_eq!(t.transpose(), m);
        });
    }

    #[test]
    fn identity_and_diag() {
        let i = Mat::identity(4);
        assert_eq!(i.diag(), vec![1.0; 4]);
        let mut m = Mat::zeros(3, 3);
        m.add_diag(2.5);
        assert_eq!(m.diag(), vec![2.5; 3]);
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![0.5; 4]);
        a.add_assign(&b);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5, 4.5]);
        a.sub_assign(&b);
        a.scale(2.0);
        assert_eq!(a.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn select_rows_picks() {
        let m = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), m.row(2));
        assert_eq!(s.row(1), m.row(0));
    }

    #[test]
    fn norms() {
        let m = Mat::from_vec(1, 2, vec![3.0, -4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-15);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn symmetrize_works() {
        let mut m = Mat::from_vec(2, 2, vec![1.0, 2.0, 4.0, 5.0]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn dot_axpy() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Mat::from_vec(2, 2, vec![1.0; 3]);
    }
}
