//! The cache-blocked, optionally thread-parallel dense engine that every
//! GP hot path routes through (via the [`LinalgCtx`] knobs).
//!
//! # Scheme
//!
//! **GEMM** (`gemm`/`gemm_tn`/`gemm_nt`): C = A·B is tiled over
//! (`KC`=192)-deep k-blocks × (`NC`=256)-wide column tiles of B. Row
//! bands of C fan out over the pool once per k-block; **each band job
//! packs its own B tile** into a thread-local buffer (so the innermost
//! loop streams it at unit stride and no job ever waits on a shared
//! packer — one barrier per k-block instead of one per tile). The
//! microkernel is picked once per call from the runtime-dispatched
//! tier ladder in [`super::simd`] (AVX-512 8×8 → AVX2+FMA 4×8 →
//! portable seed kernel; override with `PGPR_SIMD`) and the tier is
//! captured into every pool job so forced tiers survive the fan-out.
//! The transposed variants reuse the same fast path through one tiled
//! transpose. Problems below a per-kernel flop cutoff skip the pool
//! entirely (dispatch overhead swamps the kernel there — measured, see
//! `BENCH_linalg.json`); the cutoff changes scheduling only, never
//! numbers.
//!
//! **Cholesky** (`cholesky_blocked`): right-looking — scalar POTRF on
//! the `ctx.block`-sized diagonal block, a row-parallel TRSM panel,
//! then the trailing SYRK update `A₂₂ -= X·Xᵀ` executed as banded GEMM
//! calls on the pool (each band updates the rectangle covering its
//! part of the lower triangle; overshoot lands in the strictly-upper
//! half, which is zeroed at the end and never read). The triangular
//! solves (`solve_lower_mat_ctx`/`solve_upper_t_mat_ctx`) parallelize
//! over *column* bands of the right-hand side — columns of a
//! triangular solve are independent — with the same blocked kernel
//! inside each band.
//!
//! # Equivalence contracts (tested)
//!
//! * Under the `Portable` tier, serial `gemm` reproduces the seed
//!   scalar `matmul` **bitwise**: the k-blocking (`KC` a multiple of
//!   4) preserves the scalar kernel's 4-wide grouping and per-element
//!   expression exactly, and the portable microkernel is the seed
//!   kernel verbatim.
//! * Pooled runs reproduce serial runs **bitwise** for every kernel
//!   *within any tier*: parallelism only partitions disjoint output
//!   bands (see [`LinalgCtx`]), and every tier produces each element
//!   from one accumulator folded over k in a fixed order, so band
//!   boundaries never change an element's value.
//! * AVX tiers agree with `Portable` to reassociation-level tolerance;
//!   factorizations/solves agree with the scalar references to ≤1e-10
//!   on well-conditioned inputs (different but equally stable
//!   summation orders). The tier-matrix test below sweeps every
//!   supported tier through all four kernels.

use super::cholesky::NotSpd;
use super::ctx::LinalgCtx;
use super::simd::{self, SimdTier};
use super::{axpy, dot, Mat};
use std::cell::Cell;

/// k-block depth. Must stay a multiple of 4: it aligns the packed
/// panel with the scalar kernel's 4-wide k-grouping, which is what
/// makes serial `gemm` bitwise-equal to the seed `matmul`.
const KC: usize = 192;

/// Column-tile width of the packed B panel (KC×NC ≈ 384 KiB of f64
/// stays L2-resident on anything this runs on).
const NC: usize = 256;

/// Row-band height for the Cholesky trailing update when serial. Kept
/// modest so the rectangle-per-band overshoot above the diagonal stays
/// small.
const TRAIL_BAND: usize = 96;

/// Trailing-update band height when pooled: finer bands give the pool
/// enough independent units to balance the triangular (shrinking)
/// update across workers. Band size never changes element values (one
/// accumulator per element, k order fixed), so this is a pure
/// scheduling knob.
const TRAIL_BAND_POOLED: usize = 48;

/// Flop cutoffs below which a pooled ctx degrades to serial: pool
/// dispatch + barrier overhead swamps the kernel on small problems
/// (the C-mirror sweep behind `BENCH_linalg.json` shows pooled
/// Cholesky losing to serial through n=512, and GEMM only breaking
/// even near 160³). Values are flops of the respective kernel:
/// 2·m·n·k (GEMM), n³/3 (Cholesky), n²·w (solves), p²·b (diag_quad).
const GEMM_PAR_MIN_FLOPS: f64 = 8e6;
const CHOL_PAR_MIN_FLOPS: f64 = 1.5e8;
const SOLVE_PAR_MIN_FLOPS: f64 = 1e6;
const QUAD_PAR_MIN_FLOPS: f64 = 2e6;

thread_local! {
    // Test hook: pooled-≡-serial bitwise tests must exercise the real
    // fan-out at test-sized problems, which the cutoffs would silently
    // de-parallelize.
    static NO_CUTOFF: Cell<bool> = const { Cell::new(false) };
}

/// RAII guard disabling the small-problem serial cutoffs on this
/// thread (test hook; see `NO_CUTOFF`).
pub(crate) struct CutoffGuard {
    prev: bool,
}

impl Drop for CutoffGuard {
    fn drop(&mut self) {
        NO_CUTOFF.with(|c| c.set(self.prev));
    }
}

pub(crate) fn disable_small_cutoff() -> CutoffGuard {
    CutoffGuard { prev: NO_CUTOFF.with(|c| c.replace(true)) }
}

/// The ctx a kernel should actually run on: the caller's, or its
/// serial view when the problem is below the pool-worthwhile cutoff.
/// Purely a scheduling decision — banding invariance makes the result
/// bitwise-identical either way.
fn effective(ctx: &LinalgCtx, flops: f64, min_flops: f64) -> LinalgCtx {
    if ctx.is_pooled()
        && flops < min_flops
        && !NO_CUTOFF.with(|c| c.get())
    {
        ctx.serial_view()
    } else {
        ctx.clone()
    }
}

/// `C ±= A·B` — the blocked, row-band-parallel accumulation core
/// behind [`gemm`] and the factorization updates (`SUB` subtracts).
///
/// Fan-out happens once per `KC` k-block; each row-band job packs its
/// own copy of the current B tile into a job-local buffer and sweeps
/// all `NC` column tiles. The duplicated packing costs <1% of the
/// band's flops and removes both the serialized shared pack and the
/// per-tile barrier of the previous structure (the 1→2 thread scaling
/// limiter on the dev host). The SIMD tier is resolved here, on the
/// calling thread, and captured into the jobs.
pub(crate) fn gemm_acc<const SUB: bool>(
    ctx: &LinalgCtx,
    a: &Mat,
    b: &Mat,
    c: &mut Mat,
) {
    assert_eq!(
        a.cols, b.rows,
        "gemm: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm: C shape");
    let (m, kdim, n) = (a.rows, a.cols, b.cols);
    if m == 0 || kdim == 0 || n == 0 {
        return;
    }
    let flops = 2.0 * m as f64 * n as f64 * kdim as f64;
    let ctx = effective(ctx, flops, GEMM_PAR_MIN_FLOPS);
    let tier = simd::active_tier();
    let ranges = ctx.ranges(m, 16);
    let mut kb = 0;
    while kb < kdim {
        let kc = KC.min(kdim - kb);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(ranges.len());
        let mut rest: &mut [f64] = &mut c.data[..];
        for &(lo, hi) in &ranges {
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut((hi - lo) * n);
            rest = tail;
            jobs.push(Box::new(move || {
                let mut packed = vec![0.0f64; kc * NC.min(n)];
                let arows: Vec<&[f64]> = (lo..hi)
                    .map(|i| &a.data[i * kdim + kb..i * kdim + kb + kc])
                    .collect();
                let mut jb = 0;
                while jb < n {
                    let nc = NC.min(n - jb);
                    for kk in 0..kc {
                        let base = (kb + kk) * n + jb;
                        packed[kk * nc..kk * nc + nc]
                            .copy_from_slice(&b.data[base..base + nc]);
                    }
                    let b_rows: Vec<&[f64]> =
                        packed[..kc * nc].chunks(nc).collect();
                    let mut crows: Vec<&mut [f64]> = chunk
                        .chunks_mut(n)
                        .map(|row| &mut row[jb..jb + nc])
                        .collect();
                    simd::band_kernel::<SUB>(
                        tier, &arows, &mut crows, &b_rows, kc, nc,
                    );
                    jb += nc;
                }
            }));
        }
        ctx.run_jobs(jobs);
        kb += kc;
    }
}

/// C = A · B, blocked and (optionally) pooled. Serial execution is
/// bitwise-identical to the seed scalar kernel; pooled execution is
/// bitwise-identical to serial.
pub fn gemm(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc::<false>(ctx, a, b, &mut c);
    c
}

/// C = Aᵀ · B (A stored untransposed) via one tiled transpose + the
/// [`gemm`] fast path.
pub fn gemm_tn(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.rows, b.rows,
        "gemm_tn: {}x{}ᵀ · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    gemm(ctx, &a.transpose(), b)
}

/// C = A · Bᵀ (B stored untransposed) via one tiled transpose + the
/// [`gemm`] fast path.
pub fn gemm_nt(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "gemm_nt: {}x{} · {}x{}ᵀ",
        a.rows, a.cols, b.rows, b.cols
    );
    gemm(ctx, a, &b.transpose())
}

/// Blocked right-looking Cholesky: POTRF diagonal block + parallel
/// TRSM panel + pooled SYRK/GEMM trailing update. Agrees with the
/// scalar [`super::cholesky::cholesky_scalar`] to ≤1e-10 on
/// well-conditioned SPD inputs; pooled ≡ serial bitwise.
pub fn cholesky_blocked(ctx: &LinalgCtx, a: &Mat) -> Result<Mat, NotSpd> {
    assert!(a.is_square(), "cholesky of non-square");
    let n = a.rows;
    let flops = (n as f64).powi(3) / 3.0;
    let ctx = &effective(ctx, flops, CHOL_PAR_MIN_FLOPS);
    let tier = simd::active_tier();
    let trail_band = if ctx.workers() > 1 {
        TRAIL_BAND_POOLED
    } else {
        TRAIL_BAND
    };
    let mut l = a.clone();
    let nb_step = ctx.block.max(4);
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb_step).min(n);
        // POTRF on the diagonal block (scalar Banachiewicz over the
        // block; earlier blocks' contributions were already subtracted
        // by the trailing updates below).
        for i in k0..k1 {
            for j in k0..=i {
                let s = dot(&l.row(i)[k0..j], &l.row(j)[k0..j]);
                if i == j {
                    let v = l[(i, i)] - s;
                    if v <= 0.0 || !v.is_finite() {
                        return Err(NotSpd { pivot: i, value: v });
                    }
                    l[(i, i)] = v.sqrt();
                } else {
                    let denom = l[(j, j)];
                    l[(i, j)] = (l[(i, j)] - s) / denom;
                }
            }
        }
        if k1 == n {
            break;
        }
        let p = n - k1;
        let nb = k1 - k0;
        // TRSM panel: solve X·L11ᵀ = A21 row-wise (rows independent →
        // row bands on the pool).
        {
            let (head, tail) = l.data.split_at_mut(k1 * n);
            let diag: &[f64] = head;
            let mut prows: Vec<&mut [f64]> =
                tail.chunks_mut(n).map(|row| &mut row[k0..k1]).collect();
            let chunk = ctx.ranges(p, 8)[0].1;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for band in prows.chunks_mut(chunk) {
                jobs.push(Box::new(move || {
                    for xr in band.iter_mut() {
                        let x = &mut **xr;
                        for j in 0..nb {
                            let lrow = &diag
                                [(k0 + j) * n + k0..(k0 + j) * n + k0 + j];
                            let s = dot(&x[..j], lrow);
                            x[j] = (x[j] - s) / diag[(k0 + j) * n + k0 + j];
                        }
                    }
                }));
            }
            ctx.run_jobs(jobs);
        }
        // Copy the solved panel out (X, p×nb) and transpose it once so
        // the trailing update streams both operands at unit stride.
        let mut xp = Mat::zeros(p, nb);
        for r in 0..p {
            xp.row_mut(r).copy_from_slice(&l.row(k1 + r)[k0..k1]);
        }
        let xt = xp.transpose(); // nb × p
        // Trailing update: A22 -= X·Xᵀ, banded over rows. Each band
        // updates the rectangle [band rows] × [k1 .. k1+band_hi] that
        // covers its slice of the lower triangle; the strictly-upper
        // overshoot is zeroed after the loop and never read.
        {
            let bt_rows: Vec<&[f64]> = xt.data.chunks(p).collect();
            let mut rest: &mut [f64] = &mut l.data[k1 * n..];
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut lo = 0;
            while lo < p {
                let hi = (lo + trail_band).min(p);
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * n);
                rest = tail;
                let mut crows: Vec<&mut [f64]> = chunk
                    .chunks_mut(n)
                    .map(|row| &mut row[k1..k1 + hi])
                    .collect();
                let arows: Vec<&[f64]> = (lo..hi).map(|r| xp.row(r)).collect();
                let br = &bt_rows;
                jobs.push(Box::new(move || {
                    simd::band_kernel::<true>(
                        tier, &arows, &mut crows, br, nb, hi,
                    );
                }));
                lo = hi;
            }
            ctx.run_jobs(jobs);
        }
        k0 = k1;
    }
    // Zero the strictly-upper triangle (trailing-band overshoot).
    for i in 0..n {
        for j in (i + 1)..n {
            l[(i, j)] = 0.0;
        }
    }
    Ok(l)
}

/// Solve L·Y = B (matrix RHS), blocked, parallel over column bands of
/// B (columns of a triangular solve are independent).
pub fn solve_lower_mat_ctx(ctx: &LinalgCtx, l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n, "solve_lower_mat: rhs rows");
    let mut y = b.clone();
    let w = b.cols;
    if n == 0 || w == 0 {
        return y;
    }
    let flops = (n * n * w) as f64;
    let ctx = &effective(ctx, flops, SOLVE_PAR_MIN_FLOPS);
    let tier = simd::active_tier();
    let nb_step = ctx.block.max(4);
    let col_ranges = ctx.ranges(w, 8);
    {
        let band_rows = split_column_bands(&mut y.data, w, &col_ranges);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(band_rows.len());
        for rows in band_rows {
            jobs.push(Box::new(move || {
                forward_solve_band(tier, l, rows, nb_step)
            }));
        }
        ctx.run_jobs(jobs);
    }
    y
}

/// Solve Lᵀ·X = Y (matrix RHS), blocked, parallel over column bands.
pub fn solve_upper_t_mat_ctx(ctx: &LinalgCtx, l: &Mat, y: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(y.rows, n, "solve_upper_t_mat: rhs rows");
    let mut x = y.clone();
    let w = y.cols;
    if n == 0 || w == 0 {
        return x;
    }
    let flops = (n * n * w) as f64;
    let ctx = &effective(ctx, flops, SOLVE_PAR_MIN_FLOPS);
    let tier = simd::active_tier();
    let nb_step = ctx.block.max(4);
    let col_ranges = ctx.ranges(w, 8);
    {
        let band_rows = split_column_bands(&mut x.data, w, &col_ranges);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(band_rows.len());
        for rows in band_rows {
            jobs.push(Box::new(move || {
                backward_solve_band(tier, l, rows, nb_step)
            }));
        }
        ctx.run_jobs(jobs);
    }
    x
}

/// Solve (L·Lᵀ)·X = B (matrix RHS) through the blocked solves.
pub fn cho_solve_mat_ctx(ctx: &LinalgCtx, l: &Mat, b: &Mat) -> Mat {
    solve_upper_t_mat_ctx(ctx, l, &solve_lower_mat_ctx(ctx, l, b))
}

/// `C = A · B` written into a caller-owned output (shape-checked,
/// zeroed first) — the allocation-free sibling of [`gemm`] for hot
/// loops that reuse one scratch matrix across calls (the serve path's
/// per-batch feature build). Identical numbers to [`gemm`].
pub fn gemm_into(ctx: &LinalgCtx, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm_into: C shape");
    c.data.fill(0.0);
    gemm_acc::<false>(ctx, a, b, c);
}

/// k-tile depth for [`diag_quad_ctx`]: rows of A visited per pass,
/// sized so a tile's upper-triangular slice (≤ `QUAD_KT`·p doubles)
/// stays L2-resident while every output row streams over it.
const QUAD_KT: usize = 64;

/// Fused `diag(G · A · Gᵀ)` for **symmetric** A — the serve-path
/// variance kernel: `out[i] = gᵢᵀ A gᵢ` for each row gᵢ of G (b×p),
/// without materializing the b×p intermediate `G·A`.
///
/// # Scheme
///
/// Symmetry halves the flops: `gᵀAg = Σₖ g_k·(A_kk·g_k +
/// 2·Σ_{l>k} A_kl·g_l)`, so only A's upper triangle is read. The k
/// loop over A's rows is tiled (`QUAD_KT` = 64 rows per pass) so the
/// tile's triangle stays cache-resident while every row of G in the
/// band streams over it — A is read once per *band*, not once per
/// output row (the naive row-at-a-time loop re-streams all p² of A
/// from DRAM for every query once p² exceeds the L2). Output rows
/// fan out over the ctx's pool in disjoint bands, so pooled execution
/// is bitwise-identical to serial (the [`LinalgCtx`] guarantee); each
/// row's accumulation order is fixed by (k-tile, k, l) alone.
///
/// Cost: p²·b flops (vs 2·p²·b for the two triangular solves it
/// replaces — and at streaming-dot rate rather than substitution
/// rate). Requires A symmetric (only the upper triangle is read);
/// `b = 1` degenerates to a single quadratic form.
pub fn diag_quad_ctx(ctx: &LinalgCtx, g: &Mat, a: &Mat) -> Vec<f64> {
    let mut out = vec![0.0; g.rows];
    diag_quad_into(ctx, g, a, &mut out);
    out
}

/// [`diag_quad_ctx`] writing into a caller-owned output slice (the
/// allocation-free serve-path entry; `out.len()` must equal `g.rows`).
pub fn diag_quad_into(ctx: &LinalgCtx, g: &Mat, a: &Mat, out: &mut [f64]) {
    let p = g.cols;
    assert!(a.is_square(), "diag_quad: A must be square");
    assert_eq!(a.rows, p, "diag_quad: A is {}x{}, G cols {p}", a.rows, a.cols);
    assert_eq!(out.len(), g.rows, "diag_quad: out length");
    let b = g.rows;
    if b == 0 {
        return;
    }
    out.fill(0.0);
    if p == 0 {
        return;
    }
    let flops = (p * p * b) as f64;
    let ctx = &effective(ctx, flops, QUAD_PAR_MIN_FLOPS);
    let ranges = ctx.ranges(b, 8);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(ranges.len());
    let mut rest: &mut [f64] = out;
    for &(lo, hi) in &ranges {
        let (band, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
        rest = tail;
        jobs.push(Box::new(move || {
            let mut k0 = 0;
            while k0 < p {
                let k1 = (k0 + QUAD_KT).min(p);
                for (r, acc) in band.iter_mut().enumerate() {
                    let gi = g.row(lo + r);
                    let mut s = 0.0;
                    for k in k0..k1 {
                        let gk = gi[k];
                        // upper-triangular row slice A[k, k..p]
                        let arow = &a.data[k * p + k..(k + 1) * p];
                        let t = dot(&arow[1..], &gi[k + 1..]);
                        s += gk * (arow[0] * gk + 2.0 * t);
                    }
                    *acc += s;
                }
                k0 = k1;
            }
        }));
    }
    ctx.run_jobs(jobs);
}

/// Split a row-major buffer of `w`-wide rows into per-column-band row
/// windows: result[band] holds every row's `[c0..c1)` slice.
fn split_column_bands<'a>(
    data: &'a mut [f64],
    w: usize,
    col_ranges: &[(usize, usize)],
) -> Vec<Vec<&'a mut [f64]>> {
    let nrows = data.len() / w;
    let mut out: Vec<Vec<&'a mut [f64]>> = col_ranges
        .iter()
        .map(|_| Vec::with_capacity(nrows))
        .collect();
    for row in data.chunks_mut(w) {
        let mut row: &mut [f64] = row;
        for (bi, &(c0, c1)) in col_ranges.iter().enumerate() {
            let (win, tail) =
                std::mem::take(&mut row).split_at_mut(c1 - c0);
            row = tail;
            out[bi].push(win);
        }
    }
    out
}

/// Blocked forward substitution on one column band (rows = the band's
/// windows of Y, in matrix row order).
fn forward_solve_band(
    tier: SimdTier,
    l: &Mat,
    mut rows: Vec<&mut [f64]>,
    nb_step: usize,
) {
    let n = l.rows;
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb_step).min(n);
        // Diagonal block: plain forward substitution.
        for i in k0..k1 {
            let (head, tail) = rows.split_at_mut(i);
            let yi = &mut *tail[0];
            for (j, yj) in head.iter().enumerate().take(i).skip(k0) {
                let lij = l[(i, j)];
                if lij != 0.0 {
                    axpy(-lij, yj, yi);
                }
            }
            let d = l[(i, i)];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        if k1 == n {
            break;
        }
        // Trailing update: Y[k1.., :] -= L[k1.., k0..k1] · Y[k0..k1, :].
        let (solved, below) = rows.split_at_mut(k1);
        let brows: Vec<&[f64]> =
            solved[k0..k1].iter().map(|r| &**r).collect();
        let arows: Vec<&[f64]> =
            (k1..n).map(|i| &l.data[i * n + k0..i * n + k1]).collect();
        let nc = below.first().map(|r| r.len()).unwrap_or(0);
        simd::band_kernel::<true>(tier, &arows, below, &brows, k1 - k0, nc);
        k0 = k1;
    }
}

/// Blocked backward substitution (Lᵀ·X = Y) on one column band.
fn backward_solve_band(
    tier: SimdTier,
    l: &Mat,
    mut rows: Vec<&mut [f64]>,
    nb_step: usize,
) {
    let n = l.rows;
    debug_assert!(n > 0);
    let mut k0 = (n - 1) / nb_step * nb_step; // last block start
    loop {
        let k1 = (k0 + nb_step).min(n);
        let p = n - k1;
        if p > 0 {
            // X[k0..k1, :] -= L[k1.., k0..k1]ᵀ · X[k1.., :]. Pack the
            // Lᵀ block once (nb × p) so the kernel streams it.
            let nb = k1 - k0;
            let mut lt = vec![0.0f64; nb * p];
            for kk in 0..p {
                let lrow = &l.data[(k1 + kk) * n + k0..(k1 + kk) * n + k1];
                for (il, &v) in lrow.iter().enumerate() {
                    lt[il * p + kk] = v;
                }
            }
            let (active, below) = rows.split_at_mut(k1);
            let brows: Vec<&[f64]> = below.iter().map(|r| &**r).collect();
            let arows: Vec<&[f64]> = lt.chunks(p).collect();
            let cband = &mut active[k0..k1];
            let nc = cband.first().map(|r| r.len()).unwrap_or(0);
            simd::band_kernel::<true>(tier, &arows, cband, &brows, p, nc);
        }
        // Diagonal block back-substitution.
        for i in (k0..k1).rev() {
            let (head, tail) = rows.split_at_mut(i + 1);
            let xi = &mut *head[i];
            for j in (i + 1)..k1 {
                let lji = l[(j, i)];
                if lji != 0.0 {
                    axpy(-lji, &*tail[j - i - 1], xi);
                }
            }
            let d = l[(i, i)];
            for v in xi.iter_mut() {
                *v /= d;
            }
        }
        if k0 == 0 {
            break;
        }
        k0 -= nb_step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt_scalar, matmul_scalar,
                                matmul_tn_scalar};
    use crate::linalg::cholesky::{cholesky_scalar, solve_lower_mat_scalar,
                                  solve_upper_t_mat_scalar};
    use crate::testkit::prop::{prop_check, Gen};
    use crate::util::pool::ThreadPool;
    use std::sync::Arc;

    fn rand_mat(g: &mut Gen, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c))
    }

    fn seeded_mat(rng: &mut crate::util::Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, rng.normals(r * c))
    }

    fn rand_spd(g: &mut Gen, n: usize) -> Mat {
        let a = rand_mat(g, n, n);
        let mut spd = gemm_nt(&LinalgCtx::serial(), &a, &a);
        spd.add_diag(n as f64 + 1.0);
        spd
    }

    fn pooled_ctx(workers: usize) -> LinalgCtx {
        LinalgCtx::pooled(Arc::new(ThreadPool::new(workers)))
    }

    /// Under the Portable tier, serial blocked GEMM is bitwise-equal to
    /// the seed scalar kernel — the strongest form of the ≤1e-10
    /// acceptance bar (the `PGPR_SIMD=portable` contract).
    #[test]
    fn gemm_bitwise_matches_scalar_matmul() {
        let _t = simd::force_tier(SimdTier::Portable);
        prop_check("gemm-bitwise-scalar", 12, |g| {
            let (m, k, n) =
                (g.usize_in(1, 70), g.usize_in(1, 401), g.usize_in(1, 70));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            let blocked = gemm(&LinalgCtx::serial(), &a, &b);
            let scalar = matmul_scalar(&a, &b);
            assert_eq!(blocked, scalar, "m={m} k={k} n={n}");
        });
    }

    /// Pooled GEMM is bitwise-equal to serial at every thread count,
    /// under every supported SIMD tier (the cutoff guard keeps
    /// test-sized problems on the real fan-out path).
    #[test]
    fn gemm_pooled_bitwise_matches_serial() {
        let _c = disable_small_cutoff();
        for tier in SimdTier::available() {
            let _t = simd::force_tier(tier);
            prop_check(&format!("gemm-pooled-{}", tier.name()), 4, |g| {
                let (m, k, n) =
                    (g.usize_in(1, 90), g.usize_in(1, 220), g.usize_in(1, 90));
                let a = rand_mat(g, m, k);
                let b = rand_mat(g, k, n);
                let serial = gemm(&LinalgCtx::serial(), &a, &b);
                for workers in [2, 4] {
                    let pooled = gemm(&pooled_ctx(workers), &a, &b);
                    assert_eq!(
                        serial, pooled,
                        "tier={} workers={workers}",
                        tier.name()
                    );
                }
            });
        }
    }

    /// Awkward shapes: sizes straddling the KC/NC tile edges and the
    /// 1×n / n×1 degenerate cases. Portable is bitwise vs the scalar
    /// kernel; AVX tiers stay within reassociation tolerance on the
    /// same shapes (their 8-wide column tails and row remainders all
    /// get exercised here).
    #[test]
    fn gemm_awkward_shapes() {
        let ctx = LinalgCtx::serial();
        let mut g = crate::util::Pcg64::seed(77);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 300, 1),
            (1, 5, 257),
            (257, 3, 1),
            (2, 193, 255),
            (3, 192, 256),
            (5, 191, 257),
        ] {
            let a = seeded_mat(&mut g, m, k);
            let b = seeded_mat(&mut g, k, n);
            let scalar = matmul_scalar(&a, &b);
            for tier in SimdTier::available() {
                let _t = simd::force_tier(tier);
                let got = gemm(&ctx, &a, &b);
                if tier == SimdTier::Portable {
                    assert_eq!(got, scalar, "m={m} k={k} n={n}");
                } else {
                    assert!(
                        got.max_abs_diff(&scalar) < 1e-11 * (k as f64),
                        "tier={} m={m} k={k} n={n}",
                        tier.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_tn_nt_match_scalar_variants() {
        prop_check("gemm-tn-nt", 10, |g| {
            let (m, k, n) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let ctx = LinalgCtx::serial();
            let at = rand_mat(g, k, m); // used as Aᵀ
            let b = rand_mat(g, k, n);
            let tn = gemm_tn(&ctx, &at, &b);
            assert!(tn.max_abs_diff(&matmul_tn_scalar(&at, &b)) < 1e-12);
            let c = rand_mat(g, m, k);
            let d = rand_mat(g, n, k);
            let nt = gemm_nt(&ctx, &c, &d);
            assert!(nt.max_abs_diff(&matmul_nt_scalar(&c, &d)) < 1e-12);
        });
    }

    #[test]
    fn cholesky_blocked_matches_scalar() {
        prop_check("chol-blocked-scalar", 10, |g| {
            let n = g.usize_in(1, 150);
            let a = rand_spd(g, n);
            let blocked = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            assert!(blocked.max_abs_diff(&scalar) < 1e-10, "n={n}");
            // strictly-upper stays exactly zero despite band overshoot
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(blocked[(i, j)], 0.0);
                }
            }
        });
    }

    /// Pooled Cholesky ≡ serial bitwise under every supported tier —
    /// despite the pooled path also using the finer TRAIL_BAND_POOLED
    /// banding (band size never changes element values).
    #[test]
    fn cholesky_blocked_pooled_bitwise_matches_serial() {
        let _c = disable_small_cutoff();
        for tier in SimdTier::available() {
            let _t = simd::force_tier(tier);
            prop_check(&format!("chol-pooled-{}", tier.name()), 3, |g| {
                let n = g.usize_in(2, 180);
                let a = rand_spd(g, n);
                let serial =
                    cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
                let pooled = cholesky_blocked(&pooled_ctx(3), &a).unwrap();
                assert_eq!(serial, pooled, "tier={} n={n}", tier.name());
            });
        }
    }

    /// Sizes that are not multiples of the block, with a small block so
    /// several panel steps run; plus the 1×1 edge.
    #[test]
    fn cholesky_blocked_awkward_sizes() {
        let mut g = crate::util::Pcg64::seed(5);
        for &n in &[1usize, 2, 3, 7, 63, 65, 97, 130] {
            let base = seeded_mat(&mut g, n, n);
            let mut a = gemm_nt(&LinalgCtx::serial(), &base, &base);
            a.add_diag(n as f64 + 1.0);
            let ctx = LinalgCtx::serial().with_block(24);
            let blocked = cholesky_blocked(&ctx, &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            assert!(blocked.max_abs_diff(&scalar) < 1e-10, "n={n}");
        }
    }

    /// Jittered Hilbert-like (near-singular SPD) matrices: blocked and
    /// scalar factors agree within the conditioning-limited tolerance,
    /// and both recompose A.
    #[test]
    fn cholesky_blocked_near_singular_hilbert() {
        prop_check("chol-hilbert", 8, |g| {
            let n = g.usize_in(2, 48);
            let jitter = 10f64.powi(-(g.usize_in(4, 8) as i32));
            let mut a = Mat::from_fn(n, n, |i, j| 1.0 / ((i + j + 1) as f64));
            a.add_diag(jitter);
            let ctx = LinalgCtx::serial().with_block(16);
            let blocked = cholesky_blocked(&ctx, &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            // factors agree to conditioning-limited precision…
            assert!(blocked.max_abs_diff(&scalar) < 1e-8,
                    "n={n} jitter={jitter:.0e}");
            // …and both recompose A tightly
            let ctxs = LinalgCtx::serial();
            assert!(gemm_nt(&ctxs, &blocked, &blocked).max_abs_diff(&a)
                    < 1e-10);
            assert!(gemm_nt(&ctxs, &scalar, &scalar).max_abs_diff(&a)
                    < 1e-10);
        });
    }

    #[test]
    fn cholesky_blocked_rejects_non_spd() {
        let mut a = Mat::identity(100);
        a[(70, 70)] = -2.0;
        let err = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap_err();
        assert_eq!(err.pivot, 70);
        assert!(err.value < 0.0);
    }

    #[test]
    fn blocked_solves_match_scalar() {
        prop_check("solves-blocked-scalar", 10, |g| {
            let n = g.usize_in(1, 120);
            let w = g.usize_in(1, 40);
            let a = rand_spd(g, n);
            let l = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let b = rand_mat(g, n, w);
            let ctx = LinalgCtx::serial().with_block(32);
            let lo = solve_lower_mat_ctx(&ctx, &l, &b);
            assert!(lo.max_abs_diff(&solve_lower_mat_scalar(&l, &b)) < 1e-10);
            let up = solve_upper_t_mat_ctx(&ctx, &l, &b);
            assert!(up.max_abs_diff(&solve_upper_t_mat_scalar(&l, &b))
                    < 1e-10);
            // full cho_solve residual
            let x = cho_solve_mat_ctx(&ctx, &l, &b);
            let r = gemm(&LinalgCtx::serial(), &a, &x);
            assert!(r.max_abs_diff(&b) < 1e-8, "n={n} w={w}");
        });
    }

    #[test]
    fn blocked_solves_pooled_bitwise_match_serial() {
        let _c = disable_small_cutoff();
        prop_check("solves-pooled-serial", 5, |g| {
            let n = g.usize_in(2, 100);
            let w = g.usize_in(2, 64);
            let a = rand_spd(g, n);
            let l = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let b = rand_mat(g, n, w);
            let serial = LinalgCtx::serial();
            let pooled = pooled_ctx(3);
            assert_eq!(solve_lower_mat_ctx(&serial, &l, &b),
                       solve_lower_mat_ctx(&pooled, &l, &b));
            assert_eq!(solve_upper_t_mat_ctx(&serial, &l, &b),
                       solve_upper_t_mat_ctx(&pooled, &l, &b));
        });
    }

    /// Naive triple-loop reference: out[i] = Σ_{k,l} g_ik A_kl g_il.
    fn diag_quad_naive(g: &Mat, a: &Mat) -> Vec<f64> {
        (0..g.rows)
            .map(|i| {
                let gi = g.row(i);
                let mut s = 0.0;
                for k in 0..a.rows {
                    for l in 0..a.cols {
                        s += gi[k] * a[(k, l)] * gi[l];
                    }
                }
                s
            })
            .collect()
    }

    fn rand_sym(g: &mut Gen, p: usize) -> Mat {
        let mut a = rand_mat(g, p, p);
        a.symmetrize();
        a
    }

    /// Property test pinning the fused kernel to the naive triple loop,
    /// over shapes straddling the QUAD_KT tile edge.
    #[test]
    fn diag_quad_matches_naive_triple_loop() {
        prop_check("diag-quad-naive", 12, |g| {
            let b = g.usize_in(1, 40);
            let p = g.usize_in(1, 150);
            let gm = rand_mat(g, b, p);
            let a = rand_sym(g, p);
            let got = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            let want = diag_quad_naive(&gm, &a);
            for (x, y) in got.iter().zip(want.iter()) {
                let tol = 1e-11 * y.abs().max(1.0);
                assert!((x - y).abs() < tol, "b={b} p={p}: {x} vs {y}");
            }
        });
    }

    /// Awkward shapes: the b=1 degenerate, p exactly at / straddling
    /// the QUAD_KT=64 tile boundary, and p=1.
    #[test]
    fn diag_quad_awkward_shapes() {
        let mut g = crate::util::Pcg64::seed(23);
        for &(b, p) in &[
            (1usize, 1usize),
            (1, 63),
            (1, 64),
            (1, 65),
            (1, 500),
            (7, 128),
            (3, 129),
            (40, 1),
            (2, 191),
        ] {
            let gm = seeded_mat(&mut g, b, p);
            let mut a = seeded_mat(&mut g, p, p);
            a.symmetrize();
            let got = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            let want = diag_quad_naive(&gm, &a);
            for (x, y) in got.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-10 * y.abs().max(1.0),
                        "b={b} p={p}");
            }
        }
    }

    /// Pooled fused diag is bitwise-identical to serial (row bands are
    /// element-disjoint; per-row accumulation order is band-invariant).
    #[test]
    fn diag_quad_pooled_bitwise_matches_serial() {
        let _c = disable_small_cutoff();
        prop_check("diag-quad-pooled", 6, |g| {
            let b = g.usize_in(1, 60);
            let p = g.usize_in(1, 120);
            let gm = rand_mat(g, b, p);
            let a = rand_sym(g, p);
            let serial = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            for workers in [2, 4] {
                let pooled = diag_quad_ctx(&pooled_ctx(workers), &gm, &a);
                assert_eq!(serial, pooled, "workers={workers}");
            }
        });
    }

    /// gemm_into reuses a caller buffer and reproduces gemm exactly,
    /// including when the buffer held stale garbage.
    #[test]
    fn gemm_into_matches_gemm_and_clears_stale() {
        let mut g = crate::util::Pcg64::seed(77);
        let ctx = LinalgCtx::serial();
        let a = seeded_mat(&mut g, 13, 29);
        let b = seeded_mat(&mut g, 29, 17);
        let want = gemm(&ctx, &a, &b);
        let mut c = seeded_mat(&mut g, 13, 17); // stale contents
        gemm_into(&ctx, &a, &b, &mut c);
        assert_eq!(c, want);
    }

    /// The tier matrix (satellite of the SIMD PR): every supported
    /// tier through GEMM, Cholesky, both triangular solves and the
    /// fused diag-quad. Portable must be bitwise-equal to the scalar
    /// seed references where those are bitwise contracts, and every
    /// AVX tier must stay within reassociation tolerance of the
    /// Portable tier on identical inputs.
    #[test]
    fn tier_matrix_all_kernels_equivalent() {
        let mut g = crate::util::Pcg64::seed(321);
        // shapes straddle the 8-wide column blocks, the 4/8 row blocks
        // and the KC edge
        let a = seeded_mat(&mut g, 37, 201);
        let b = seeded_mat(&mut g, 201, 43);
        let base = seeded_mat(&mut g, 131, 131);
        let mut spd = gemm_nt(&LinalgCtx::serial(), &base, &base);
        spd.add_diag(132.0);
        let rhs = seeded_mat(&mut g, 131, 19);
        let (g_ref, l_ref, y_ref, x_ref) = {
            let _t = simd::force_tier(SimdTier::Portable);
            let ctx = LinalgCtx::serial();
            let l = cholesky_blocked(&ctx, &spd).unwrap();
            let y = solve_lower_mat_ctx(&ctx, &l, &rhs);
            let x = solve_upper_t_mat_ctx(&ctx, &l, &rhs);
            (gemm(&ctx, &a, &b), l, y, x)
        };
        for tier in SimdTier::available() {
            let _t = simd::force_tier(tier);
            let ctx = LinalgCtx::serial();
            let gm = gemm(&ctx, &a, &b);
            let l = cholesky_blocked(&ctx, &spd).unwrap();
            let y = solve_lower_mat_ctx(&ctx, &l_ref, &rhs);
            let x = solve_upper_t_mat_ctx(&ctx, &l_ref, &rhs);
            if tier == SimdTier::Portable {
                assert_eq!(gm, g_ref);
                assert_eq!(l, l_ref);
                assert_eq!(y, y_ref);
                assert_eq!(x, x_ref);
            } else {
                let name = tier.name();
                assert!(gm.max_abs_diff(&g_ref) < 1e-9, "{name} gemm");
                assert!(l.max_abs_diff(&l_ref) < 1e-9, "{name} chol");
                assert!(y.max_abs_diff(&y_ref) < 1e-9, "{name} fwd");
                assert!(x.max_abs_diff(&x_ref) < 1e-9, "{name} bwd");
            }
        }
    }

    /// The small-problem cutoff is scheduling-only: a pooled ctx below
    /// the GEMM flop threshold must give bitwise-identical results to
    /// both the serial path and a cutoff-disabled pooled run.
    #[test]
    fn small_problem_cutoff_is_bitwise_invisible() {
        let mut g = crate::util::Pcg64::seed(9);
        let a = seeded_mat(&mut g, 40, 50); // 2·40·50·30 = 2.4e5 flops
        let b = seeded_mat(&mut g, 50, 30);
        let serial = gemm(&LinalgCtx::serial(), &a, &b);
        let pooled = pooled_ctx(2);
        let with_cutoff = gemm(&pooled, &a, &b);
        let without = {
            let _c = disable_small_cutoff();
            gemm(&pooled, &a, &b)
        };
        assert_eq!(serial, with_cutoff);
        assert_eq!(serial, without);
    }

    /// A ctx whose pool is "hidden" (call from a worker of the same
    /// pool) must fall back to serial and still give exact results.
    #[test]
    fn nested_call_from_worker_degrades_to_serial() {
        let pool = Arc::new(ThreadPool::new(2));
        let ctx = LinalgCtx::pooled(Arc::clone(&pool));
        let mut g = crate::util::Pcg64::seed(42);
        let a = seeded_mat(&mut g, 33, 47);
        let b = seeded_mat(&mut g, 47, 29);
        let want = gemm(&LinalgCtx::serial(), &a, &b);
        let got = pool.par_map(1, move |_| gemm(&ctx, &a, &b));
        assert_eq!(got[0], want);
    }
}
