//! The cache-blocked, optionally thread-parallel dense engine that every
//! GP hot path routes through (via the [`LinalgCtx`] knobs).
//!
//! # Scheme
//!
//! **GEMM** (`gemm`/`gemm_tn`/`gemm_nt`): C = A·B is tiled over
//! (`KC`=192)-deep k-blocks × (`NC`=256)-wide column tiles of B. Each
//! B tile is packed into a contiguous buffer (so the innermost loop
//! streams it at unit stride regardless of the source leading
//! dimension), then row bands of C fan out over the pool. The
//! microloop processes **two C rows × four packed B rows** per pass —
//! the shape that measured fastest on the dev host (≈2.1–2.6× the
//! seed's streaming i-k-j kernel at 1024², see `BENCH_linalg.json`):
//! two output rows reuse every B load and four k-steps amortize each
//! C-row load/store, which is exactly what the seed kernel (reloading
//! C and B from L3 on every pass) lacked. The transposed variants
//! reuse the same fast path through one tiled transpose.
//!
//! **Cholesky** (`cholesky_blocked`): right-looking — scalar POTRF on
//! the `ctx.block`-sized diagonal block, a row-parallel TRSM panel,
//! then the trailing SYRK update `A₂₂ -= X·Xᵀ` executed as banded GEMM
//! calls on the pool (each band updates the rectangle covering its
//! part of the lower triangle; overshoot lands in the strictly-upper
//! half, which is zeroed at the end and never read). The triangular
//! solves (`solve_lower_mat_ctx`/`solve_upper_t_mat_ctx`) parallelize
//! over *column* bands of the right-hand side — columns of a
//! triangular solve are independent — with the same blocked kernel
//! inside each band.
//!
//! # Equivalence contracts (tested)
//!
//! * Serial `gemm` reproduces the seed scalar `matmul` **bitwise**: the
//!   k-blocking (`KC` a multiple of 4) preserves the scalar kernel's
//!   4-wide grouping and per-element expression exactly.
//! * Pooled runs reproduce serial runs **bitwise** for every kernel:
//!   parallelism only partitions disjoint output bands (see
//!   [`LinalgCtx`]); band boundaries never change any element's
//!   instruction sequence.
//! * Factorizations/solves agree with the scalar reference
//!   implementations to ≤1e-10 on well-conditioned inputs (different
//!   but equally stable summation orders).

use super::cholesky::NotSpd;
use super::ctx::LinalgCtx;
use super::{axpy, dot, Mat};

/// k-block depth. Must stay a multiple of 4: it aligns the packed
/// panel with the scalar kernel's 4-wide k-grouping, which is what
/// makes serial `gemm` bitwise-equal to the seed `matmul`.
const KC: usize = 192;

/// Column-tile width of the packed B panel (KC×NC ≈ 384 KiB of f64
/// stays L2-resident on anything this runs on).
const NC: usize = 256;

/// Row-band height for the Cholesky trailing update. Kept fixed (and
/// modest) rather than derived from the worker count so the
/// rectangle-per-band overshoot above the diagonal stays small in both
/// serial and pooled runs.
const TRAIL_BAND: usize = 96;

/// One C row: `c[j] ±= (a · B)[j]` over a `kc`-deep, `nc`-wide tile.
/// `SUB` selects subtraction at compile time (a runtime ±1 multiplier
/// measurably costs ~20% GEMM throughput). Mirrors the seed kernel's
/// expression exactly (including the zero-skip on the k remainder).
fn band_kernel_row<const SUB: bool>(
    a0: &[f64],
    c0: &mut [f64],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    let c0 = &mut c0[..nc];
    let mut kk = 0;
    while kk + 4 <= kc {
        let (p0, p1, p2, p3) = (a0[kk], a0[kk + 1], a0[kk + 2], a0[kk + 3]);
        let b0 = &b_rows[kk][..nc];
        let b1 = &b_rows[kk + 1][..nc];
        let b2 = &b_rows[kk + 2][..nc];
        let b3 = &b_rows[kk + 3][..nc];
        for j in 0..nc {
            let t = p0 * b0[j] + p1 * b1[j] + p2 * b2[j] + p3 * b3[j];
            if SUB {
                c0[j] -= t;
            } else {
                c0[j] += t;
            }
        }
        kk += 4;
    }
    while kk < kc {
        let p = a0[kk];
        if p != 0.0 {
            let brow = &b_rows[kk][..nc];
            for j in 0..nc {
                let t = p * brow[j];
                if SUB {
                    c0[j] -= t;
                } else {
                    c0[j] += t;
                }
            }
        }
        kk += 1;
    }
}

/// The microloop: `c_rows[r] ±= a_rows[r] · B` over a tile, two C rows
/// at a time (each B load feeds both rows; four k-steps amortize each
/// C access). `b_rows[kk]` is packed row kk of the tile.
fn band_kernel<const SUB: bool>(
    a_rows: &[&[f64]],
    c_rows: &mut [&mut [f64]],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    debug_assert_eq!(a_rows.len(), c_rows.len());
    debug_assert!(b_rows.len() >= kc);
    let rows = c_rows.len();
    let mut r = 0;
    while r + 2 <= rows {
        let (head, tail) = c_rows.split_at_mut(r + 1);
        let c0 = &mut head[r][..nc];
        let c1 = &mut tail[0][..nc];
        let a0 = a_rows[r];
        let a1 = a_rows[r + 1];
        let mut kk = 0;
        while kk + 4 <= kc {
            let (p0, p1, p2, p3) =
                (a0[kk], a0[kk + 1], a0[kk + 2], a0[kk + 3]);
            let (q0, q1, q2, q3) =
                (a1[kk], a1[kk + 1], a1[kk + 2], a1[kk + 3]);
            let b0 = &b_rows[kk][..nc];
            let b1 = &b_rows[kk + 1][..nc];
            let b2 = &b_rows[kk + 2][..nc];
            let b3 = &b_rows[kk + 3][..nc];
            for j in 0..nc {
                let (v0, v1, v2, v3) = (b0[j], b1[j], b2[j], b3[j]);
                let t0 = p0 * v0 + p1 * v1 + p2 * v2 + p3 * v3;
                let t1 = q0 * v0 + q1 * v1 + q2 * v2 + q3 * v3;
                if SUB {
                    c0[j] -= t0;
                    c1[j] -= t1;
                } else {
                    c0[j] += t0;
                    c1[j] += t1;
                }
            }
            kk += 4;
        }
        while kk < kc {
            let (p, q) = (a0[kk], a1[kk]);
            let brow = &b_rows[kk][..nc];
            if p != 0.0 {
                for j in 0..nc {
                    let t = p * brow[j];
                    if SUB {
                        c0[j] -= t;
                    } else {
                        c0[j] += t;
                    }
                }
            }
            if q != 0.0 {
                for j in 0..nc {
                    let t = q * brow[j];
                    if SUB {
                        c1[j] -= t;
                    } else {
                        c1[j] += t;
                    }
                }
            }
            kk += 1;
        }
        r += 2;
    }
    if r < rows {
        band_kernel_row::<SUB>(a_rows[r], &mut *c_rows[r], b_rows, kc, nc);
    }
}

/// `C ±= A·B` — the blocked, row-band-parallel accumulation core
/// behind [`gemm`] and the factorization updates (`SUB` subtracts).
pub(crate) fn gemm_acc<const SUB: bool>(
    ctx: &LinalgCtx,
    a: &Mat,
    b: &Mat,
    c: &mut Mat,
) {
    assert_eq!(
        a.cols, b.rows,
        "gemm: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm: C shape");
    let (m, kdim, n) = (a.rows, a.cols, b.cols);
    if m == 0 || kdim == 0 || n == 0 {
        return;
    }
    let ranges = ctx.ranges(m, 16);
    let mut packed = vec![0.0f64; KC.min(kdim) * NC.min(n)];
    let mut kb = 0;
    while kb < kdim {
        let kc = KC.min(kdim - kb);
        let mut jb = 0;
        while jb < n {
            let nc = NC.min(n - jb);
            for kk in 0..kc {
                let base = (kb + kk) * n + jb;
                packed[kk * nc..kk * nc + nc]
                    .copy_from_slice(&b.data[base..base + nc]);
            }
            let b_rows: Vec<&[f64]> = packed[..kc * nc].chunks(nc).collect();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(ranges.len());
            let mut rest: &mut [f64] = &mut c.data[..];
            for &(lo, hi) in &ranges {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * n);
                rest = tail;
                let mut crows: Vec<&mut [f64]> = chunk
                    .chunks_mut(n)
                    .map(|row| &mut row[jb..jb + nc])
                    .collect();
                let arows: Vec<&[f64]> = (lo..hi)
                    .map(|i| &a.data[i * kdim + kb..i * kdim + kb + kc])
                    .collect();
                let br = &b_rows;
                jobs.push(Box::new(move || {
                    band_kernel::<SUB>(&arows, &mut crows, br, kc, nc);
                }));
            }
            ctx.run_jobs(jobs);
            jb += nc;
        }
        kb += kc;
    }
}

/// C = A · B, blocked and (optionally) pooled. Serial execution is
/// bitwise-identical to the seed scalar kernel; pooled execution is
/// bitwise-identical to serial.
pub fn gemm(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc::<false>(ctx, a, b, &mut c);
    c
}

/// C = Aᵀ · B (A stored untransposed) via one tiled transpose + the
/// [`gemm`] fast path.
pub fn gemm_tn(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.rows, b.rows,
        "gemm_tn: {}x{}ᵀ · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    gemm(ctx, &a.transpose(), b)
}

/// C = A · Bᵀ (B stored untransposed) via one tiled transpose + the
/// [`gemm`] fast path.
pub fn gemm_nt(ctx: &LinalgCtx, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "gemm_nt: {}x{} · {}x{}ᵀ",
        a.rows, a.cols, b.rows, b.cols
    );
    gemm(ctx, a, &b.transpose())
}

/// Blocked right-looking Cholesky: POTRF diagonal block + parallel
/// TRSM panel + pooled SYRK/GEMM trailing update. Agrees with the
/// scalar [`super::cholesky::cholesky_scalar`] to ≤1e-10 on
/// well-conditioned SPD inputs; pooled ≡ serial bitwise.
pub fn cholesky_blocked(ctx: &LinalgCtx, a: &Mat) -> Result<Mat, NotSpd> {
    assert!(a.is_square(), "cholesky of non-square");
    let n = a.rows;
    let mut l = a.clone();
    let nb_step = ctx.block.max(4);
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb_step).min(n);
        // POTRF on the diagonal block (scalar Banachiewicz over the
        // block; earlier blocks' contributions were already subtracted
        // by the trailing updates below).
        for i in k0..k1 {
            for j in k0..=i {
                let s = dot(&l.row(i)[k0..j], &l.row(j)[k0..j]);
                if i == j {
                    let v = l[(i, i)] - s;
                    if v <= 0.0 || !v.is_finite() {
                        return Err(NotSpd { pivot: i, value: v });
                    }
                    l[(i, i)] = v.sqrt();
                } else {
                    let denom = l[(j, j)];
                    l[(i, j)] = (l[(i, j)] - s) / denom;
                }
            }
        }
        if k1 == n {
            break;
        }
        let p = n - k1;
        let nb = k1 - k0;
        // TRSM panel: solve X·L11ᵀ = A21 row-wise (rows independent →
        // row bands on the pool).
        {
            let (head, tail) = l.data.split_at_mut(k1 * n);
            let diag: &[f64] = head;
            let mut prows: Vec<&mut [f64]> =
                tail.chunks_mut(n).map(|row| &mut row[k0..k1]).collect();
            let chunk = ctx.ranges(p, 8)[0].1;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for band in prows.chunks_mut(chunk) {
                jobs.push(Box::new(move || {
                    for xr in band.iter_mut() {
                        let x = &mut **xr;
                        for j in 0..nb {
                            let lrow = &diag
                                [(k0 + j) * n + k0..(k0 + j) * n + k0 + j];
                            let s = dot(&x[..j], lrow);
                            x[j] = (x[j] - s) / diag[(k0 + j) * n + k0 + j];
                        }
                    }
                }));
            }
            ctx.run_jobs(jobs);
        }
        // Copy the solved panel out (X, p×nb) and transpose it once so
        // the trailing update streams both operands at unit stride.
        let mut xp = Mat::zeros(p, nb);
        for r in 0..p {
            xp.row_mut(r).copy_from_slice(&l.row(k1 + r)[k0..k1]);
        }
        let xt = xp.transpose(); // nb × p
        // Trailing update: A22 -= X·Xᵀ, banded over rows. Each band
        // updates the rectangle [band rows] × [k1 .. k1+band_hi] that
        // covers its slice of the lower triangle; the strictly-upper
        // overshoot is zeroed after the loop and never read.
        {
            let bt_rows: Vec<&[f64]> = xt.data.chunks(p).collect();
            let mut rest: &mut [f64] = &mut l.data[k1 * n..];
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut lo = 0;
            while lo < p {
                let hi = (lo + TRAIL_BAND).min(p);
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * n);
                rest = tail;
                let mut crows: Vec<&mut [f64]> = chunk
                    .chunks_mut(n)
                    .map(|row| &mut row[k1..k1 + hi])
                    .collect();
                let arows: Vec<&[f64]> = (lo..hi).map(|r| xp.row(r)).collect();
                let br = &bt_rows;
                jobs.push(Box::new(move || {
                    band_kernel::<true>(&arows, &mut crows, br, nb, hi);
                }));
                lo = hi;
            }
            ctx.run_jobs(jobs);
        }
        k0 = k1;
    }
    // Zero the strictly-upper triangle (trailing-band overshoot).
    for i in 0..n {
        for j in (i + 1)..n {
            l[(i, j)] = 0.0;
        }
    }
    Ok(l)
}

/// Solve L·Y = B (matrix RHS), blocked, parallel over column bands of
/// B (columns of a triangular solve are independent).
pub fn solve_lower_mat_ctx(ctx: &LinalgCtx, l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n, "solve_lower_mat: rhs rows");
    let mut y = b.clone();
    let w = b.cols;
    if n == 0 || w == 0 {
        return y;
    }
    let nb_step = ctx.block.max(4);
    let col_ranges = ctx.ranges(w, 8);
    {
        let band_rows = split_column_bands(&mut y.data, w, &col_ranges);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(band_rows.len());
        for rows in band_rows {
            jobs.push(Box::new(move || forward_solve_band(l, rows, nb_step)));
        }
        ctx.run_jobs(jobs);
    }
    y
}

/// Solve Lᵀ·X = Y (matrix RHS), blocked, parallel over column bands.
pub fn solve_upper_t_mat_ctx(ctx: &LinalgCtx, l: &Mat, y: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(y.rows, n, "solve_upper_t_mat: rhs rows");
    let mut x = y.clone();
    let w = y.cols;
    if n == 0 || w == 0 {
        return x;
    }
    let nb_step = ctx.block.max(4);
    let col_ranges = ctx.ranges(w, 8);
    {
        let band_rows = split_column_bands(&mut x.data, w, &col_ranges);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(band_rows.len());
        for rows in band_rows {
            jobs.push(Box::new(move || backward_solve_band(l, rows, nb_step)));
        }
        ctx.run_jobs(jobs);
    }
    x
}

/// Solve (L·Lᵀ)·X = B (matrix RHS) through the blocked solves.
pub fn cho_solve_mat_ctx(ctx: &LinalgCtx, l: &Mat, b: &Mat) -> Mat {
    solve_upper_t_mat_ctx(ctx, l, &solve_lower_mat_ctx(ctx, l, b))
}

/// `C = A · B` written into a caller-owned output (shape-checked,
/// zeroed first) — the allocation-free sibling of [`gemm`] for hot
/// loops that reuse one scratch matrix across calls (the serve path's
/// per-batch feature build). Identical numbers to [`gemm`].
pub fn gemm_into(ctx: &LinalgCtx, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm_into: C shape");
    c.data.fill(0.0);
    gemm_acc::<false>(ctx, a, b, c);
}

/// k-tile depth for [`diag_quad_ctx`]: rows of A visited per pass,
/// sized so a tile's upper-triangular slice (≤ `QUAD_KT`·p doubles)
/// stays L2-resident while every output row streams over it.
const QUAD_KT: usize = 64;

/// Fused `diag(G · A · Gᵀ)` for **symmetric** A — the serve-path
/// variance kernel: `out[i] = gᵢᵀ A gᵢ` for each row gᵢ of G (b×p),
/// without materializing the b×p intermediate `G·A`.
///
/// # Scheme
///
/// Symmetry halves the flops: `gᵀAg = Σₖ g_k·(A_kk·g_k +
/// 2·Σ_{l>k} A_kl·g_l)`, so only A's upper triangle is read. The k
/// loop over A's rows is tiled (`QUAD_KT` = 64 rows per pass) so the
/// tile's triangle stays cache-resident while every row of G in the
/// band streams over it — A is read once per *band*, not once per
/// output row (the naive row-at-a-time loop re-streams all p² of A
/// from DRAM for every query once p² exceeds the L2). Output rows
/// fan out over the ctx's pool in disjoint bands, so pooled execution
/// is bitwise-identical to serial (the [`LinalgCtx`] guarantee); each
/// row's accumulation order is fixed by (k-tile, k, l) alone.
///
/// Cost: p²·b flops (vs 2·p²·b for the two triangular solves it
/// replaces — and at streaming-dot rate rather than substitution
/// rate). Requires A symmetric (only the upper triangle is read);
/// `b = 1` degenerates to a single quadratic form.
pub fn diag_quad_ctx(ctx: &LinalgCtx, g: &Mat, a: &Mat) -> Vec<f64> {
    let mut out = vec![0.0; g.rows];
    diag_quad_into(ctx, g, a, &mut out);
    out
}

/// [`diag_quad_ctx`] writing into a caller-owned output slice (the
/// allocation-free serve-path entry; `out.len()` must equal `g.rows`).
pub fn diag_quad_into(ctx: &LinalgCtx, g: &Mat, a: &Mat, out: &mut [f64]) {
    let p = g.cols;
    assert!(a.is_square(), "diag_quad: A must be square");
    assert_eq!(a.rows, p, "diag_quad: A is {}x{}, G cols {p}", a.rows, a.cols);
    assert_eq!(out.len(), g.rows, "diag_quad: out length");
    let b = g.rows;
    if b == 0 {
        return;
    }
    out.fill(0.0);
    if p == 0 {
        return;
    }
    let ranges = ctx.ranges(b, 8);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(ranges.len());
    let mut rest: &mut [f64] = out;
    for &(lo, hi) in &ranges {
        let (band, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
        rest = tail;
        jobs.push(Box::new(move || {
            let mut k0 = 0;
            while k0 < p {
                let k1 = (k0 + QUAD_KT).min(p);
                for (r, acc) in band.iter_mut().enumerate() {
                    let gi = g.row(lo + r);
                    let mut s = 0.0;
                    for k in k0..k1 {
                        let gk = gi[k];
                        // upper-triangular row slice A[k, k..p]
                        let arow = &a.data[k * p + k..(k + 1) * p];
                        let t = dot(&arow[1..], &gi[k + 1..]);
                        s += gk * (arow[0] * gk + 2.0 * t);
                    }
                    *acc += s;
                }
                k0 = k1;
            }
        }));
    }
    ctx.run_jobs(jobs);
}

/// Split a row-major buffer of `w`-wide rows into per-column-band row
/// windows: result[band] holds every row's `[c0..c1)` slice.
fn split_column_bands<'a>(
    data: &'a mut [f64],
    w: usize,
    col_ranges: &[(usize, usize)],
) -> Vec<Vec<&'a mut [f64]>> {
    let nrows = data.len() / w;
    let mut out: Vec<Vec<&'a mut [f64]>> = col_ranges
        .iter()
        .map(|_| Vec::with_capacity(nrows))
        .collect();
    for row in data.chunks_mut(w) {
        let mut row: &mut [f64] = row;
        for (bi, &(c0, c1)) in col_ranges.iter().enumerate() {
            let (win, tail) =
                std::mem::take(&mut row).split_at_mut(c1 - c0);
            row = tail;
            out[bi].push(win);
        }
    }
    out
}

/// Blocked forward substitution on one column band (rows = the band's
/// windows of Y, in matrix row order).
fn forward_solve_band(l: &Mat, mut rows: Vec<&mut [f64]>, nb_step: usize) {
    let n = l.rows;
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb_step).min(n);
        // Diagonal block: plain forward substitution.
        for i in k0..k1 {
            let (head, tail) = rows.split_at_mut(i);
            let yi = &mut *tail[0];
            for (j, yj) in head.iter().enumerate().take(i).skip(k0) {
                let lij = l[(i, j)];
                if lij != 0.0 {
                    axpy(-lij, yj, yi);
                }
            }
            let d = l[(i, i)];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        if k1 == n {
            break;
        }
        // Trailing update: Y[k1.., :] -= L[k1.., k0..k1] · Y[k0..k1, :].
        let (solved, below) = rows.split_at_mut(k1);
        let brows: Vec<&[f64]> =
            solved[k0..k1].iter().map(|r| &**r).collect();
        let arows: Vec<&[f64]> =
            (k1..n).map(|i| &l.data[i * n + k0..i * n + k1]).collect();
        let nc = below.first().map(|r| r.len()).unwrap_or(0);
        band_kernel::<true>(&arows, below, &brows, k1 - k0, nc);
        k0 = k1;
    }
}

/// Blocked backward substitution (Lᵀ·X = Y) on one column band.
fn backward_solve_band(l: &Mat, mut rows: Vec<&mut [f64]>, nb_step: usize) {
    let n = l.rows;
    debug_assert!(n > 0);
    let mut k0 = (n - 1) / nb_step * nb_step; // last block start
    loop {
        let k1 = (k0 + nb_step).min(n);
        let p = n - k1;
        if p > 0 {
            // X[k0..k1, :] -= L[k1.., k0..k1]ᵀ · X[k1.., :]. Pack the
            // Lᵀ block once (nb × p) so the kernel streams it.
            let nb = k1 - k0;
            let mut lt = vec![0.0f64; nb * p];
            for kk in 0..p {
                let lrow = &l.data[(k1 + kk) * n + k0..(k1 + kk) * n + k1];
                for (il, &v) in lrow.iter().enumerate() {
                    lt[il * p + kk] = v;
                }
            }
            let (active, below) = rows.split_at_mut(k1);
            let brows: Vec<&[f64]> = below.iter().map(|r| &**r).collect();
            let arows: Vec<&[f64]> = lt.chunks(p).collect();
            let cband = &mut active[k0..k1];
            let nc = cband.first().map(|r| r.len()).unwrap_or(0);
            band_kernel::<true>(&arows, cband, &brows, p, nc);
        }
        // Diagonal block back-substitution.
        for i in (k0..k1).rev() {
            let (head, tail) = rows.split_at_mut(i + 1);
            let xi = &mut *head[i];
            for j in (i + 1)..k1 {
                let lji = l[(j, i)];
                if lji != 0.0 {
                    axpy(-lji, &*tail[j - i - 1], xi);
                }
            }
            let d = l[(i, i)];
            for v in xi.iter_mut() {
                *v /= d;
            }
        }
        if k0 == 0 {
            break;
        }
        k0 -= nb_step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt_scalar, matmul_scalar,
                                matmul_tn_scalar};
    use crate::linalg::cholesky::{cholesky_scalar, solve_lower_mat_scalar,
                                  solve_upper_t_mat_scalar};
    use crate::testkit::prop::{prop_check, Gen};
    use crate::util::pool::ThreadPool;
    use std::sync::Arc;

    fn rand_mat(g: &mut Gen, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c))
    }

    fn seeded_mat(rng: &mut crate::util::Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, rng.normals(r * c))
    }

    fn rand_spd(g: &mut Gen, n: usize) -> Mat {
        let a = rand_mat(g, n, n);
        let mut spd = gemm_nt(&LinalgCtx::serial(), &a, &a);
        spd.add_diag(n as f64 + 1.0);
        spd
    }

    fn pooled_ctx(workers: usize) -> LinalgCtx {
        LinalgCtx::pooled(Arc::new(ThreadPool::new(workers)))
    }

    /// Serial blocked GEMM is bitwise-equal to the seed scalar kernel —
    /// the strongest form of the ≤1e-10 acceptance bar.
    #[test]
    fn gemm_bitwise_matches_scalar_matmul() {
        prop_check("gemm-bitwise-scalar", 12, |g| {
            let (m, k, n) =
                (g.usize_in(1, 70), g.usize_in(1, 401), g.usize_in(1, 70));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            let blocked = gemm(&LinalgCtx::serial(), &a, &b);
            let scalar = matmul_scalar(&a, &b);
            assert_eq!(blocked, scalar, "m={m} k={k} n={n}");
        });
    }

    /// Pooled GEMM is bitwise-equal to serial at every thread count.
    #[test]
    fn gemm_pooled_bitwise_matches_serial() {
        prop_check("gemm-pooled-serial", 6, |g| {
            let (m, k, n) =
                (g.usize_in(1, 90), g.usize_in(1, 220), g.usize_in(1, 90));
            let a = rand_mat(g, m, k);
            let b = rand_mat(g, k, n);
            let serial = gemm(&LinalgCtx::serial(), &a, &b);
            for workers in [2, 4] {
                let pooled = gemm(&pooled_ctx(workers), &a, &b);
                assert_eq!(serial, pooled, "workers={workers}");
            }
        });
    }

    /// Awkward shapes: sizes straddling the KC/NC tile edges and the
    /// 1×n / n×1 degenerate cases.
    #[test]
    fn gemm_awkward_shapes() {
        let ctx = LinalgCtx::serial();
        let mut g = crate::util::Pcg64::seed(77);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 300, 1),
            (1, 5, 257),
            (257, 3, 1),
            (2, 193, 255),
            (3, 192, 256),
            (5, 191, 257),
        ] {
            let a = seeded_mat(&mut g, m, k);
            let b = seeded_mat(&mut g, k, n);
            assert_eq!(gemm(&ctx, &a, &b), matmul_scalar(&a, &b),
                       "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_nt_match_scalar_variants() {
        prop_check("gemm-tn-nt", 10, |g| {
            let (m, k, n) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let ctx = LinalgCtx::serial();
            let at = rand_mat(g, k, m); // used as Aᵀ
            let b = rand_mat(g, k, n);
            let tn = gemm_tn(&ctx, &at, &b);
            assert!(tn.max_abs_diff(&matmul_tn_scalar(&at, &b)) < 1e-12);
            let c = rand_mat(g, m, k);
            let d = rand_mat(g, n, k);
            let nt = gemm_nt(&ctx, &c, &d);
            assert!(nt.max_abs_diff(&matmul_nt_scalar(&c, &d)) < 1e-12);
        });
    }

    #[test]
    fn cholesky_blocked_matches_scalar() {
        prop_check("chol-blocked-scalar", 10, |g| {
            let n = g.usize_in(1, 150);
            let a = rand_spd(g, n);
            let blocked = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            assert!(blocked.max_abs_diff(&scalar) < 1e-10, "n={n}");
            // strictly-upper stays exactly zero despite band overshoot
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(blocked[(i, j)], 0.0);
                }
            }
        });
    }

    #[test]
    fn cholesky_blocked_pooled_bitwise_matches_serial() {
        prop_check("chol-pooled-serial", 5, |g| {
            let n = g.usize_in(2, 180);
            let a = rand_spd(g, n);
            let serial = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let pooled = cholesky_blocked(&pooled_ctx(3), &a).unwrap();
            assert_eq!(serial, pooled, "n={n}");
        });
    }

    /// Sizes that are not multiples of the block, with a small block so
    /// several panel steps run; plus the 1×1 edge.
    #[test]
    fn cholesky_blocked_awkward_sizes() {
        let mut g = crate::util::Pcg64::seed(5);
        for &n in &[1usize, 2, 3, 7, 63, 65, 97, 130] {
            let base = seeded_mat(&mut g, n, n);
            let mut a = gemm_nt(&LinalgCtx::serial(), &base, &base);
            a.add_diag(n as f64 + 1.0);
            let ctx = LinalgCtx::serial().with_block(24);
            let blocked = cholesky_blocked(&ctx, &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            assert!(blocked.max_abs_diff(&scalar) < 1e-10, "n={n}");
        }
    }

    /// Jittered Hilbert-like (near-singular SPD) matrices: blocked and
    /// scalar factors agree within the conditioning-limited tolerance,
    /// and both recompose A.
    #[test]
    fn cholesky_blocked_near_singular_hilbert() {
        prop_check("chol-hilbert", 8, |g| {
            let n = g.usize_in(2, 48);
            let jitter = 10f64.powi(-(g.usize_in(4, 8) as i32));
            let mut a = Mat::from_fn(n, n, |i, j| 1.0 / ((i + j + 1) as f64));
            a.add_diag(jitter);
            let ctx = LinalgCtx::serial().with_block(16);
            let blocked = cholesky_blocked(&ctx, &a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            // factors agree to conditioning-limited precision…
            assert!(blocked.max_abs_diff(&scalar) < 1e-8,
                    "n={n} jitter={jitter:.0e}");
            // …and both recompose A tightly
            let ctxs = LinalgCtx::serial();
            assert!(gemm_nt(&ctxs, &blocked, &blocked).max_abs_diff(&a)
                    < 1e-10);
            assert!(gemm_nt(&ctxs, &scalar, &scalar).max_abs_diff(&a)
                    < 1e-10);
        });
    }

    #[test]
    fn cholesky_blocked_rejects_non_spd() {
        let mut a = Mat::identity(100);
        a[(70, 70)] = -2.0;
        let err = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap_err();
        assert_eq!(err.pivot, 70);
        assert!(err.value < 0.0);
    }

    #[test]
    fn blocked_solves_match_scalar() {
        prop_check("solves-blocked-scalar", 10, |g| {
            let n = g.usize_in(1, 120);
            let w = g.usize_in(1, 40);
            let a = rand_spd(g, n);
            let l = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let b = rand_mat(g, n, w);
            let ctx = LinalgCtx::serial().with_block(32);
            let lo = solve_lower_mat_ctx(&ctx, &l, &b);
            assert!(lo.max_abs_diff(&solve_lower_mat_scalar(&l, &b)) < 1e-10);
            let up = solve_upper_t_mat_ctx(&ctx, &l, &b);
            assert!(up.max_abs_diff(&solve_upper_t_mat_scalar(&l, &b))
                    < 1e-10);
            // full cho_solve residual
            let x = cho_solve_mat_ctx(&ctx, &l, &b);
            let r = gemm(&LinalgCtx::serial(), &a, &x);
            assert!(r.max_abs_diff(&b) < 1e-8, "n={n} w={w}");
        });
    }

    #[test]
    fn blocked_solves_pooled_bitwise_match_serial() {
        prop_check("solves-pooled-serial", 5, |g| {
            let n = g.usize_in(2, 100);
            let w = g.usize_in(2, 64);
            let a = rand_spd(g, n);
            let l = cholesky_blocked(&LinalgCtx::serial(), &a).unwrap();
            let b = rand_mat(g, n, w);
            let serial = LinalgCtx::serial();
            let pooled = pooled_ctx(3);
            assert_eq!(solve_lower_mat_ctx(&serial, &l, &b),
                       solve_lower_mat_ctx(&pooled, &l, &b));
            assert_eq!(solve_upper_t_mat_ctx(&serial, &l, &b),
                       solve_upper_t_mat_ctx(&pooled, &l, &b));
        });
    }

    /// Naive triple-loop reference: out[i] = Σ_{k,l} g_ik A_kl g_il.
    fn diag_quad_naive(g: &Mat, a: &Mat) -> Vec<f64> {
        (0..g.rows)
            .map(|i| {
                let gi = g.row(i);
                let mut s = 0.0;
                for k in 0..a.rows {
                    for l in 0..a.cols {
                        s += gi[k] * a[(k, l)] * gi[l];
                    }
                }
                s
            })
            .collect()
    }

    fn rand_sym(g: &mut Gen, p: usize) -> Mat {
        let mut a = rand_mat(g, p, p);
        a.symmetrize();
        a
    }

    /// Property test pinning the fused kernel to the naive triple loop,
    /// over shapes straddling the QUAD_KT tile edge.
    #[test]
    fn diag_quad_matches_naive_triple_loop() {
        prop_check("diag-quad-naive", 12, |g| {
            let b = g.usize_in(1, 40);
            let p = g.usize_in(1, 150);
            let gm = rand_mat(g, b, p);
            let a = rand_sym(g, p);
            let got = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            let want = diag_quad_naive(&gm, &a);
            for (x, y) in got.iter().zip(want.iter()) {
                let tol = 1e-11 * y.abs().max(1.0);
                assert!((x - y).abs() < tol, "b={b} p={p}: {x} vs {y}");
            }
        });
    }

    /// Awkward shapes: the b=1 degenerate, p exactly at / straddling
    /// the QUAD_KT=64 tile boundary, and p=1.
    #[test]
    fn diag_quad_awkward_shapes() {
        let mut g = crate::util::Pcg64::seed(23);
        for &(b, p) in &[
            (1usize, 1usize),
            (1, 63),
            (1, 64),
            (1, 65),
            (1, 500),
            (7, 128),
            (3, 129),
            (40, 1),
            (2, 191),
        ] {
            let gm = seeded_mat(&mut g, b, p);
            let mut a = seeded_mat(&mut g, p, p);
            a.symmetrize();
            let got = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            let want = diag_quad_naive(&gm, &a);
            for (x, y) in got.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-10 * y.abs().max(1.0),
                        "b={b} p={p}");
            }
        }
    }

    /// Pooled fused diag is bitwise-identical to serial (row bands are
    /// element-disjoint; per-row accumulation order is band-invariant).
    #[test]
    fn diag_quad_pooled_bitwise_matches_serial() {
        prop_check("diag-quad-pooled", 6, |g| {
            let b = g.usize_in(1, 60);
            let p = g.usize_in(1, 120);
            let gm = rand_mat(g, b, p);
            let a = rand_sym(g, p);
            let serial = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            for workers in [2, 4] {
                let pooled = diag_quad_ctx(&pooled_ctx(workers), &gm, &a);
                assert_eq!(serial, pooled, "workers={workers}");
            }
        });
    }

    /// gemm_into reuses a caller buffer and reproduces gemm exactly,
    /// including when the buffer held stale garbage.
    #[test]
    fn gemm_into_matches_gemm_and_clears_stale() {
        let mut g = crate::util::Pcg64::seed(77);
        let ctx = LinalgCtx::serial();
        let a = seeded_mat(&mut g, 13, 29);
        let b = seeded_mat(&mut g, 29, 17);
        let want = gemm(&ctx, &a, &b);
        let mut c = seeded_mat(&mut g, 13, 17); // stale contents
        gemm_into(&ctx, &a, &b, &mut c);
        assert_eq!(c, want);
    }

    /// A ctx whose pool is "hidden" (call from a worker of the same
    /// pool) must fall back to serial and still give exact results.
    #[test]
    fn nested_call_from_worker_degrades_to_serial() {
        let pool = Arc::new(ThreadPool::new(2));
        let ctx = LinalgCtx::pooled(Arc::clone(&pool));
        let mut g = crate::util::Pcg64::seed(42);
        let a = seeded_mat(&mut g, 33, 47);
        let b = seeded_mat(&mut g, 47, 29);
        let want = gemm(&LinalgCtx::serial(), &a, &b);
        let got = pool.par_map(1, move |_| gemm(&ctx, &a, &b));
        assert_eq!(got[0], want);
    }
}
