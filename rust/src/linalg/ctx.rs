//! [`LinalgCtx`] — the execution context every blocked kernel takes:
//! a factorization block size plus an optional [`ThreadPool`] handle.
//!
//! Callers choose serial or pooled execution *explicitly*: the ctx is
//! plumbed down from wherever the pool lives (e.g.
//! [`crate::cluster::ParallelExecutor::linalg_ctx`]) instead of any
//! global state. Two guarantees shape the design:
//!
//! 1. **Pool-nested calls degrade to serial.** When the calling thread
//!    is itself a worker of the ctx's pool (a simulated machine's math
//!    running under the cluster executor), [`LinalgCtx::pool`] returns
//!    `None` and kernels run inline — same-pool `run_batch` would
//!    deadlock (and asserts; see [`ThreadPool::run_batch`]).
//! 2. **Pooled ≡ serial, bitwise.** Parallelism only ever partitions
//!    *output* rows/columns into disjoint bands; every element is
//!    computed by the same instruction sequence whatever the band
//!    boundaries or worker count, so a pooled run reproduces the serial
//!    run exactly. The PR-1 executor-equivalence suite relies on this.

use std::sync::Arc;

use crate::util::pool::ThreadPool;

/// Default factorization block (POTRF/TRSM panel width). 64 keeps the
/// diagonal block + one packed panel column comfortably inside L1/L2
/// while the trailing GEMM update dominates the flops.
pub const DEFAULT_BLOCK: usize = 64;

/// Execution context for the blocked linalg engine: block size +
/// optional thread pool. Cheap to clone (the pool is shared via `Arc`).
#[derive(Clone)]
pub struct LinalgCtx {
    /// Factorization block size (Cholesky panel width). Must be > 0; a
    /// multiple of 4 preserves the GEMM microkernel's full-speed path.
    pub block: usize,
    pool: Option<Arc<ThreadPool>>,
}

impl Default for LinalgCtx {
    fn default() -> Self {
        LinalgCtx::serial()
    }
}

impl std::fmt::Debug for LinalgCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.pool {
            None => write!(f, "LinalgCtx::serial(block={})", self.block),
            Some(p) => write!(
                f,
                "LinalgCtx::pooled(block={}, workers={})",
                self.block,
                p.workers()
            ),
        }
    }
}

impl LinalgCtx {
    /// Serial execution, default block size.
    pub fn serial() -> LinalgCtx {
        LinalgCtx { block: DEFAULT_BLOCK, pool: None }
    }

    /// Pooled execution on an existing shared pool, default block size.
    pub fn pooled(pool: Arc<ThreadPool>) -> LinalgCtx {
        LinalgCtx { block: DEFAULT_BLOCK, pool: Some(pool) }
    }

    /// Builder-style block-size override.
    pub fn with_block(mut self, block: usize) -> LinalgCtx {
        assert!(block > 0, "LinalgCtx block must be > 0");
        self.block = block;
        self
    }

    /// A serial ctx with the same block size — the small-problem
    /// fallback behind the flop cutoffs in [`super::blocked`] (pool
    /// dispatch overhead swamps the kernel below a per-kernel size;
    /// results are bitwise-unchanged, only the fan-out is skipped).
    pub(crate) fn serial_view(&self) -> LinalgCtx {
        LinalgCtx { block: self.block, pool: None }
    }

    /// The pool to fan work out on — `None` when serial *or* when the
    /// calling thread is one of this pool's own workers (guarantee 1).
    pub fn pool(&self) -> Option<&ThreadPool> {
        match &self.pool {
            Some(p) if !p.is_worker() => Some(p),
            _ => None,
        }
    }

    /// True when a pool is attached (regardless of calling thread).
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Worker threads available to this ctx *from the calling thread*
    /// (1 when serial or when called from a worker of the same pool).
    pub fn workers(&self) -> usize {
        self.pool().map(|p| p.workers()).unwrap_or(1)
    }

    /// Run a batch of jobs: on the pool when available from this
    /// thread, inline (in order) otherwise. Jobs must write disjoint
    /// data; banded callers in [`super::blocked`] uphold guarantee 2.
    pub(crate) fn run_jobs<'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'env>>,
    ) {
        match self.pool() {
            Some(pool) if jobs.len() > 1 => {
                // sits inside measured kernels: one relaxed load when
                // telemetry is off (the pooled-vs-serial bench gate
                // doubles as the overhead assertion)
                if crate::obsv::enabled() {
                    crate::obsv::counter_add("linalg.pool_dispatches", 1);
                    crate::obsv::counter_add("linalg.pool_jobs",
                                             jobs.len() as u64);
                }
                pool.run_batch(jobs);
            }
            _ => {
                if crate::obsv::enabled() {
                    crate::obsv::counter_add("linalg.serial_dispatches", 1);
                }
                for job in jobs {
                    job();
                }
            }
        }
    }

    /// Split `n` units into ~equal contiguous ranges sized for this
    /// ctx's parallelism: one range when serial, about two per worker
    /// when pooled (never smaller than `min` units, to keep per-job
    /// work well above pool dispatch cost). Returns `(lo, hi)` pairs
    /// covering `0..n` exactly, in order.
    pub(crate) fn ranges(&self, n: usize, min: usize) -> Vec<(usize, usize)> {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.workers();
        let min = min.max(1);
        let target = if workers <= 1 { 1 } else { 2 * workers };
        let chunk = (n / target).max(min).max(1);
        let mut out = Vec::new();
        let mut lo = 0;
        while lo < n {
            let hi = (lo + chunk).min(n);
            out.push((lo, hi));
            lo = hi;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_ctx_has_no_pool() {
        let ctx = LinalgCtx::serial();
        assert!(ctx.pool().is_none());
        assert!(!ctx.is_pooled());
        assert_eq!(ctx.workers(), 1);
        assert_eq!(ctx.block, DEFAULT_BLOCK);
        assert_eq!(format!("{ctx:?}"), "LinalgCtx::serial(block=64)");
    }

    #[test]
    fn pooled_ctx_reports_pool() {
        let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        assert!(ctx.is_pooled());
        assert_eq!(ctx.workers(), 3);
        assert!(format!("{ctx:?}").contains("workers=3"));
    }

    #[test]
    fn pool_is_hidden_from_its_own_workers() {
        let pool = Arc::new(ThreadPool::new(2));
        let ctx = LinalgCtx::pooled(Arc::clone(&pool));
        assert!(ctx.pool().is_some(), "visible from the caller thread");
        let c = ctx.clone();
        let seen = pool.par_map(2, move |_| c.pool().is_some());
        assert_eq!(seen, vec![false, false], "hidden on worker threads");
    }

    #[test]
    fn ranges_cover_exactly() {
        let ctx = LinalgCtx::serial();
        assert_eq!(ctx.ranges(10, 1), vec![(0, 10)]);
        assert!(ctx.ranges(0, 4).is_empty());
        let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(2)));
        let r = ctx.ranges(100, 8);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "contiguous");
        }
        assert!(r.iter().all(|&(lo, hi)| hi - lo >= 8 || hi == 100));
    }

    #[test]
    fn run_jobs_inline_when_serial() {
        let ctx = LinalgCtx::serial();
        let mut hits = vec![false; 4];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = hits
                .iter_mut()
                .map(|h| {
                    let job: Box<dyn FnOnce() + Send + '_> =
                        Box::new(move || *h = true);
                    job
                })
                .collect();
            ctx.run_jobs(jobs);
        }
        assert_eq!(hits, vec![true; 4]);
    }

    #[test]
    fn with_block_overrides() {
        let ctx = LinalgCtx::serial().with_block(32);
        assert_eq!(ctx.block, 32);
    }
}
