//! Cholesky factorization and triangular solves.
//!
//! Mirrors the pure-HLO implementations in `python/compile/model.py` so
//! the native backend and the PJRT artifacts produce matching numbers.
//!
//! The public entry points route through the blocked engine
//! ([`super::blocked`]) with a serial [`super::LinalgCtx`]; pass a ctx
//! to `cholesky_blocked` / `solve_lower_mat_ctx` /
//! `solve_upper_t_mat_ctx` for pooled execution. The `*_scalar`
//! variants are the seed's unblocked kernels, kept as the numerical
//! reference (property-tested to ≤1e-10 agreement) and as the
//! `linalg_bench` baseline.

use super::blocked;
use super::ctx::LinalgCtx;
use super::{dot, Mat};

/// Error for a non-SPD input (reports the failing pivot).
#[derive(Debug, Clone, PartialEq)]
pub struct NotSpd {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not SPD: pivot {} = {:.3e}", self.pivot, self.value)
    }
}

impl std::error::Error for NotSpd {}

/// Lower Cholesky factor L with A = L·Lᵀ, via the blocked right-looking
/// engine (serial ctx). ≈2–3× the scalar kernel at 512²–1024².
pub fn cholesky(a: &Mat) -> Result<Mat, NotSpd> {
    blocked::cholesky_blocked(&LinalgCtx::serial(), a)
}

/// Seed scalar factorization (Cholesky–Banachiewicz, row-oriented):
/// fills L one row at a time; inner products run over contiguous row
/// prefixes. Reference implementation for the blocked engine.
pub fn cholesky_scalar(a: &Mat) -> Result<Mat, NotSpd> {
    assert!(a.is_square(), "cholesky of non-square");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let v = a[(i, i)] - s;
                if v <= 0.0 || !v.is_finite() {
                    return Err(NotSpd { pivot: i, value: v });
                }
                l[(i, j)] = v.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L·y = b (vector) by forward substitution.
pub fn solve_lower_vec(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let s = dot(&l.row(i)[..i], &y[..i]);
        y[i] = (b[i] - s) / l[(i, i)];
    }
    y
}

/// Solve Lᵀ·x = y (vector) by back substitution.
pub fn solve_upper_t_vec(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        // Lᵀ[i, j] = L[j, i] for j > i
        let mut s = 0.0;
        for j in (i + 1)..n {
            s += l[(j, i)] * x[j];
        }
        x[i] = (y[i] - s) / l[(i, i)];
    }
    x
}

/// Solve (L·Lᵀ)·x = b (vector).
pub fn cho_solve_vec(l: &Mat, b: &[f64]) -> Vec<f64> {
    solve_upper_t_vec(l, &solve_lower_vec(l, b))
}

/// Solve L·Y = B (matrix RHS) via the blocked engine (serial ctx).
pub fn solve_lower_mat(l: &Mat, b: &Mat) -> Mat {
    blocked::solve_lower_mat_ctx(&LinalgCtx::serial(), l, b)
}

/// Seed scalar L·Y = B forward substitution (row-wise), kept as the
/// blocked engine's reference.
pub fn solve_lower_mat_scalar(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n);
    let mut y = b.clone();
    for i in 0..n {
        // y[i,:] = (b[i,:] - L[i,:i]·y[:i,:]) / L[i,i]
        let (head, tail) = y.data.split_at_mut(i * y.cols);
        let yrow = &mut tail[..y.cols];
        for j in 0..i {
            let lij = l[(i, j)];
            if lij != 0.0 {
                let yj = &head[j * b.cols..(j + 1) * b.cols];
                for c in 0..b.cols {
                    yrow[c] -= lij * yj[c];
                }
            }
        }
        let d = l[(i, i)];
        for v in yrow.iter_mut() {
            *v /= d;
        }
    }
    y
}

/// Solve Lᵀ·X = Y (matrix RHS) via the blocked engine (serial ctx).
pub fn solve_upper_t_mat(l: &Mat, y: &Mat) -> Mat {
    blocked::solve_upper_t_mat_ctx(&LinalgCtx::serial(), l, y)
}

/// Seed scalar Lᵀ·X = Y back substitution, kept as the blocked
/// engine's reference.
pub fn solve_upper_t_mat_scalar(l: &Mat, y: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(y.rows, n);
    let mut x = y.clone();
    for i in (0..n).rev() {
        let (head, tail) = x.data.split_at_mut((i + 1) * x.cols);
        let xrow = &mut head[i * x.cols..];
        for j in (i + 1)..n {
            let lji = l[(j, i)];
            if lji != 0.0 {
                let xj = &tail[(j - i - 1) * y.cols..(j - i) * y.cols];
                for c in 0..y.cols {
                    xrow[c] -= lji * xj[c];
                }
            }
        }
        let d = l[(i, i)];
        for v in xrow.iter_mut() {
            *v /= d;
        }
    }
    x
}

/// Solve (L·Lᵀ)·X = B (matrix RHS).
pub fn cho_solve_mat(l: &Mat, b: &Mat) -> Mat {
    solve_upper_t_mat(l, &solve_lower_mat(l, b))
}

/// log det(A) from its Cholesky factor: 2·Σ log L[i,i].
pub fn logdet_from_chol(l: &Mat) -> f64 {
    (0..l.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, matvec};
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::{assert_all_close, max_abs_diff};

    fn rand_spd(g: &mut Gen, n: usize) -> Mat {
        let a = Mat::from_vec(n, n, g.normal_vec(n * n));
        let mut spd = matmul_nt(&a, &a);
        spd.add_diag(n as f64);
        spd
    }

    #[test]
    fn factor_recomposes() {
        prop_check("chol-recompose", 24, |g| {
            let n = g.usize_in(1, 16);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let back = matmul_nt(&l, &l);
            assert!(back.max_abs_diff(&a) < 1e-10, "n={n}");
            // lower-triangular structure
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        });
    }

    /// The blocked default agrees with the seed scalar factorization.
    #[test]
    fn blocked_default_matches_scalar() {
        prop_check("chol-default-scalar", 12, |g| {
            let n = g.usize_in(1, 90);
            let a = rand_spd(g, n);
            let blocked = cholesky(&a).unwrap();
            let scalar = cholesky_scalar(&a).unwrap();
            assert!(blocked.max_abs_diff(&scalar) < 1e-10, "n={n}");
        });
    }

    #[test]
    fn rejects_non_spd() {
        let mut a = Mat::identity(3);
        a[(2, 2)] = -1.0;
        let err = cholesky(&a).unwrap_err();
        assert_eq!(err.pivot, 2);
        let err_s = cholesky_scalar(&a).unwrap_err();
        assert_eq!(err_s.pivot, 2);
    }

    #[test]
    fn vec_solves_residual() {
        prop_check("chol-solve-vec", 24, |g| {
            let n = g.usize_in(1, 14);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let b = g.normal_vec(n);
            let x = cho_solve_vec(&l, &b);
            let r = matvec(&a, &x);
            assert_all_close(&r, &b, 1e-9, 1e-9);
        });
    }

    #[test]
    fn mat_solves_residual() {
        prop_check("chol-solve-mat", 16, |g| {
            let n = g.usize_in(1, 12);
            let k = g.usize_in(1, 6);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let b = Mat::from_vec(n, k, g.normal_vec(n * k));
            let x = cho_solve_mat(&l, &b);
            let r = matmul(&a, &x);
            assert!(r.max_abs_diff(&b) < 1e-9);
        });
    }

    /// The blocked mat solves agree with the seed scalar substitutions.
    #[test]
    fn mat_solves_match_scalar() {
        prop_check("solves-default-scalar", 12, |g| {
            let n = g.usize_in(1, 80);
            let k = g.usize_in(1, 20);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let b = Mat::from_vec(n, k, g.normal_vec(n * k));
            assert!(solve_lower_mat(&l, &b)
                .max_abs_diff(&solve_lower_mat_scalar(&l, &b)) < 1e-10);
            assert!(solve_upper_t_mat(&l, &b)
                .max_abs_diff(&solve_upper_t_mat_scalar(&l, &b)) < 1e-10);
        });
    }

    #[test]
    fn mat_and_vec_solves_agree() {
        prop_check("solve-consistency", 16, |g| {
            let n = g.usize_in(1, 10);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let b = g.normal_vec(n);
            let via_vec = cho_solve_vec(&l, &b);
            let via_mat = cho_solve_mat(&l, &Mat::from_vec(n, 1, b)).data;
            assert!(max_abs_diff(&via_vec, &via_mat) < 1e-12);
        });
    }

    #[test]
    fn triangular_solves_residuals() {
        prop_check("tri-solves", 16, |g| {
            let n = g.usize_in(1, 12);
            let a = rand_spd(g, n);
            let l = cholesky(&a).unwrap();
            let b = g.normal_vec(n);
            let y = solve_lower_vec(&l, &b);
            assert_all_close(&matvec(&l, &y), &b, 1e-10, 1e-10);
            let x = solve_upper_t_vec(&l, &b);
            let lt = l.transpose();
            assert_all_close(&matvec(&lt, &x), &b, 1e-10, 1e-10);
        });
    }

    #[test]
    fn logdet_matches_identity_scaling() {
        let mut a = Mat::identity(5);
        a.scale(4.0);
        let l = cholesky(&a).unwrap();
        let want = 5.0 * 4.0f64.ln();
        assert!((logdet_from_chol(&l) - want).abs() < 1e-12);
    }

    #[test]
    fn one_by_one() {
        let a = Mat::from_vec(1, 1, vec![9.0]);
        let l = cholesky(&a).unwrap();
        assert_eq!(l[(0, 0)], 3.0);
        assert_eq!(cho_solve_vec(&l, &[18.0]), vec![2.0]);
    }
}
