//! Classical multi-dimensional scaling (Torgerson MDS).
//!
//! The paper's AIMPEAK domain models a road network with a *relational*
//! GP; footnote 2 says the segment graph is embedded into Euclidean space
//! with MDS so the squared-exponential kernel applies. This module is
//! that embedding: distance matrix → double-centered Gram → top-k
//! eigenpairs → coordinates.

use super::eigen::sym_eigen;
use super::Mat;

/// Embed `n` points into `k` dimensions from their pairwise distances.
///
/// Returns an `n×k` coordinate matrix whose pairwise Euclidean distances
/// approximate `dist` (exactly, if `dist` is Euclidean of rank ≤ k).
/// Eigenvalues ≤ 0 (non-Euclidean directions) are dropped — their
/// coordinates are zero-filled.
pub fn classical_mds(dist: &Mat, k: usize) -> Mat {
    assert!(dist.is_square(), "mds: non-square distance matrix");
    let n = dist.rows;
    assert!(k >= 1);

    // B = -1/2 · J · D² · J,  J = I - 11ᵀ/n  (double centering)
    let mut d2 = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = dist[(i, j)];
            d2[(i, j)] = v * v;
        }
    }
    let row_mean: Vec<f64> =
        (0..n).map(|i| d2.row(i).iter().sum::<f64>() / n as f64).collect();
    let grand = row_mean.iter().sum::<f64>() / n as f64;
    let mut b = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] = -0.5 * (d2[(i, j)] - row_mean[i] - row_mean[j] + grand);
        }
    }

    let e = sym_eigen(&b);
    let mut coords = Mat::zeros(n, k);
    for c in 0..k.min(n) {
        let w = e.values[c];
        if w <= 0.0 {
            break; // descending order: the rest are non-Euclidean/noise
        }
        let s = w.sqrt();
        for r in 0..n {
            coords[(r, c)] = e.vectors[(r, c)] * s;
        }
    }
    coords
}

/// Pairwise Euclidean distance matrix of row-vector points.
pub fn pairwise_distances(points: &Mat) -> Mat {
    let n = points.rows;
    let mut d = Mat::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let mut s = 0.0;
            for c in 0..points.cols {
                let diff = points[(i, c)] - points[(j, c)];
                s += diff * diff;
            }
            let v = s.sqrt();
            d[(i, j)] = v;
            d[(j, i)] = v;
        }
    }
    d
}

/// Stress: relative Frobenius error between `dist` and the embedding's
/// pairwise distances. 0 = perfect.
pub fn stress(dist: &Mat, coords: &Mat) -> f64 {
    let recon = pairwise_distances(coords);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..dist.rows {
        for j in 0..dist.cols {
            let e = dist[(i, j)] - recon[(i, j)];
            num += e * e;
            den += dist[(i, j)] * dist[(i, j)];
        }
    }
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::prop_check;
    use crate::util::Pcg64;

    #[test]
    fn recovers_euclidean_configuration() {
        prop_check("mds-euclidean", 8, |g| {
            let n = g.usize_in(3, 12);
            let k = g.usize_in(1, 4);
            let pts = Mat::from_vec(n, k, g.normal_vec(n * k));
            let dist = pairwise_distances(&pts);
            let emb = classical_mds(&dist, k);
            assert!(stress(&dist, &emb) < 1e-7, "n={n} k={k}");
        });
    }

    #[test]
    fn embedding_shape() {
        let mut rng = Pcg64::seed(2);
        let pts = Mat::from_vec(6, 2, rng.normals(12));
        let dist = pairwise_distances(&pts);
        let emb = classical_mds(&dist, 4);
        assert_eq!((emb.rows, emb.cols), (6, 4));
    }

    #[test]
    fn lower_dim_embedding_reduces_but_bounded() {
        let mut rng = Pcg64::seed(3);
        let pts = Mat::from_vec(10, 3, rng.normals(30));
        let dist = pairwise_distances(&pts);
        let s3 = stress(&dist, &classical_mds(&dist, 3));
        let s1 = stress(&dist, &classical_mds(&dist, 1));
        assert!(s3 < 1e-7);
        assert!(s1 >= s3);
        assert!(s1 < 1.0);
    }

    #[test]
    fn non_euclidean_graph_distances_still_embed() {
        // path-graph hop distances (Euclidean in 1-D, actually)
        let n = 8;
        let dist = Mat::from_fn(n, n, |i, j| (i as f64 - j as f64).abs());
        let emb = classical_mds(&dist, 2);
        assert!(stress(&dist, &emb) < 1e-7);
    }

    #[test]
    fn degenerate_all_zero_distances() {
        let dist = Mat::zeros(5, 5);
        let emb = classical_mds(&dist, 2);
        assert!(emb.max_abs() < 1e-10);
        assert_eq!(stress(&dist, &emb), 0.0);
    }

    #[test]
    fn pairwise_distance_properties() {
        prop_check("pairwise-dist", 8, |g| {
            let n = g.usize_in(2, 10);
            let pts = Mat::from_vec(n, 3, g.normal_vec(n * 3));
            let d = pairwise_distances(&pts);
            for i in 0..n {
                assert_eq!(d[(i, i)], 0.0);
                for j in 0..n {
                    assert!((d[(i, j)] - d[(j, i)]).abs() < 1e-15);
                    assert!(d[(i, j)] >= 0.0);
                }
            }
        });
    }
}
