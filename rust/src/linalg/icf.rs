//! Row-based incomplete Cholesky factorization (ICF) with diagonal
//! pivoting — the low-rank handle of the paper's Section 4.
//!
//! Produces `F ∈ R^{R×n}` with `FᵀF ≈ K` for an SPD kernel matrix `K`
//! given *implicitly* by a row oracle, so the full `n×n` matrix is never
//! materialized (the paper's point: `R ≪ n`). Each iteration selects the
//! largest residual diagonal as pivot and fills one row of F — the
//! "row-based" scheme of Chang et al. (2007) that pICF distributes
//! column-block-wise across machines (see `parallel::picf`).

use super::ctx::LinalgCtx;
use super::Mat;

/// Source of kernel matrix entries: `n`, diagonal, and full rows.
pub trait KernelSource {
    fn n(&self) -> usize;
    fn diag(&self, i: usize) -> f64;
    /// Write row `i` of K into `out` (length n).
    fn row(&self, i: usize, out: &mut [f64]);
}

/// A dense matrix as a [`KernelSource`] (tests, small problems).
pub struct DenseSource<'a>(pub &'a Mat);

impl KernelSource for DenseSource<'_> {
    fn n(&self) -> usize {
        self.0.rows
    }
    fn diag(&self, i: usize) -> f64 {
        self.0[(i, i)]
    }
    fn row(&self, i: usize, out: &mut [f64]) {
        out.copy_from_slice(self.0.row(i));
    }
}

/// Result of ICF: `f` is R×n with `fᵀf ≈ K`; `pivots[k]` is the column
/// chosen at step k; `residual` is the final trace of `K − FᵀF`.
#[derive(Debug, Clone)]
pub struct IcfFactor {
    pub f: Mat,
    pub pivots: Vec<usize>,
    pub residual: f64,
}

impl IcfFactor {
    /// The column block `F_m = F[:, lo..hi]` owned by one machine.
    pub fn column_block(&self, lo: usize, hi: usize) -> Mat {
        let r = self.f.rows;
        let mut out = Mat::zeros(r, hi - lo);
        for k in 0..r {
            out.row_mut(k).copy_from_slice(&self.f.row(k)[lo..hi]);
        }
        out
    }
}

/// Pivoted incomplete Cholesky of rank ≤ `rank` (serial ctx).
///
/// Stops early when the residual trace falls below `tol` (pass 0.0 to
/// force exactly `rank` steps on a full-rank matrix).
pub fn icf(k: &dyn KernelSource, rank: usize, tol: f64) -> IcfFactor {
    icf_ctx(&LinalgCtx::serial(), k, rank, tol)
}

/// [`icf`] with explicit execution context: the per-step O(step·n) row
/// correction and residual-diagonal update fan out over *column* bands
/// of F on the ctx's pool. Banding is element-disjoint, so the pooled
/// factor is bitwise-identical to the serial one (which in turn stays
/// bit-identical to `parallel::picf::parallel_icf`, pivot for pivot —
/// the pivot scan itself is untouched).
pub fn icf_ctx(
    ctx: &LinalgCtx,
    k: &dyn KernelSource,
    rank: usize,
    tol: f64,
) -> IcfFactor {
    let n = k.n();
    let rank = rank.min(n);
    let mut d: Vec<f64> = (0..n).map(|i| k.diag(i)).collect();
    let mut f = Mat::zeros(rank, n);
    let mut pivots = Vec::with_capacity(rank);
    let mut krow = vec![0.0; n];
    let col_ranges = ctx.ranges(n, 64);

    for step in 0..rank {
        // pivot: largest residual diagonal; ties broken toward the
        // smallest index (must match parallel::picf::parallel_icf so the
        // distributed factor is bit-identical to the serial one)
        let (j, dj) = d.iter().enumerate().fold(
            (0usize, f64::NEG_INFINITY),
            |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc },
        );
        if dj <= tol || dj <= 0.0 {
            // converged (or numerically exhausted): truncate F
            let mut ftrunc = Mat::zeros(step, n);
            for r in 0..step {
                ftrunc.row_mut(r).copy_from_slice(f.row(r));
            }
            return IcfFactor {
                f: ftrunc,
                pivots,
                residual: d.iter().map(|x| x.max(0.0)).sum(),
            };
        }
        pivots.push(j);
        let piv = dj.sqrt();
        k.row(j, &mut krow);

        // f[step, i] = (K[j, i] - Σ_{t<step} f[t, j] f[t, i]) / piv
        // accumulate the correction without re-reading columns, one
        // column band per pool job (serial ctx: one inline band)
        let (done, frow_tail) = f.data.split_at_mut(step * n);
        let frow = &mut frow_tail[..n];
        frow.copy_from_slice(&krow);
        {
            let done_ref: &[f64] = done;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(col_ranges.len());
            let mut rest: &mut [f64] = frow;
            let mut d_rest: &mut [f64] = &mut d[..];
            for &(lo, hi) in &col_ranges {
                let (fband, ftail) =
                    std::mem::take(&mut rest).split_at_mut(hi - lo);
                rest = ftail;
                let (dband, dtail) =
                    std::mem::take(&mut d_rest).split_at_mut(hi - lo);
                d_rest = dtail;
                jobs.push(Box::new(move || {
                    for t in 0..step {
                        let ftj = done_ref[t * n + j];
                        if ftj != 0.0 {
                            let ft = &done_ref[t * n + lo..t * n + hi];
                            for (v, &fv) in fband.iter_mut().zip(ft) {
                                *v -= ftj * fv;
                            }
                        }
                    }
                    for v in fband.iter_mut() {
                        *v /= piv;
                    }
                    if (lo..hi).contains(&j) {
                        fband[j - lo] = piv; // exact; avoids drift
                    }
                    // residual diagonal update (band-local)
                    for (dv, &fv) in dband.iter_mut().zip(fband.iter()) {
                        *dv -= fv * fv;
                    }
                }));
            }
            ctx.run_jobs(jobs);
        }
        d[j] = 0.0;
    }

    IcfFactor {
        f,
        pivots,
        residual: d.iter().map(|x| x.max(0.0)).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_nt, matmul_tn};
    use crate::testkit::prop::{prop_check, Gen};

    fn rand_spd(g: &mut Gen, n: usize) -> Mat {
        let a = Mat::from_vec(n, n, g.normal_vec(n * n));
        let mut spd = matmul_nt(&a, &a);
        spd.add_diag(0.1);
        spd
    }

    #[test]
    fn full_rank_recovers_matrix() {
        prop_check("icf-full-rank", 16, |g| {
            let n = g.usize_in(1, 12);
            let k = rand_spd(g, n);
            let fac = icf(&DenseSource(&k), n, 0.0);
            let approx = matmul_tn(&fac.f, &fac.f);
            assert!(
                approx.max_abs_diff(&k) < 1e-8,
                "n={n} resid={}",
                fac.residual
            );
        });
    }

    #[test]
    fn truncated_rank_monotone_improvement() {
        let n = 20;
        let mut grng = crate::util::Pcg64::seed(4);
        let a = Mat::from_vec(n, n, grng.normals(n * n));
        let mut k = matmul_nt(&a, &a);
        k.add_diag(0.5);
        let mut prev = f64::INFINITY;
        for r in [2, 5, 10, 20] {
            let fac = icf(&DenseSource(&k), r, 0.0);
            let err = matmul_tn(&fac.f, &fac.f).max_abs_diff(&k);
            assert!(err <= prev + 1e-9, "rank {r}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn residual_nonincreasing_with_rank() {
        let mut rng = crate::util::Pcg64::seed(11);
        let n = 16;
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let mut k = matmul_nt(&a, &a);
        k.add_diag(0.2);
        let r1 = icf(&DenseSource(&k), 4, 0.0).residual;
        let r2 = icf(&DenseSource(&k), 8, 0.0).residual;
        let r3 = icf(&DenseSource(&k), 16, 0.0).residual;
        assert!(r1 >= r2 && r2 >= r3);
        assert!(r3 < 1e-8);
    }

    #[test]
    fn pivots_are_distinct() {
        prop_check("icf-pivots", 12, |g| {
            let n = g.usize_in(2, 14);
            let k = rand_spd(g, n);
            let fac = icf(&DenseSource(&k), n, 0.0);
            let mut p = fac.pivots.clone();
            p.sort_unstable();
            p.dedup();
            assert_eq!(p.len(), fac.pivots.len());
        });
    }

    #[test]
    fn low_rank_matrix_detected_early() {
        // rank-3 + tiny ridge: ICF should stop well before n
        let mut rng = crate::util::Pcg64::seed(21);
        let n = 15;
        let b = Mat::from_vec(n, 3, rng.normals(n * 3));
        let mut k = matmul_nt(&b, &b);
        k.add_diag(1e-12);
        let fac = icf(&DenseSource(&k), n, 1e-9);
        assert!(fac.f.rows <= 5, "rows={}", fac.f.rows);
        assert!(matmul_tn(&fac.f, &fac.f).max_abs_diff(&k) < 1e-5);
    }

    #[test]
    fn column_block_extraction() {
        let mut rng = crate::util::Pcg64::seed(31);
        let n = 12;
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let mut k = matmul_nt(&a, &a);
        k.add_diag(0.3);
        let fac = icf(&DenseSource(&k), 6, 0.0);
        let blk = fac.column_block(4, 9);
        assert_eq!((blk.rows, blk.cols), (6, 5));
        for r in 0..6 {
            assert_eq!(blk.row(r), &fac.f.row(r)[4..9]);
        }
    }

    /// Pooled ICF (column-banded updates) is bitwise-identical to the
    /// serial factorization — pivots, factor, and residual.
    #[test]
    fn pooled_icf_bitwise_matches_serial() {
        use crate::linalg::ctx::LinalgCtx;
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        prop_check("icf-pooled-serial", 6, |g| {
            let n = g.usize_in(2, 80);
            let k = rand_spd(g, n);
            let r = g.usize_in(1, n + 1).min(n);
            let serial = icf(&DenseSource(&k), r, 0.0);
            let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
            let pooled = icf_ctx(&ctx, &DenseSource(&k), r, 0.0);
            assert_eq!(serial.pivots, pooled.pivots);
            assert_eq!(serial.f, pooled.f);
            assert_eq!(serial.residual, pooled.residual);
        });
    }

    #[test]
    fn approximation_is_psd_bounded() {
        // FᵀF never overshoots the diagonal: K - FᵀF has nonneg diag
        prop_check("icf-psd-diag", 12, |g| {
            let n = g.usize_in(2, 12);
            let k = rand_spd(g, n);
            let r = g.usize_in(1, n + 1).min(n);
            let fac = icf(&DenseSource(&k), r, 0.0);
            let approx = matmul_tn(&fac.f, &fac.f);
            for i in 0..n {
                assert!(k[(i, i)] - approx[(i, i)] > -1e-9);
            }
        });
    }
}
