//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Needed by classical MDS (road-network embedding) and by spectral
//! diagnostics in tests. Jacobi is simple, numerically robust, and
//! adequate at the sizes we use (n ≲ 1000).

use super::Mat;

/// Eigen-decomposition of a symmetric matrix: `a = V · diag(w) · Vᵀ`.
/// Eigenvalues are returned in descending order; `vectors` holds the
/// corresponding eigenvectors as *columns*.
#[derive(Debug, Clone)]
pub struct SymEigen {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Cyclic Jacobi with a convergence threshold on the off-diagonal norm.
pub fn sym_eigen(a: &Mat) -> SymEigen {
    assert!(a.is_square(), "sym_eigen of non-square");
    let n = a.rows;
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::identity(n);

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s.sqrt()
    };

    let scale = m.max_abs().max(1.0);
    let tol = 1e-14 * scale * n as f64;
    for _sweep in 0..100 {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol / (n * n) as f64 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Jacobi rotation (Golub & Van Loan §8.5)
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate rotations
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort descending by eigenvalue
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_c)] = v[(r, old_c)];
        }
    }
    SymEigen { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, matmul_tn};
    use crate::testkit::prop::{prop_check, Gen};

    fn rand_sym(g: &mut Gen, n: usize) -> Mat {
        let a = Mat::from_vec(n, n, g.normal_vec(n * n));
        let mut s = a.clone();
        s.add_assign(&a.transpose());
        s.scale(0.5);
        s
    }

    #[test]
    fn reconstructs_matrix() {
        prop_check("eigen-reconstruct", 12, |g| {
            let n = g.usize_in(1, 10);
            let a = rand_sym(g, n);
            let e = sym_eigen(&a);
            // V diag(w) Vᵀ
            let mut vd = e.vectors.clone();
            for r in 0..n {
                for c in 0..n {
                    vd[(r, c)] *= e.values[c];
                }
            }
            let back = matmul_nt(&vd, &e.vectors);
            assert!(back.max_abs_diff(&a) < 1e-8, "n={n}");
        });
    }

    #[test]
    fn vectors_orthonormal() {
        prop_check("eigen-orthonormal", 12, |g| {
            let n = g.usize_in(1, 10);
            let a = rand_sym(g, n);
            let e = sym_eigen(&a);
            let vtv = matmul_tn(&e.vectors, &e.vectors);
            assert!(vtv.max_abs_diff(&Mat::identity(n)) < 1e-10);
        });
    }

    #[test]
    fn values_sorted_descending() {
        prop_check("eigen-sorted", 12, |g| {
            let n = g.usize_in(2, 10);
            let e = sym_eigen(&rand_sym(g, n));
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        });
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 2.0;
        let e = sym_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn eigenpairs_satisfy_av_eq_wv() {
        let mut rng = crate::util::Pcg64::seed(5);
        let n = 8;
        let b = Mat::from_vec(n, n, rng.normals(n * n));
        let mut a = b.clone();
        a.add_assign(&b.transpose());
        let e = sym_eigen(&a);
        let av = matmul(&a, &e.vectors);
        for c in 0..n {
            for r in 0..n {
                let want = e.values[c] * e.vectors[(r, c)];
                assert!((av[(r, c)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn psd_matrix_has_nonneg_values() {
        let mut rng = crate::util::Pcg64::seed(6);
        let n = 7;
        let b = Mat::from_vec(n, 4, rng.normals(n * 4));
        let k = matmul_nt(&b, &b);
        let e = sym_eigen(&k);
        assert!(e.values.iter().all(|&w| w > -1e-9));
        // rank 4: remaining eigenvalues ~ 0
        assert!(e.values[4..].iter().all(|&w| w.abs() < 1e-8));
    }
}
