//! Runtime-dispatched SIMD microkernels for the blocked engine.
//!
//! # The tier ladder
//!
//! Three implementations of the same band-GEMM contract live side by
//! side, best-first:
//!
//! | tier       | microkernel shape          | where it runs               |
//! |------------|----------------------------|-----------------------------|
//! | `Avx512`   | 8 C rows × 8 cols (zmm)    | `avx512f` hosts             |
//! | `Avx2`     | 4 C rows × 8 cols (ymm)    | `avx2`+`fma` hosts          |
//! | `Portable` | 2 C rows × 4 k-steps       | everywhere (the seed kernel)|
//!
//! [`active_tier`] picks the best supported tier **once** per process
//! (via `is_x86_feature_detected!`) and caches it in a `OnceLock`:
//! feature detection costs a `cpuid` + TLS dance, and the kernels sit
//! under hot loops that may be entered millions of times per serve
//! stream — re-detecting per call would show up. Dispatch happens at
//! band granularity (thousands of flops per call), never per element.
//!
//! The environment knob `PGPR_SIMD` (`portable` | `avx2` | `avx512`)
//! overrides detection at startup so every tier is testable on any
//! host that supports it: requests are *clamped* to what the CPU
//! actually has (asking for `avx512` on an AVX2 host silently runs the
//! AVX2 tier — never an illegal instruction). Unknown values panic
//! loudly; this is a developer knob. Tests that need a specific tier
//! in-process use [`force_tier`], a thread-local RAII override that
//! the blocked entry points read on the *calling* thread and capture
//! into their pool jobs (so a forced tier survives the fan-out).
//!
//! # Equivalence contracts (tested here and in [`super::blocked`])
//!
//! * The `Portable` tier is the seed microkernel moved verbatim:
//!   running with `PGPR_SIMD=portable` is **bitwise-identical** to the
//!   pre-SIMD blocked engine (and therefore, serially, to the seed
//!   scalar `matmul`).
//! * Within *any* tier, each output element is produced by a single
//!   accumulator folded over k in a fixed order (vector lanes and
//!   scalar remainder tails both use fused multiply-add in the same
//!   k order), so band boundaries, worker counts and row-block shapes
//!   never change any element's value: pooled ≡ serial **bitwise**
//!   holds per tier.
//! * AVX tiers agree with `Portable` to reassociation-level tolerance
//!   (different but equally stable summation orders), asserted by the
//!   tier-matrix tests in `blocked.rs`.
//!
//! # Adding a tier
//!
//! 1. Add a variant to [`SimdTier`] (keep the ladder ordered best →
//!    portable) and teach [`SimdTier::supported`] its feature test.
//! 2. Implement `band_kernel` (and optionally the exp lanes in
//!    [`exp`]) keeping the one-accumulator-per-element fma-chain rule
//!    above — that rule is what preserves the pooled ≡ serial bitwise
//!    contract; everything else is free.
//! 3. Extend the `match` in [`band_kernel`] and the tier-matrix tests;
//!    the bench harness picks the new tier up from
//!    [`SimdTier::available`] automatically.

pub mod exp;
pub mod mixed;
mod portable;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;

use std::cell::Cell;
use std::sync::OnceLock;

/// One rung of the dispatch ladder. Ordering is meaningful: later
/// variants are wider (see the module docs for shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// The seed scalar microkernel — runs everywhere, bitwise-equal to
    /// the pre-SIMD engine.
    Portable,
    /// AVX2 + FMA, 4×8 f64 register block.
    Avx2,
    /// AVX-512F, 8×8 f64 register block.
    Avx512,
}

impl SimdTier {
    /// Stable lowercase name (the `PGPR_SIMD` vocabulary, also used in
    /// bench output).
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Portable => "portable",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
        }
    }

    /// Whether the executing CPU can run this tier.
    pub fn supported(self) -> bool {
        match self {
            SimdTier::Portable => true,
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => {
                is_x86_feature_detected!("avx2")
                    && is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx512 => is_x86_feature_detected!("avx512f"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }

    /// Every tier the executing CPU supports, portable first. The
    /// tier-matrix tests and the per-tier bench sweep iterate this.
    pub fn available() -> Vec<SimdTier> {
        [SimdTier::Portable, SimdTier::Avx2, SimdTier::Avx512]
            .into_iter()
            .filter(|t| t.supported())
            .collect()
    }
}

/// Parse a `PGPR_SIMD` value. Pure so it can be unit-tested without
/// mutating process environment. Unknown values are a loud error (the
/// knob exists for tests/CI; silently ignoring a typo would quietly
/// benchmark the wrong tier).
fn parse_tier(raw: &str) -> Result<SimdTier, String> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "portable" | "scalar" => Ok(SimdTier::Portable),
        "avx2" => Ok(SimdTier::Avx2),
        "avx512" => Ok(SimdTier::Avx512),
        other => Err(format!(
            "PGPR_SIMD={other:?}: expected portable|avx2|avx512"
        )),
    }
}

/// Clamp a requested tier to what the CPU supports (never dispatch an
/// instruction set the host lacks; requests only ever lower the tier
/// or keep it).
fn clamp_supported(want: SimdTier) -> SimdTier {
    if want.supported() {
        return want;
    }
    if want == SimdTier::Avx512 && SimdTier::Avx2.supported() {
        return SimdTier::Avx2;
    }
    SimdTier::Portable
}

fn detect() -> SimdTier {
    if let Ok(raw) = std::env::var("PGPR_SIMD") {
        match parse_tier(&raw) {
            Ok(want) => return clamp_supported(want),
            Err(msg) => panic!("{msg}"),
        }
    }
    if SimdTier::Avx512.supported() {
        SimdTier::Avx512
    } else if SimdTier::Avx2.supported() {
        SimdTier::Avx2
    } else {
        SimdTier::Portable
    }
}

static CACHED: OnceLock<SimdTier> = OnceLock::new();

thread_local! {
    static FORCED: Cell<Option<SimdTier>> = const { Cell::new(None) };
}

/// The tier the calling thread should dispatch to: a thread-local
/// [`force_tier`] override when one is active (tests, per-tier bench
/// sweeps), else the process-wide cached detection (`PGPR_SIMD`
/// override or best supported). Blocked entry points read this once
/// per call on the calling thread and pass the value down into their
/// pool jobs.
pub fn active_tier() -> SimdTier {
    if let Some(t) = FORCED.with(|f| f.get()) {
        return t;
    }
    *CACHED.get_or_init(detect)
}

/// RAII guard restoring the previous thread-local tier override.
pub struct TierGuard {
    prev: Option<SimdTier>,
}

impl Drop for TierGuard {
    fn drop(&mut self) {
        FORCED.with(|f| f.set(self.prev));
    }
}

/// Force a tier for the current thread until the guard drops. Panics
/// if the CPU does not support the tier (callers gate on
/// [`SimdTier::supported`] / [`SimdTier::available`]). This is the
/// in-process knob behind the tier-matrix tests and the per-tier bench
/// sweep; `PGPR_SIMD` is the process-wide equivalent.
pub fn force_tier(tier: SimdTier) -> TierGuard {
    assert!(
        tier.supported(),
        "force_tier({}): not supported on this CPU",
        tier.name()
    );
    let prev = FORCED.with(|f| f.replace(Some(tier)));
    TierGuard { prev }
}

/// Tier-dispatched band microkernel: `c_rows[r] ±= a_rows[r] · B` over
/// a `kc`-deep, `nc`-wide tile whose packed rows are `b_rows[0..kc]`.
/// `SUB` selects subtraction at compile time (same specialization the
/// seed kernel used — a runtime ±1 multiplier costs ~20% GEMM
/// throughput).
pub(crate) fn band_kernel<const SUB: bool>(
    tier: SimdTier,
    a_rows: &[&[f64]],
    c_rows: &mut [&mut [f64]],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    match tier {
        SimdTier::Portable => {
            portable::band_kernel::<SUB>(a_rows, c_rows, b_rows, kc, nc)
        }
        #[cfg(target_arch = "x86_64")]
        // Safety: dispatch only selects these tiers when the features
        // were detected (detect/clamp_supported/force_tier all gate on
        // SimdTier::supported).
        SimdTier::Avx2 => unsafe {
            avx2::band_kernel::<SUB>(a_rows, c_rows, b_rows, kc, nc)
        },
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx512 => unsafe {
            avx512::band_kernel::<SUB>(a_rows, c_rows, b_rows, kc, nc)
        },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Avx2 | SimdTier::Avx512 => {
            portable::band_kernel::<SUB>(a_rows, c_rows, b_rows, kc, nc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tier_vocabulary() {
        assert_eq!(parse_tier("portable"), Ok(SimdTier::Portable));
        assert_eq!(parse_tier("scalar"), Ok(SimdTier::Portable));
        assert_eq!(parse_tier(" AVX2 "), Ok(SimdTier::Avx2));
        assert_eq!(parse_tier("Avx512"), Ok(SimdTier::Avx512));
        assert!(parse_tier("avx1024").is_err());
        assert!(parse_tier("").is_err());
    }

    #[test]
    fn clamp_never_raises() {
        // Portable is always supported, so clamping it is the identity;
        // any clamped result must itself be supported.
        assert_eq!(clamp_supported(SimdTier::Portable), SimdTier::Portable);
        for want in [SimdTier::Avx2, SimdTier::Avx512] {
            assert!(clamp_supported(want).supported());
        }
    }

    #[test]
    fn available_starts_portable_and_is_supported() {
        let tiers = SimdTier::available();
        assert_eq!(tiers[0], SimdTier::Portable);
        assert!(tiers.iter().all(|t| t.supported()));
    }

    #[test]
    fn force_tier_overrides_and_restores() {
        let before = active_tier();
        {
            let _g = force_tier(SimdTier::Portable);
            assert_eq!(active_tier(), SimdTier::Portable);
            // nesting restores the inner previous value
            {
                let _g2 = force_tier(SimdTier::Portable);
                assert_eq!(active_tier(), SimdTier::Portable);
            }
            assert_eq!(active_tier(), SimdTier::Portable);
        }
        assert_eq!(active_tier(), before);
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for t in [SimdTier::Portable, SimdTier::Avx2, SimdTier::Avx512] {
            assert_eq!(parse_tier(t.name()), Ok(t));
        }
    }
}
