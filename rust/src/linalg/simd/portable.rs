//! The `Portable` tier: the seed scalar microkernel, moved here
//! verbatim from `blocked.rs`. Every expression (the 4-wide k
//! grouping, the zero-skip on the k remainder, the two-rows-at-a-time
//! pairing) is preserved exactly — this is what makes
//! `PGPR_SIMD=portable` bitwise-identical to the pre-SIMD blocked
//! engine, and (serially) to the seed scalar `matmul`.

/// One C row: `c[j] ±= (a · B)[j]` over a `kc`-deep, `nc`-wide tile.
/// Mirrors the seed kernel's expression exactly (including the
/// zero-skip on the k remainder).
fn band_kernel_row<const SUB: bool>(
    a0: &[f64],
    c0: &mut [f64],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    let c0 = &mut c0[..nc];
    let mut kk = 0;
    while kk + 4 <= kc {
        let (p0, p1, p2, p3) = (a0[kk], a0[kk + 1], a0[kk + 2], a0[kk + 3]);
        let b0 = &b_rows[kk][..nc];
        let b1 = &b_rows[kk + 1][..nc];
        let b2 = &b_rows[kk + 2][..nc];
        let b3 = &b_rows[kk + 3][..nc];
        for j in 0..nc {
            let t = p0 * b0[j] + p1 * b1[j] + p2 * b2[j] + p3 * b3[j];
            if SUB {
                c0[j] -= t;
            } else {
                c0[j] += t;
            }
        }
        kk += 4;
    }
    while kk < kc {
        let p = a0[kk];
        if p != 0.0 {
            let brow = &b_rows[kk][..nc];
            for j in 0..nc {
                let t = p * brow[j];
                if SUB {
                    c0[j] -= t;
                } else {
                    c0[j] += t;
                }
            }
        }
        kk += 1;
    }
}

/// The seed microloop: `c_rows[r] ±= a_rows[r] · B` over a tile, two C
/// rows at a time (each B load feeds both rows; four k-steps amortize
/// each C access). `b_rows[kk]` is packed row kk of the tile.
pub(super) fn band_kernel<const SUB: bool>(
    a_rows: &[&[f64]],
    c_rows: &mut [&mut [f64]],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    debug_assert_eq!(a_rows.len(), c_rows.len());
    debug_assert!(b_rows.len() >= kc);
    let rows = c_rows.len();
    let mut r = 0;
    while r + 2 <= rows {
        let (head, tail) = c_rows.split_at_mut(r + 1);
        let c0 = &mut head[r][..nc];
        let c1 = &mut tail[0][..nc];
        let a0 = a_rows[r];
        let a1 = a_rows[r + 1];
        let mut kk = 0;
        while kk + 4 <= kc {
            let (p0, p1, p2, p3) =
                (a0[kk], a0[kk + 1], a0[kk + 2], a0[kk + 3]);
            let (q0, q1, q2, q3) =
                (a1[kk], a1[kk + 1], a1[kk + 2], a1[kk + 3]);
            let b0 = &b_rows[kk][..nc];
            let b1 = &b_rows[kk + 1][..nc];
            let b2 = &b_rows[kk + 2][..nc];
            let b3 = &b_rows[kk + 3][..nc];
            for j in 0..nc {
                let (v0, v1, v2, v3) = (b0[j], b1[j], b2[j], b3[j]);
                let t0 = p0 * v0 + p1 * v1 + p2 * v2 + p3 * v3;
                let t1 = q0 * v0 + q1 * v1 + q2 * v2 + q3 * v3;
                if SUB {
                    c0[j] -= t0;
                    c1[j] -= t1;
                } else {
                    c0[j] += t0;
                    c1[j] += t1;
                }
            }
            kk += 4;
        }
        while kk < kc {
            let (p, q) = (a0[kk], a1[kk]);
            let brow = &b_rows[kk][..nc];
            if p != 0.0 {
                for j in 0..nc {
                    let t = p * brow[j];
                    if SUB {
                        c0[j] -= t;
                    } else {
                        c0[j] += t;
                    }
                }
            }
            if q != 0.0 {
                for j in 0..nc {
                    let t = q * brow[j];
                    if SUB {
                        c1[j] -= t;
                    } else {
                        c1[j] += t;
                    }
                }
            }
            kk += 1;
        }
        r += 2;
    }
    if r < rows {
        band_kernel_row::<SUB>(a_rows[r], &mut *c_rows[r], b_rows, kc, nc);
    }
}
