//! Mixed-precision serve primitives: **f32 storage, f64 accumulate**.
//!
//! The staged serve operators are memory-bound — every predict batch
//! streams the feature map's support matrix and the staged quadratic
//! operator from DRAM. Storing them in f32 halves that traffic (and
//! doubles effective SIMD width) while every arithmetic reduction
//! still runs in f64: each f32 element is widened exactly (f32 → f64
//! is lossless), so the *only* error vs the f64 pipeline is the
//! one-time storage rounding of the operator entries (≤ 2⁻²⁴ relative
//! per entry, amplified ~√p by the dot products — observed ~10⁻⁶
//! relative on serve-sized problems, budgeted at 10⁻⁴ in
//! `gp::predictor`).
//!
//! Pooled execution stays bitwise-identical to serial for the same
//! reason as the f64 engine: output rows fan out in disjoint bands and
//! each row's accumulation order is fixed by (k-tile, k, l) alone.

use crate::linalg::ctx::LinalgCtx;
use crate::linalg::Mat;

/// Row-major f32 matrix — storage-only sibling of [`Mat`] for staged
/// serve operators. No arithmetic is ever done in f32; see the module
/// docs.
#[derive(Clone, Debug, Default)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Demote an f64 matrix (round-to-nearest per entry — the one
    /// lossy step of the mixed-precision pipeline).
    pub fn from_mat(m: &Mat) -> Self {
        MatF32 {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&v| v as f32).collect(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place, reusing the allocation (serve scratch reuse;
    /// contents are unspecified afterwards).
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }
}

/// Widening dot product: f32 operands, f64 multiply-accumulate.
/// Same 4-accumulator shape as [`crate::linalg::dot`] (the pairwise
/// `(s0+s1)+(s2+s3)` combine), so it vectorizes the same way and its
/// error behaves like the f64 dot over the widened values.
pub fn dot_wide(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= n {
        s0 += a[i] as f64 * b[i] as f64;
        s1 += a[i + 1] as f64 * b[i + 1] as f64;
        s2 += a[i + 2] as f64 * b[i + 2] as f64;
        s3 += a[i + 3] as f64 * b[i + 3] as f64;
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] as f64 * b[i] as f64;
        i += 1;
    }
    s
}

/// Widening axpy: `out[j] += coef * row[j]` with the f32 row widened
/// per element — the building block for f32-storage GEMV row sweeps.
#[inline]
pub fn axpy_wide(coef: f64, row: &[f32], out: &mut [f64]) {
    debug_assert_eq!(row.len(), out.len());
    for (o, &r) in out.iter_mut().zip(row.iter()) {
        *o += coef * r as f64;
    }
}

/// Mirror of the f64 k-tile depth in `blocked::diag_quad_into` (kept
/// equal so both precisions have the same cache behavior and the same
/// per-row accumulation order).
const QUAD_KT: usize = 64;

/// `diag(G · A · Gᵀ)` for symmetric `A`, f32 storage / f64 accumulate —
/// the mixed-precision sibling of [`crate::linalg::blocked::diag_quad_into`]
/// with the identical tiling, banding and per-row accumulation order
/// (only the element loads are widened f32). `out.len() == g.rows`;
/// only A's upper triangle is read.
pub fn diag_quad_f32_into(
    ctx: &LinalgCtx,
    g: &MatF32,
    a: &MatF32,
    out: &mut [f64],
) {
    let p = g.cols;
    assert_eq!(a.rows, a.cols, "diag_quad_f32: A must be square");
    assert_eq!(a.rows, p, "diag_quad_f32: A is {}x{}, G cols {p}", a.rows, a.cols);
    assert_eq!(out.len(), g.rows, "diag_quad_f32: out length");
    let b = g.rows;
    if b == 0 {
        return;
    }
    out.fill(0.0);
    if p == 0 {
        return;
    }
    let ranges = ctx.ranges(b, 8);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(ranges.len());
    let mut rest: &mut [f64] = out;
    for &(lo, hi) in &ranges {
        let (band, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
        rest = tail;
        jobs.push(Box::new(move || {
            let mut k0 = 0;
            while k0 < p {
                let k1 = (k0 + QUAD_KT).min(p);
                for (r, acc) in band.iter_mut().enumerate() {
                    let gi = g.row(lo + r);
                    let mut s = 0.0;
                    for k in k0..k1 {
                        let gk = gi[k] as f64;
                        // upper-triangular row slice A[k, k..p]
                        let arow = &a.data[k * p + k..(k + 1) * p];
                        let t = dot_wide(&arow[1..], &gi[k + 1..]);
                        s += gk * (arow[0] as f64 * gk + 2.0 * t);
                    }
                    *acc += s;
                }
                k0 = k1;
            }
        }));
    }
    ctx.run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::diag_quad_ctx;
    use crate::testkit::prop::prop_check;
    use crate::util::Pcg64;
    use crate::util::pool::ThreadPool;
    use std::sync::Arc;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        m.data = Pcg64::seed(seed).normals(rows * cols);
        m
    }

    fn rand_spd(n: usize, seed: u64) -> Mat {
        let b = rand_mat(n, n + 3, seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n + 3 {
                    s += b.data[i * (n + 3) + k] * b.data[j * (n + 3) + k];
                }
                a.data[i * n + j] = s;
            }
            a.data[i * n + i] += 1.0;
        }
        a
    }

    #[test]
    fn dot_wide_exact_on_representable_values() {
        // small integers are exact in both precisions, so the widened
        // dot must equal the integer result exactly
        let a: Vec<f32> = (0..37).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..37).map(|i| (i % 5) as f32 - 2.0).collect();
        let want: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        assert_eq!(dot_wide(&a, &b), want);
    }

    #[test]
    fn dot_wide_tracks_f64_dot_to_storage_rounding() {
        prop_check("dot-wide-vs-f64", 30, |g| {
            let n = g.usize_in(1, 300);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            let wide = dot_wide(&af, &bf);
            let exact = crate::linalg::dot(&a, &b);
            let scale: f64 =
                a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>();
            // two f32 roundings per term, f64 accumulation
            assert!(
                (wide - exact).abs() <= 4.0 * f32::EPSILON as f64 * scale.max(1.0),
                "n={n}: wide={wide} exact={exact}"
            );
        });
    }

    #[test]
    fn diag_quad_f32_tracks_f64_oracle() {
        prop_check("diag-quad-f32", 12, |g| {
            let p = g.usize_in(1, 60);
            let b = g.usize_in(1, 40);
            let a = rand_spd(p, 11 + g.case as u64);
            let gm = rand_mat(b, p, 99 + g.case as u64);
            let want = diag_quad_ctx(&LinalgCtx::serial(), &gm, &a);
            let af = MatF32::from_mat(&a);
            let gf = MatF32::from_mat(&gm);
            let mut got = vec![0.0; b];
            diag_quad_f32_into(&LinalgCtx::serial(), &gf, &af, &mut got);
            for i in 0..b {
                let tol = 1e-4 * want[i].abs().max(1.0);
                assert!(
                    (got[i] - want[i]).abs() <= tol,
                    "row {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        });
    }

    #[test]
    fn diag_quad_f32_pooled_bitwise_matches_serial() {
        let p = 83;
        let b = 57;
        let a = MatF32::from_mat(&rand_spd(p, 5));
        let gm = MatF32::from_mat(&rand_mat(b, p, 6));
        let mut serial = vec![0.0; b];
        diag_quad_f32_into(&LinalgCtx::serial(), &gm, &a, &mut serial);
        for workers in [2, 4] {
            let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(workers)));
            let mut pooled = vec![0.0; b];
            diag_quad_f32_into(&ctx, &gm, &a, &mut pooled);
            for i in 0..b {
                assert_eq!(
                    pooled[i].to_bits(),
                    serial[i].to_bits(),
                    "workers={workers} row={i}"
                );
            }
        }
    }

    #[test]
    fn matf32_roundtrip_and_resize() {
        let m = rand_mat(7, 9, 44);
        let f = MatF32::from_mat(&m);
        assert_eq!(f.rows, 7);
        assert_eq!(f.cols, 9);
        for i in 0..7 {
            for (j, &v) in f.row(i).iter().enumerate() {
                assert_eq!(v, m.data[i * 9 + j] as f32);
            }
        }
        let mut f2 = f.clone();
        f2.resize_to(3, 4);
        assert_eq!(f2.data.len(), 12);
        f2.row_mut(0)[0] = 2.5;
        assert_eq!(f2.row(0)[0], 2.5);
    }

    #[test]
    fn axpy_wide_accumulates() {
        let row: Vec<f32> = vec![1.0, 2.0, -0.5];
        let mut out = vec![1.0f64, 1.0, 1.0];
        axpy_wide(2.0, &row, &mut out);
        assert_eq!(out, vec![3.0, 5.0, 0.0]);
    }
}
