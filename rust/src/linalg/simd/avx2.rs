//! The `Avx2` tier: 4 C rows × 8 columns of f64 per register block
//! (8 ymm accumulators; each broadcast A element feeds two fmadds,
//! each pair of B loads feeds all four rows).
//!
//! Numerics contract: every output element is one accumulator folded
//! over k in order with fused multiply-add — vector lanes via
//! `vfmadd231pd`, the scalar column tail via `f64::mul_add` in the
//! same k order — then added/subtracted into C once. Values therefore
//! depend only on (kc, k order), never on which register block or
//! band an element landed in: pooled ≡ serial stays bitwise within
//! this tier.

use std::arch::x86_64::*;

/// Band microkernel, AVX2+FMA.
///
/// # Safety
///
/// The CPU must support `avx2` and `fma` (dispatch guarantees this).
/// Slice shapes are checked with real asserts below; everything after
/// them is in-bounds by construction.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn band_kernel<const SUB: bool>(
    a_rows: &[&[f64]],
    c_rows: &mut [&mut [f64]],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    assert_eq!(a_rows.len(), c_rows.len());
    assert!(b_rows.len() >= kc);
    for br in &b_rows[..kc] {
        assert!(br.len() >= nc);
    }
    for (a, c) in a_rows.iter().zip(c_rows.iter()) {
        assert!(a.len() >= kc && c.len() >= nc);
    }
    let rows = c_rows.len();
    let bp: Vec<*const f64> =
        b_rows[..kc].iter().map(|r| r.as_ptr()).collect();
    let mut r = 0;
    while r + 4 <= rows {
        let ap = [
            a_rows[r].as_ptr(),
            a_rows[r + 1].as_ptr(),
            a_rows[r + 2].as_ptr(),
            a_rows[r + 3].as_ptr(),
        ];
        let cp = [
            c_rows[r].as_mut_ptr(),
            c_rows[r + 1].as_mut_ptr(),
            c_rows[r + 2].as_mut_ptr(),
            c_rows[r + 3].as_mut_ptr(),
        ];
        block4::<SUB>(ap, cp, &bp, kc, nc);
        r += 4;
    }
    while r < rows {
        block1::<SUB>(a_rows[r].as_ptr(), c_rows[r].as_mut_ptr(), &bp, kc, nc);
        r += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn block4<const SUB: bool>(
    ap: [*const f64; 4],
    cp: [*mut f64; 4],
    bp: &[*const f64],
    kc: usize,
    nc: usize,
) {
    let [a0, a1, a2, a3] = ap;
    let [c0, c1, c2, c3] = cp;
    let mut j = 0;
    while j + 8 <= nc {
        let mut s00 = _mm256_setzero_pd();
        let mut s01 = _mm256_setzero_pd();
        let mut s10 = _mm256_setzero_pd();
        let mut s11 = _mm256_setzero_pd();
        let mut s20 = _mm256_setzero_pd();
        let mut s21 = _mm256_setzero_pd();
        let mut s30 = _mm256_setzero_pd();
        let mut s31 = _mm256_setzero_pd();
        for kk in 0..kc {
            let b = *bp.get_unchecked(kk);
            let b0 = _mm256_loadu_pd(b.add(j));
            let b1 = _mm256_loadu_pd(b.add(j + 4));
            let v0 = _mm256_set1_pd(*a0.add(kk));
            s00 = _mm256_fmadd_pd(v0, b0, s00);
            s01 = _mm256_fmadd_pd(v0, b1, s01);
            let v1 = _mm256_set1_pd(*a1.add(kk));
            s10 = _mm256_fmadd_pd(v1, b0, s10);
            s11 = _mm256_fmadd_pd(v1, b1, s11);
            let v2 = _mm256_set1_pd(*a2.add(kk));
            s20 = _mm256_fmadd_pd(v2, b0, s20);
            s21 = _mm256_fmadd_pd(v2, b1, s21);
            let v3 = _mm256_set1_pd(*a3.add(kk));
            s30 = _mm256_fmadd_pd(v3, b0, s30);
            s31 = _mm256_fmadd_pd(v3, b1, s31);
        }
        apply2::<SUB>(c0.add(j), s00, s01);
        apply2::<SUB>(c1.add(j), s10, s11);
        apply2::<SUB>(c2.add(j), s20, s21);
        apply2::<SUB>(c3.add(j), s30, s31);
        j += 8;
    }
    while j < nc {
        col_tail::<SUB>(a0, c0, bp, kc, j);
        col_tail::<SUB>(a1, c1, bp, kc, j);
        col_tail::<SUB>(a2, c2, bp, kc, j);
        col_tail::<SUB>(a3, c3, bp, kc, j);
        j += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn block1<const SUB: bool>(
    a: *const f64,
    c: *mut f64,
    bp: &[*const f64],
    kc: usize,
    nc: usize,
) {
    let mut j = 0;
    while j + 8 <= nc {
        let mut s0 = _mm256_setzero_pd();
        let mut s1 = _mm256_setzero_pd();
        for kk in 0..kc {
            let b = *bp.get_unchecked(kk);
            let v = _mm256_set1_pd(*a.add(kk));
            s0 = _mm256_fmadd_pd(v, _mm256_loadu_pd(b.add(j)), s0);
            s1 = _mm256_fmadd_pd(v, _mm256_loadu_pd(b.add(j + 4)), s1);
        }
        apply2::<SUB>(c.add(j), s0, s1);
        j += 8;
    }
    while j < nc {
        col_tail::<SUB>(a, c, bp, kc, j);
        j += 1;
    }
}

/// `c[0..4] ±= lo; c[4..8] ±= hi` — the one add/sub into C per block.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn apply2<const SUB: bool>(c: *mut f64, lo: __m256d, hi: __m256d) {
    let cur0 = _mm256_loadu_pd(c);
    let cur1 = _mm256_loadu_pd(c.add(4));
    let (n0, n1) = if SUB {
        (_mm256_sub_pd(cur0, lo), _mm256_sub_pd(cur1, hi))
    } else {
        (_mm256_add_pd(cur0, lo), _mm256_add_pd(cur1, hi))
    };
    _mm256_storeu_pd(c, n0);
    _mm256_storeu_pd(c.add(4), n1);
}

/// Scalar column tail: the same single-accumulator fused chain as a
/// vector lane (`f64::mul_add` is fused), so an element's value does
/// not depend on whether it fell in the vector body or this tail.
#[inline(always)]
unsafe fn col_tail<const SUB: bool>(
    a: *const f64,
    c: *mut f64,
    bp: &[*const f64],
    kc: usize,
    j: usize,
) {
    let mut acc = 0.0f64;
    for kk in 0..kc {
        acc = (*a.add(kk)).mul_add(*(*bp.get_unchecked(kk)).add(j), acc);
    }
    if SUB {
        *c.add(j) -= acc;
    } else {
        *c.add(j) += acc;
    }
}
