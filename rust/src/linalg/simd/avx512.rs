//! The `Avx512` tier: 8 C rows × 8 columns of f64 per register block
//! (8 zmm accumulators + 1 B register out of 32, so the broadcast
//! temporaries never spill).
//!
//! Same numerics contract as the AVX2 tier: one fused-multiply-add
//! accumulator per output element, folded over k in order (vector
//! lanes and the `f64::mul_add` scalar column tail alike), applied to
//! C once — element values are independent of banding and blocking,
//! preserving pooled ≡ serial bitwise within the tier.

use std::arch::x86_64::*;

/// Band microkernel, AVX-512F.
///
/// # Safety
///
/// The CPU must support `avx512f` (dispatch guarantees this). Slice
/// shapes are checked with real asserts below.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn band_kernel<const SUB: bool>(
    a_rows: &[&[f64]],
    c_rows: &mut [&mut [f64]],
    b_rows: &[&[f64]],
    kc: usize,
    nc: usize,
) {
    assert_eq!(a_rows.len(), c_rows.len());
    assert!(b_rows.len() >= kc);
    for br in &b_rows[..kc] {
        assert!(br.len() >= nc);
    }
    for (a, c) in a_rows.iter().zip(c_rows.iter()) {
        assert!(a.len() >= kc && c.len() >= nc);
    }
    let rows = c_rows.len();
    let bp: Vec<*const f64> =
        b_rows[..kc].iter().map(|r| r.as_ptr()).collect();
    let mut r = 0;
    while r + 8 <= rows {
        let mut ap = [std::ptr::null::<f64>(); 8];
        let mut cp = [std::ptr::null_mut::<f64>(); 8];
        for i in 0..8 {
            ap[i] = a_rows[r + i].as_ptr();
            cp[i] = c_rows[r + i].as_mut_ptr();
        }
        block8::<SUB>(ap, cp, &bp, kc, nc);
        r += 8;
    }
    while r < rows {
        block1::<SUB>(a_rows[r].as_ptr(), c_rows[r].as_mut_ptr(), &bp, kc, nc);
        r += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn block8<const SUB: bool>(
    ap: [*const f64; 8],
    cp: [*mut f64; 8],
    bp: &[*const f64],
    kc: usize,
    nc: usize,
) {
    let mut j = 0;
    while j + 8 <= nc {
        let mut s0 = _mm512_setzero_pd();
        let mut s1 = _mm512_setzero_pd();
        let mut s2 = _mm512_setzero_pd();
        let mut s3 = _mm512_setzero_pd();
        let mut s4 = _mm512_setzero_pd();
        let mut s5 = _mm512_setzero_pd();
        let mut s6 = _mm512_setzero_pd();
        let mut s7 = _mm512_setzero_pd();
        for kk in 0..kc {
            let b = _mm512_loadu_pd((*bp.get_unchecked(kk)).add(j));
            s0 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[0].add(kk)), b, s0);
            s1 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[1].add(kk)), b, s1);
            s2 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[2].add(kk)), b, s2);
            s3 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[3].add(kk)), b, s3);
            s4 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[4].add(kk)), b, s4);
            s5 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[5].add(kk)), b, s5);
            s6 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[6].add(kk)), b, s6);
            s7 = _mm512_fmadd_pd(_mm512_set1_pd(*ap[7].add(kk)), b, s7);
        }
        apply::<SUB>(cp[0].add(j), s0);
        apply::<SUB>(cp[1].add(j), s1);
        apply::<SUB>(cp[2].add(j), s2);
        apply::<SUB>(cp[3].add(j), s3);
        apply::<SUB>(cp[4].add(j), s4);
        apply::<SUB>(cp[5].add(j), s5);
        apply::<SUB>(cp[6].add(j), s6);
        apply::<SUB>(cp[7].add(j), s7);
        j += 8;
    }
    while j < nc {
        for i in 0..8 {
            col_tail::<SUB>(ap[i], cp[i], bp, kc, j);
        }
        j += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn block1<const SUB: bool>(
    a: *const f64,
    c: *mut f64,
    bp: &[*const f64],
    kc: usize,
    nc: usize,
) {
    let mut j = 0;
    while j + 8 <= nc {
        let mut s = _mm512_setzero_pd();
        for kk in 0..kc {
            let b = _mm512_loadu_pd((*bp.get_unchecked(kk)).add(j));
            s = _mm512_fmadd_pd(_mm512_set1_pd(*a.add(kk)), b, s);
        }
        apply::<SUB>(c.add(j), s);
        j += 8;
    }
    while j < nc {
        col_tail::<SUB>(a, c, bp, kc, j);
        j += 1;
    }
}

/// `c[0..8] ±= s` — the one add/sub into C per block.
#[target_feature(enable = "avx512f")]
unsafe fn apply<const SUB: bool>(c: *mut f64, s: __m512d) {
    let cur = _mm512_loadu_pd(c);
    let next = if SUB {
        _mm512_sub_pd(cur, s)
    } else {
        _mm512_add_pd(cur, s)
    };
    _mm512_storeu_pd(c, next);
}

/// Scalar column tail — identical fused chain to a vector lane.
#[inline(always)]
unsafe fn col_tail<const SUB: bool>(
    a: *const f64,
    c: *mut f64,
    bp: &[*const f64],
    kc: usize,
    j: usize,
) {
    let mut acc = 0.0f64;
    for kk in 0..kc {
        acc = (*a.add(kk)).mul_add(*(*bp.get_unchecked(kk)).add(j), acc);
    }
    if SUB {
        *c.add(j) -= acc;
    } else {
        *c.add(j) += acc;
    }
}
