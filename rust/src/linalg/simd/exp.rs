//! Vectorized elementwise `exp` for the SE-kernel band transform —
//! the pass that dominates `SeArd::gram_ctx` and `FeatureMap::fill`
//! on every serve batch.
//!
//! # The polynomial `exp` and its accuracy contract
//!
//! [`exp_neg`] evaluates `e^x` for `x ∈ [EXP_MIN, 0]` (SE arguments
//! are always `-0.5·sq ≤ 0`) by the standard three-step scheme, with
//! every step chosen so the scalar mirror and the AVX lanes execute
//! the *same* rounded operations and are therefore **bitwise
//! identical**:
//!
//! 1. **Range reduction** `x = k·ln2 + r`, `|r| ≤ ln2/2`: `k` is
//!    `round(x·log₂e)` via the 2⁵²+2⁵¹ magic-constant trick (one add
//!    and one subtract — identical rounding on scalar and vector, no
//!    `round()` libcall), and `r` via two-term Cody–Waite
//!    (`LN2_HI`/`LN2_LO`) with fused multiply-adds.
//! 2. **Core** `e^r` as the degree-13 Taylor polynomial in a fused
//!    Horner chain (truncation ≈ 4·10⁻¹⁸, far below one ulp).
//! 3. **Scaling** by `2^k` through direct exponent-bit assembly
//!    (`k ∈ [-1021, 0]` on this domain, so the scale is always a
//!    positive normal).
//!
//! Accuracy: **≤ [`EXP_NEG_ULP_BOUND`] ulp** of `f64::exp` on the
//! whole domain (observed ≤ 2; the test suite sweeps the domain and
//! asserts the bound). Inputs below `EXP_MIN` flush to exactly `0.0`
//! (`f64::exp` would return a value ≤ 3.3·10⁻³⁰⁸ there; the SE kernel
//! treats both as "no correlation").
//!
//! # Which call sites use which path
//!
//! [`se_apply`] is the one banded SE transform shared by
//! `SeArd::gram_ctx`, `FeatureMap::fill` (and, at single-element
//! granularity, `SeArd::k` via [`se_point`]):
//!
//! * `Portable` tier: the seed expression verbatim — `sf2 *
//!   (-0.5·sq).exp()` with libm `exp` — preserving the
//!   `PGPR_SIMD=portable` ≡ seed bitwise contract.
//! * AVX tiers: 4- or 8-wide polynomial lanes, with the scalar-mirror
//!   [`exp_neg`] on the column tail so an element's value never
//!   depends on which path it fell in (pooled ≡ serial bitwise holds
//!   per tier, tested).

use super::SimdTier;

/// Documented ulp bound of [`exp_neg`] against `f64::exp` on
/// `[EXP_MIN, 0]` (asserted in tests).
pub const EXP_NEG_ULP_BOUND: u64 = 4;

/// Flush-to-zero threshold: below this, [`exp_neg`] returns exactly
/// 0.0. Chosen so `2^k` stays a positive normal scale on the live
/// domain (k ≥ -1021).
pub const EXP_MIN: f64 = -708.0;

const LOG2E: f64 = std::f64::consts::LOG2_E;
// Cody–Waite split of ln2 (fdlibm constants): LN2_HI has 11 trailing
// zero mantissa bits, so k·LN2_HI is exact for |k| ≤ 2^11 and the
// reduction error collapses into the tiny LN2_LO term.
const LN2_HI: f64 = f64::from_bits(0x3FE62E42FEE00000);
const LN2_LO: f64 = f64::from_bits(0x3DEA39EF35793C76);
// 2^52 + 2^51: adding then subtracting rounds to the nearest integer
// (ties to even) for |t| < 2^51, and the integer is recoverable from
// the low mantissa bits of the biased sum.
const MAGIC: f64 = 6_755_399_441_055_744.0;
const MAGIC_BITS: i64 = MAGIC.to_bits() as i64;

// Taylor coefficients 1/n! for the degree-13 Horner core (c13 first).
const POLY: [f64; 12] = [
    1.0 / 6_227_020_800.0, // 1/13!
    1.0 / 479_001_600.0,   // 1/12!
    1.0 / 39_916_800.0,    // 1/11!
    1.0 / 3_628_800.0,     // 1/10!
    1.0 / 362_880.0,       // 1/9!
    1.0 / 40_320.0,        // 1/8!
    1.0 / 5_040.0,         // 1/7!
    1.0 / 720.0,           // 1/6!
    1.0 / 120.0,           // 1/5!
    1.0 / 24.0,            // 1/4!
    1.0 / 6.0,             // 1/3!
    1.0 / 2.0,             // 1/2!
];

/// Polynomial `e^x` for `x ≤ 0` — the scalar mirror of the AVX lanes
/// (same rounded operations in the same order, so it is bitwise-equal
/// to any vector lane fed the same input). See the module docs for
/// the scheme and the ulp bound.
#[inline]
pub fn exp_neg(x: f64) -> f64 {
    if x < EXP_MIN {
        return 0.0;
    }
    let t = x * LOG2E;
    let kb = t + MAGIC;
    let k = kb - MAGIC;
    let ki = (kb.to_bits() as i64).wrapping_sub(MAGIC_BITS);
    let r1 = k.mul_add(-LN2_HI, x);
    let r = k.mul_add(-LN2_LO, r1);
    let mut p = POLY[0];
    for &c in &POLY[1..] {
        p = p.mul_add(r, c);
    }
    let p = p.mul_add(r, 1.0); // + r/1!
    let p = p.mul_add(r, 1.0); // + 1
    let scale = f64::from_bits(((ki + 1023) as u64) << 52);
    p * scale
}

/// The scalar SE oracle: `sf2 · e^{-sq/2}` via libm `exp` — the seed
/// expression every tier is pinned against (and the pointwise path
/// `SeArd::k` uses directly).
#[inline]
pub fn se_point(sf2: f64, sq: f64) -> f64 {
    sf2 * (-0.5 * sq).exp()
}

/// The banded SE transform shared by `SeArd::gram_ctx` and
/// `FeatureMap::fill`: on entry `krow[j]` holds the cross term
/// `x₁ᵢ·x₂ⱼ` (scaled), on exit `krow[j] = sf2 ·
/// exp(-0.5·max(0, s1v + sq2[j] - 2·krow[j]))`.
///
/// `Portable` evaluates the seed expression verbatim (libm `exp`);
/// AVX tiers use the polynomial lanes + scalar-mirror tail. The tier
/// is passed explicitly (read once on the calling thread) so pool
/// jobs inherit it.
pub fn se_apply(
    tier: SimdTier,
    sf2: f64,
    s1v: f64,
    sq2: &[f64],
    krow: &mut [f64],
) {
    debug_assert_eq!(sq2.len(), krow.len());
    match tier {
        SimdTier::Portable => {
            for (kv, &s2) in krow.iter_mut().zip(sq2.iter()) {
                let sq = (s1v + s2 - 2.0 * *kv).max(0.0);
                *kv = sf2 * (-0.5 * sq).exp();
            }
        }
        #[cfg(target_arch = "x86_64")]
        // Safety: dispatch only selects these tiers when the CPU
        // features were detected.
        SimdTier::Avx2 => unsafe { se_apply_avx2(sf2, s1v, sq2, krow) },
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx512 => unsafe { se_apply_avx512(sf2, s1v, sq2, krow) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Avx2 | SimdTier::Avx512 => {
            for (kv, &s2) in krow.iter_mut().zip(sq2.iter()) {
                *kv = se_lane(sf2, s1v, s2, *kv);
            }
        }
    }
}

/// One SE element through the polynomial path — the scalar mirror of
/// an AVX `se_apply` lane (used for column tails and as the bitwise
/// reference in tests).
#[inline]
pub fn se_lane(sf2: f64, s1v: f64, s2: f64, kv: f64) -> f64 {
    let sq = (s1v + s2 - 2.0 * kv).max(0.0);
    sf2 * exp_neg(-0.5 * sq)
}

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// 4-wide polynomial `e^x` for `x ≤ 0` lanes; lanes below `EXP_MIN`
/// flush to 0.0. Bitwise-equal to [`exp_neg`] per lane.
///
/// # Safety
///
/// CPU must support avx2+fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn exp_neg_pd4(x: __m256d) -> __m256d {
    let t = _mm256_mul_pd(x, _mm256_set1_pd(LOG2E));
    let kb = _mm256_add_pd(t, _mm256_set1_pd(MAGIC));
    let k = _mm256_sub_pd(kb, _mm256_set1_pd(MAGIC));
    let ki = _mm256_sub_epi64(
        _mm256_castpd_si256(kb),
        _mm256_set1_epi64x(MAGIC_BITS),
    );
    // r = x - k·LN2_HI - k·LN2_LO, fused (fnmadd = c - a·b).
    let r1 = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_HI), x);
    let r = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_LO), r1);
    let mut p = _mm256_set1_pd(POLY[0]);
    for &c in &POLY[1..] {
        p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(c));
    }
    p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0));
    p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0));
    let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(
        _mm256_add_epi64(ki, _mm256_set1_epi64x(1023)),
    ));
    let res = _mm256_mul_pd(p, scale);
    // Flush x < EXP_MIN lanes to 0.0 (NaN lanes compare false and
    // propagate, matching the scalar mirror).
    let under = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(EXP_MIN));
    _mm256_andnot_pd(under, res)
}

/// # Safety
///
/// CPU must support avx2+fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn se_apply_avx2(sf2: f64, s1v: f64, sq2: &[f64], krow: &mut [f64]) {
    debug_assert_eq!(sq2.len(), krow.len());
    let n = krow.len();
    let base = _mm256_set1_pd(s1v);
    let neg_half = _mm256_set1_pd(-0.5);
    let sf2v = _mm256_set1_pd(sf2);
    let zero = _mm256_setzero_pd();
    let mut j = 0;
    while j + 4 <= n {
        let kv = _mm256_loadu_pd(krow.as_ptr().add(j));
        let s2 = _mm256_loadu_pd(sq2.as_ptr().add(j));
        let sq = _mm256_sub_pd(
            _mm256_add_pd(base, s2),
            _mm256_add_pd(kv, kv),
        );
        let sq = _mm256_max_pd(sq, zero);
        let e = exp_neg_pd4(_mm256_mul_pd(neg_half, sq));
        _mm256_storeu_pd(
            krow.as_mut_ptr().add(j),
            _mm256_mul_pd(sf2v, e),
        );
        j += 4;
    }
    while j < n {
        krow[j] = se_lane(sf2, s1v, sq2[j], krow[j]);
        j += 1;
    }
}

/// 8-wide polynomial `e^x` lanes — same scheme as the 4-wide version.
///
/// # Safety
///
/// CPU must support avx512f.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn exp_neg_pd8(x: __m512d) -> __m512d {
    let t = _mm512_mul_pd(x, _mm512_set1_pd(LOG2E));
    let kb = _mm512_add_pd(t, _mm512_set1_pd(MAGIC));
    let k = _mm512_sub_pd(kb, _mm512_set1_pd(MAGIC));
    let ki = _mm512_sub_epi64(
        _mm512_castpd_si512(kb),
        _mm512_set1_epi64(MAGIC_BITS),
    );
    let r1 = _mm512_fnmadd_pd(k, _mm512_set1_pd(LN2_HI), x);
    let r = _mm512_fnmadd_pd(k, _mm512_set1_pd(LN2_LO), r1);
    let mut p = _mm512_set1_pd(POLY[0]);
    for &c in &POLY[1..] {
        p = _mm512_fmadd_pd(p, r, _mm512_set1_pd(c));
    }
    p = _mm512_fmadd_pd(p, r, _mm512_set1_pd(1.0));
    p = _mm512_fmadd_pd(p, r, _mm512_set1_pd(1.0));
    let scale = _mm512_castsi512_pd(_mm512_slli_epi64::<52>(
        _mm512_add_epi64(ki, _mm512_set1_epi64(1023)),
    ));
    let res = _mm512_mul_pd(p, scale);
    // Keep lanes that are NOT below EXP_MIN (unordered → keep, so NaN
    // propagates like the scalar mirror); flushed lanes become 0.0.
    let keep = _mm512_cmp_pd_mask::<_CMP_NLT_UQ>(x, _mm512_set1_pd(EXP_MIN));
    _mm512_maskz_mov_pd(keep, res)
}

/// # Safety
///
/// CPU must support avx512f.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn se_apply_avx512(sf2: f64, s1v: f64, sq2: &[f64], krow: &mut [f64]) {
    debug_assert_eq!(sq2.len(), krow.len());
    let n = krow.len();
    let base = _mm512_set1_pd(s1v);
    let neg_half = _mm512_set1_pd(-0.5);
    let sf2v = _mm512_set1_pd(sf2);
    let zero = _mm512_setzero_pd();
    let mut j = 0;
    while j + 8 <= n {
        let kv = _mm512_loadu_pd(krow.as_ptr().add(j));
        let s2 = _mm512_loadu_pd(sq2.as_ptr().add(j));
        let sq = _mm512_sub_pd(
            _mm512_add_pd(base, s2),
            _mm512_add_pd(kv, kv),
        );
        let sq = _mm512_max_pd(sq, zero);
        let e = exp_neg_pd8(_mm512_mul_pd(neg_half, sq));
        _mm512_storeu_pd(
            krow.as_mut_ptr().add(j),
            _mm512_mul_pd(sf2v, e),
        );
        j += 8;
    }
    while j < n {
        krow[j] = se_lane(sf2, s1v, sq2[j], krow[j]);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::prop_check;

    fn ulp_diff(a: f64, b: f64) -> u64 {
        // both operands are positive (or zero) on this domain
        (a.to_bits() as i64 - b.to_bits() as i64).unsigned_abs()
    }

    /// The documented ulp bound against libm exp, across the whole
    /// domain plus the boundary/identity edges.
    #[test]
    fn exp_neg_within_ulp_bound_of_libm() {
        // exact edges
        assert_eq!(exp_neg(0.0), 1.0);
        assert_eq!(exp_neg(-0.0), 1.0);
        assert_eq!(exp_neg(-709.0), 0.0);
        assert_eq!(exp_neg(f64::NEG_INFINITY), 0.0);
        assert!(exp_neg(f64::NAN).is_nan());
        // boundary stays a positive normal within the bound
        let b = exp_neg(EXP_MIN);
        assert!(b > 0.0 && b.is_normal());
        assert!(ulp_diff(b, EXP_MIN.exp()) <= EXP_NEG_ULP_BOUND);
        // dense sweep: uniform over the domain + log-uniform near 0
        prop_check("exp-neg-ulp", 40, |g| {
            for _ in 0..256 {
                let x = -g.f64_in(0.0, 708.0);
                let d = ulp_diff(exp_neg(x), x.exp());
                assert!(d <= EXP_NEG_ULP_BOUND, "x={x}: {d} ulp");
                let x = -(10f64).powf(g.f64_in(-12.0, 2.5));
                let d = ulp_diff(exp_neg(x), x.exp());
                assert!(d <= EXP_NEG_ULP_BOUND, "x={x}: {d} ulp");
            }
        });
    }

    /// Portable se_apply is the seed expression bitwise (the contract
    /// `PGPR_SIMD=portable` ≡ pre-SIMD engine rests on).
    #[test]
    fn se_apply_portable_matches_seed_expression() {
        prop_check("se-apply-portable", 20, |g| {
            let n = g.usize_in(1, 40);
            let sf2 = g.f64_in(0.1, 3.0);
            let s1v = g.f64_in(0.0, 50.0);
            let sq2: Vec<f64> =
                (0..n).map(|_| g.f64_in(0.0, 50.0)).collect();
            let cross: Vec<f64> =
                (0..n).map(|_| g.f64_in(-10.0, 10.0)).collect();
            let mut krow = cross.clone();
            se_apply(SimdTier::Portable, sf2, s1v, &sq2, &mut krow);
            for j in 0..n {
                let sq = (s1v + sq2[j] - 2.0 * cross[j]).max(0.0);
                assert_eq!(krow[j], sf2 * (-0.5 * sq).exp());
            }
        });
    }

    /// AVX vector lanes are bitwise-equal to the scalar mirror
    /// [`se_lane`] (which the column tails also use), and every tier
    /// stays within a tight relative tolerance of the libm oracle.
    #[test]
    fn se_apply_avx_lanes_match_scalar_mirror_bitwise() {
        for tier in SimdTier::available() {
            prop_check(&format!("se-apply-{}", tier.name()), 10, |g| {
                let n = g.usize_in(1, 70); // spans vector body + tail
                let sf2 = g.f64_in(0.1, 3.0);
                let s1v = g.f64_in(0.0, 80.0);
                let sq2: Vec<f64> =
                    (0..n).map(|_| g.f64_in(0.0, 80.0)).collect();
                let cross: Vec<f64> =
                    (0..n).map(|_| g.f64_in(-20.0, 20.0)).collect();
                let mut krow = cross.clone();
                se_apply(tier, sf2, s1v, &sq2, &mut krow);
                for j in 0..n {
                    if tier != SimdTier::Portable {
                        let want = se_lane(sf2, s1v, sq2[j], cross[j]);
                        assert_eq!(
                            krow[j].to_bits(),
                            want.to_bits(),
                            "{} lane {j}",
                            tier.name()
                        );
                    }
                    let sq = (s1v + sq2[j] - 2.0 * cross[j]).max(0.0);
                    let oracle = se_point(sf2, sq);
                    assert!(
                        (krow[j] - oracle).abs()
                            <= 1e-14 * oracle.abs().max(1e-300),
                        "{} vs oracle at {j}",
                        tier.name()
                    );
                }
            });
        }
    }
}
