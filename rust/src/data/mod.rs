//! Datasets and workload generators.
//!
//! The paper evaluates on two proprietary/real datasets; this module
//! builds faithful synthetic equivalents (see DESIGN.md §Substitutions):
//!
//! * [`aimpeak`] — spatiotemporal traffic speeds on a generated urban
//!   road network, MDS-embedded per the paper's footnote 2;
//! * [`sarcos`]  — 7-DoF robot-arm inverse dynamics via recursive
//!   Newton–Euler, 21-d inputs;
//! * [`rff`]     — random-Fourier-feature GP sampler used to draw smooth
//!   latent fields at sizes where exact GP sampling is cubic-infeasible;
//! * [`partition`] — Definition 1 even partitions: random and the
//!   paper's parallelized clustering scheme (Remark 2 after Def. 5).

pub mod aimpeak;
pub mod partition;
pub mod rff;
pub mod sarcos;

use crate::linalg::Mat;
use crate::util::Pcg64;

/// A regression dataset: inputs `x` (n×d, row per point) and outputs `y`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(x: Mat, y: Vec<f64>) -> Dataset {
        assert_eq!(x.rows, y.len(), "x/y length mismatch");
        Dataset { x, y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    /// Subset by row indices.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Random split into (rest, test) where test gets `test_frac` of rows.
    pub fn split_test(&self, test_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let n = self.len();
        let n_test = ((n as f64) * test_frac).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.select(train_idx), self.select(test_idx))
    }

    /// First `n` rows (after an external shuffle) — used for "training
    /// data of varying sizes randomly selected" sweeps.
    pub fn take(&self, n: usize) -> Dataset {
        assert!(n <= self.len());
        let idx: Vec<usize> = (0..n).collect();
        self.select(&idx)
    }

    pub fn y_mean(&self) -> f64 {
        if self.y.is_empty() {
            0.0
        } else {
            self.y.iter().sum::<f64>() / self.len() as f64
        }
    }

    pub fn y_std(&self) -> f64 {
        let m = self.y_mean();
        let v = self.y.iter().map(|y| (y - m) * (y - m)).sum::<f64>()
            / self.len().max(1) as f64;
        v.sqrt()
    }

    /// Center outputs in place; returns the subtracted mean. The paper's
    /// equations assume a known prior mean — we use the empirical train
    /// mean, the standard choice.
    pub fn center_y(&mut self) -> f64 {
        let m = self.y_mean();
        for y in self.y.iter_mut() {
            *y -= m;
        }
        m
    }

    /// Affine-rescale outputs to the given mean/std (used to match the
    /// paper's reported dataset statistics).
    pub fn rescale_y(&mut self, target_mean: f64, target_std: f64) {
        let m = self.y_mean();
        let s = self.y_std().max(1e-12);
        for y in self.y.iter_mut() {
            *y = (*y - m) / s * target_std + target_mean;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let x = Mat::from_fn(n, 2, |i, j| (i * 2 + j) as f64);
        let y = (0..n).map(|i| i as f64).collect();
        Dataset::new(x, y)
    }

    #[test]
    fn select_and_take() {
        let d = toy(10);
        let s = d.select(&[3, 7]);
        assert_eq!(s.y, vec![3.0, 7.0]);
        assert_eq!(s.x.row(1), d.x.row(7));
        assert_eq!(d.take(4).len(), 4);
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let d = toy(20);
        let mut rng = Pcg64::seed(1);
        let (train, test) = d.split_test(0.25, &mut rng);
        assert_eq!(test.len(), 5);
        assert_eq!(train.len(), 15);
        let mut all: Vec<i64> =
            train.y.iter().chain(test.y.iter()).map(|&v| v as i64).collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<i64>>());
    }

    #[test]
    fn center_and_rescale() {
        let mut d = toy(5);
        let m = d.center_y();
        assert!((m - 2.0).abs() < 1e-12);
        assert!(d.y_mean().abs() < 1e-12);
        d.rescale_y(49.5, 21.7);
        assert!((d.y_mean() - 49.5).abs() < 1e-9);
        assert!((d.y_std() - 21.7).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        Dataset::new(Mat::zeros(3, 1), vec![0.0; 4]);
    }
}
