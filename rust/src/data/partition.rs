//! Definition 1 partitions: distribute `(D, y_D)` evenly among M machines.
//!
//! Two schemes:
//! * [`random_partition`] — uniformly random even blocks (the baseline);
//! * [`cluster_partition`] — the paper's *parallelized clustering scheme*
//!   (Remark 2 after Definition 5): each machine picks a random cluster
//!   center from its initial block, every training/test point is assigned
//!   to the nearest center subject to the hard caps `|D|/M` and `|U|/M`,
//!   which keeps the partition even (Definition 1) while co-locating
//!   correlated D_m and U_m — the thing pPIC's local term feeds on.

use crate::linalg::Mat;
use crate::util::Pcg64;

/// Even random partition of `0..n` into `m` blocks. Requires `m | n`
/// (the paper's Definition 1 assumes even divisibility; callers trim).
pub fn random_partition(n: usize, m: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(m >= 1 && n % m == 0, "random_partition: {m} must divide {n}");
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.chunks(n / m).map(|c| c.to_vec()).collect()
}

/// Result of the clustering scheme: block index lists for D and U.
#[derive(Debug, Clone)]
pub struct ClusterPartition {
    pub d_blocks: Vec<Vec<usize>>,
    pub u_blocks: Vec<Vec<usize>>,
    /// chosen cluster-center rows (indices into `xd`)
    pub centers: Vec<usize>,
}

/// The paper's parallelized clustering scheme over training inputs `xd`
/// and test inputs `xu`. Both must divide evenly by `m`.
pub fn cluster_partition(
    xd: &Mat,
    xu: &Mat,
    m: usize,
    rng: &mut Pcg64,
) -> ClusterPartition {
    let n = xd.rows;
    let u = xu.rows;
    assert!(m >= 1 && n % m == 0, "cluster_partition: {m} must divide {n}");
    assert!(u % m == 0, "cluster_partition: {m} must divide |U|={u}");

    // Step 1 of the scheme: initial random even blocks; machine i picks a
    // random center from its own local data.
    let initial = random_partition(n, m, rng);
    let centers: Vec<usize> = initial
        .iter()
        .map(|blk| blk[rng.below(blk.len())])
        .collect();

    let assign = |x: &Mat, cap: usize, rng: &mut Pcg64| -> Vec<Vec<usize>> {
        // Each point goes to the nearest center whose block still has
        // room; points are visited in random order so overflow spills
        // are unbiased (mirrors the asynchronous sends of the paper's
        // scheme under the same capacity constraint).
        let mut order: Vec<usize> = (0..x.rows).collect();
        rng.shuffle(&mut order);
        let mut blocks: Vec<Vec<usize>> = vec![Vec::with_capacity(cap); m];
        for &p in &order {
            // centers sorted by distance
            let mut by_dist: Vec<(f64, usize)> = centers
                .iter()
                .enumerate()
                .map(|(c, &ci)| {
                    let mut s = 0.0;
                    for col in 0..x.cols.min(xd.cols) {
                        let diff = x[(p, col)] - xd[(ci, col)];
                        s += diff * diff;
                    }
                    (s, c)
                })
                .collect();
            by_dist.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let slot = by_dist
                .iter()
                .find(|(_, c)| blocks[*c].len() < cap)
                .map(|(_, c)| *c)
                .expect("capacity sums to n");
            blocks[slot].push(p);
        }
        blocks
    };

    let d_blocks = assign(xd, n / m, rng);
    let u_blocks = assign(xu, u / m, rng);
    ClusterPartition { d_blocks, u_blocks, centers }
}

/// Check Definition 1 invariants: blocks are disjoint, cover `0..n`, and
/// all have equal size. Used by tests and debug assertions.
pub fn is_even_partition(blocks: &[Vec<usize>], n: usize) -> bool {
    if blocks.is_empty() {
        return n == 0;
    }
    let size = blocks[0].len();
    if blocks.iter().any(|b| b.len() != size) {
        return false;
    }
    let mut seen = vec![false; n];
    let mut count = 0;
    for b in blocks {
        for &i in b {
            if i >= n || seen[i] {
                return false;
            }
            seen[i] = true;
            count += 1;
        }
    }
    count == n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::prop_check;

    #[test]
    fn random_partition_invariants() {
        prop_check("random-partition", 24, |g| {
            let m = g.usize_in(1, 9);
            let per = g.usize_in(1, 12);
            let n = m * per;
            let blocks = random_partition(n, m, g.rng());
            assert_eq!(blocks.len(), m);
            assert!(is_even_partition(&blocks, n));
        });
    }

    #[test]
    #[should_panic]
    fn random_partition_requires_divisibility() {
        random_partition(10, 3, &mut Pcg64::seed(1));
    }

    #[test]
    fn cluster_partition_invariants() {
        prop_check("cluster-partition", 16, |g| {
            let m = g.usize_in(1, 6);
            let nd = m * g.usize_in(2, 10);
            let nu = m * g.usize_in(1, 6);
            let d = g.usize_in(1, 4);
            let xd = Mat::from_vec(nd, d, g.normal_vec(nd * d));
            let xu = Mat::from_vec(nu, d, g.normal_vec(nu * d));
            let p = cluster_partition(&xd, &xu, m, g.rng());
            assert!(is_even_partition(&p.d_blocks, nd));
            assert!(is_even_partition(&p.u_blocks, nu));
            assert_eq!(p.centers.len(), m);
            assert!(p.centers.iter().all(|&c| c < nd));
        });
    }

    /// Mean squared distance of points to their block's center.
    fn within_block_sqdist(xd: &Mat, blocks: &[Vec<usize>], centers: &[usize]) -> f64 {
        let mut s = 0.0;
        let mut n = 0.0;
        for (b, blk) in blocks.iter().enumerate() {
            for &i in blk {
                for c in 0..xd.cols {
                    let diff = xd[(i, c)] - xd[(centers[b], c)];
                    s += diff * diff;
                }
                n += 1.0;
            }
        }
        s / n
    }

    #[test]
    fn clustering_beats_random_partition_on_locality() {
        // Two well-separated blobs. The paper's scheme can still draw both
        // centers from one blob (random pick per initial block), so the
        // guarantee is statistical: averaged over seeds, nearest-center
        // assignment puts points much closer to their center than a
        // random even partition does.
        let n = 40;
        let mut cluster_cost = 0.0;
        let mut random_cost = 0.0;
        for seed in 0..10 {
            let mut rng = Pcg64::seed(100 + seed);
            let mut xd = Mat::zeros(n, 2);
            for i in 0..n {
                let offset = if i < n / 2 { -10.0 } else { 10.0 };
                xd[(i, 0)] = offset + rng.normal() * 0.1;
                xd[(i, 1)] = rng.normal() * 0.1;
            }
            let xu = xd.clone();
            let p = cluster_partition(&xd, &xu, 2, &mut rng);
            cluster_cost += within_block_sqdist(&xd, &p.d_blocks, &p.centers);
            let rp = random_partition(n, 2, &mut rng);
            random_cost += within_block_sqdist(&xd, &rp, &p.centers);
        }
        assert!(
            cluster_cost < random_cost,
            "cluster {cluster_cost} vs random {random_cost}"
        );
    }

    #[test]
    fn is_even_partition_detects_violations() {
        assert!(is_even_partition(&[vec![0, 1], vec![2, 3]], 4));
        assert!(!is_even_partition(&[vec![0, 1], vec![1, 2]], 4)); // dup
        assert!(!is_even_partition(&[vec![0], vec![1, 2]], 3)); // uneven
        assert!(!is_even_partition(&[vec![0, 5]], 2)); // out of range
        assert!(!is_even_partition(&[vec![0], vec![1]], 3)); // incomplete
    }

    #[test]
    fn single_machine_gets_everything() {
        let mut rng = Pcg64::seed(4);
        let blocks = random_partition(8, 1, &mut rng);
        assert_eq!(blocks.len(), 1);
        assert!(is_even_partition(&blocks, 8));
    }
}
