//! Synthetic SARCOS-like robot-arm inverse-dynamics workload.
//!
//! The paper's SARCOS dataset (48933 records) maps 21-d inputs — 7 joint
//! positions, 7 velocities, 7 accelerations — to the torque of joint 1.
//! We reproduce that map with a real (simplified) rigid-body dynamics
//! model: a 7-link serial chain with revolute joints, torques computed by
//! the recursive Newton–Euler algorithm (RNE). Joint trajectories are
//! random sums of sinusoids (smooth, physically-plausible excitation);
//! outputs are rescaled to the paper's mean 13.7 / sd 20.5.
//!
//! The point of using actual RNE rather than an arbitrary random function:
//! inverse dynamics is multimodal and short-length-scale in parts of the
//! state space — exactly the regime where PIC's local blocks beat PITC's
//! pure summary (the paper's SARCOS-side observations).

use super::Dataset;
use crate::linalg::Mat;
use crate::util::Pcg64;

pub const DOF: usize = 7;
pub const INPUT_DIM: usize = 3 * DOF;

// ---------------------------------------------------------------- vec3

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Vec3(pub f64, pub f64, pub f64);

impl Vec3 {
    pub const ZERO: Vec3 = Vec3(0.0, 0.0, 0.0);

    pub fn add(self, o: Vec3) -> Vec3 {
        Vec3(self.0 + o.0, self.1 + o.1, self.2 + o.2)
    }
    pub fn sub(self, o: Vec3) -> Vec3 {
        Vec3(self.0 - o.0, self.1 - o.1, self.2 - o.2)
    }
    pub fn scale(self, s: f64) -> Vec3 {
        Vec3(self.0 * s, self.1 * s, self.2 * s)
    }
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3(
            self.1 * o.2 - self.2 * o.1,
            self.2 * o.0 - self.0 * o.2,
            self.0 * o.1 - self.1 * o.0,
        )
    }
    pub fn dot(self, o: Vec3) -> f64 {
        self.0 * o.0 + self.1 * o.1 + self.2 * o.2
    }
}

/// 3×3 rotation matrix (row-major), only what RNE needs.
#[derive(Debug, Clone, Copy)]
pub struct Rot3(pub [f64; 9]);

impl Rot3 {
    /// Rotation by angle about Z then a fixed link twist about X
    /// (standard DH-style composition).
    pub fn dh(theta: f64, alpha: f64) -> Rot3 {
        let (ct, st) = (theta.cos(), theta.sin());
        let (ca, sa) = (alpha.cos(), alpha.sin());
        Rot3([
            ct, -st * ca, st * sa,
            st, ct * ca, -ct * sa,
            0.0, sa, ca,
        ])
    }

    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        let m = &self.0;
        Vec3(
            m[0] * v.0 + m[1] * v.1 + m[2] * v.2,
            m[3] * v.0 + m[4] * v.1 + m[5] * v.2,
            m[6] * v.0 + m[7] * v.1 + m[8] * v.2,
        )
    }

    /// Transpose (inverse) applied to a vector.
    pub fn t_mul_vec(&self, v: Vec3) -> Vec3 {
        let m = &self.0;
        Vec3(
            m[0] * v.0 + m[3] * v.1 + m[6] * v.2,
            m[1] * v.0 + m[4] * v.1 + m[7] * v.2,
            m[2] * v.0 + m[5] * v.1 + m[8] * v.2,
        )
    }
}

// ---------------------------------------------------------------- arm

/// Per-link parameters of the serial chain.
#[derive(Debug, Clone)]
pub struct Link {
    /// DH twist angle between joint axes.
    pub alpha: f64,
    /// link length (m), translation along the rotated X.
    pub a: f64,
    /// link mass (kg)
    pub mass: f64,
    /// center of mass offset in the link frame
    pub com: Vec3,
    /// principal moments of inertia (diagonal, link frame)
    pub inertia: Vec3,
    /// viscous friction coefficient
    pub friction: f64,
}

/// A 7-DoF serial arm.
#[derive(Debug, Clone)]
pub struct Arm {
    pub links: Vec<Link>,
    pub gravity: Vec3,
}

impl Arm {
    /// A SARCOS-like anthropomorphic 7-DoF arm (masses/lengths roughly
    /// human-arm scale; alternating twists like shoulder/elbow/wrist).
    pub fn sarcos_like() -> Arm {
        let alphas = [
            std::f64::consts::FRAC_PI_2,
            -std::f64::consts::FRAC_PI_2,
            std::f64::consts::FRAC_PI_2,
            -std::f64::consts::FRAC_PI_2,
            std::f64::consts::FRAC_PI_2,
            -std::f64::consts::FRAC_PI_2,
            0.0,
        ];
        let lengths = [0.0, 0.30, 0.05, 0.25, 0.05, 0.10, 0.06];
        let masses = [5.0, 4.0, 2.5, 2.0, 1.2, 0.8, 0.4];
        let links = (0..DOF)
            .map(|i| Link {
                alpha: alphas[i],
                a: lengths[i],
                mass: masses[i],
                com: Vec3(lengths[i] * 0.5, 0.0, 0.02),
                inertia: Vec3(
                    0.02 * masses[i],
                    0.02 * masses[i],
                    0.01 * masses[i],
                ),
                friction: 0.1,
            })
            .collect();
        Arm {
            links,
            gravity: Vec3(0.0, 0.0, -9.81),
        }
    }

    /// Recursive Newton–Euler inverse dynamics: joint torques for state
    /// (q, qd, qdd). Forward pass propagates velocities/accelerations
    /// base→tip; backward pass propagates forces tip→base.
    pub fn inverse_dynamics(&self, q: &[f64], qd: &[f64], qdd: &[f64]) -> Vec<f64> {
        let n = self.links.len();
        assert!(q.len() == n && qd.len() == n && qdd.len() == n);
        let z = Vec3(0.0, 0.0, 1.0); // joint axis in local frame

        // forward recursion
        let mut rots = Vec::with_capacity(n); // R_i: frame i-1 -> i
        let mut w = Vec3::ZERO; // angular velocity
        let mut wd = Vec3::ZERO; // angular acceleration
        // linear acceleration of frame origin; seed with -g so gravity
        // enters every link (standard trick)
        let mut a = self.gravity.scale(-1.0);
        let mut ws = Vec::with_capacity(n);
        let mut wds = Vec::with_capacity(n);
        let mut acs = Vec::with_capacity(n); // com linear accel per link
        let mut aos = Vec::with_capacity(n); // origin accel per link

        for i in 0..n {
            let link = &self.links[i];
            let r = Rot3::dh(q[i], link.alpha);
            // transform into frame i (rotate by Rᵀ)
            let w_in = r.t_mul_vec(w);
            let wd_in = r.t_mul_vec(wd);
            let a_in = r.t_mul_vec(a);
            // revolute joint about local z
            let w_i = w_in.add(z.scale(qd[i]));
            let wd_i = wd_in
                .add(z.scale(qdd[i]))
                .add(w_in.cross(z.scale(qd[i])));
            let p = Vec3(link.a, 0.0, 0.0); // origin offset in frame i
            let a_i = a_in
                .add(wd_i.cross(p))
                .add(w_i.cross(w_i.cross(p)));
            let ac = a_i
                .add(wd_i.cross(link.com))
                .add(w_i.cross(w_i.cross(link.com)));
            rots.push(r);
            ws.push(w_i);
            wds.push(wd_i);
            aos.push(a_i);
            acs.push(ac);
            w = w_i;
            wd = wd_i;
            a = a_i;
        }

        // backward recursion
        let mut f_next = Vec3::ZERO;
        let mut t_next = Vec3::ZERO;
        let mut torques = vec![0.0; n];
        for i in (0..n).rev() {
            let link = &self.links[i];
            let inertia_w = |v: Vec3| -> Vec3 {
                Vec3(
                    link.inertia.0 * v.0,
                    link.inertia.1 * v.1,
                    link.inertia.2 * v.2,
                )
            };
            let f_inertial = acs[i].scale(link.mass);
            let t_inertial = inertia_w(wds[i])
                .add(ws[i].cross(inertia_w(ws[i])));
            // force/torque from the next link, expressed in this frame
            let (f_child, t_child) = if i + 1 < n {
                let r_next = rots[i + 1];
                let f_c = r_next.mul_vec(f_next);
                let p_next = Vec3(self.links[i + 1].a, 0.0, 0.0);
                let t_c = r_next.mul_vec(t_next).add(p_next.cross(f_c));
                (f_c, t_c)
            } else {
                (Vec3::ZERO, Vec3::ZERO)
            };
            let f_i = f_inertial.add(f_child);
            let t_i = t_inertial
                .add(link.com.cross(f_inertial))
                .add(t_child);
            torques[i] = t_i.dot(Vec3(0.0, 0.0, 1.0)) + link.friction * qd[i];
            f_next = f_i;
            t_next = t_i;
        }
        torques
    }
}

// ------------------------------------------------------------- dataset

/// Configuration for the SARCOS-like dataset.
#[derive(Debug, Clone)]
pub struct SarcosConfig {
    pub n_samples: usize,
    /// sinusoid components per joint trajectory
    pub harmonics: usize,
    /// observation noise std before rescaling
    pub noise_std: f64,
    pub seed: u64,
}

impl Default for SarcosConfig {
    fn default() -> Self {
        SarcosConfig {
            n_samples: 5000,
            harmonics: 3,
            noise_std: 0.02,
            seed: 2005,
        }
    }
}

/// Generate `(q, qd, qdd) → torque_1` samples along random smooth
/// trajectories of a 7-DoF arm.
pub fn generate(cfg: &SarcosConfig) -> Dataset {
    let arm = Arm::sarcos_like();
    let mut rng = Pcg64::new(cfg.seed, 0x5A);
    // random multi-sine trajectory parameters per joint
    let mut amp = vec![vec![0.0; cfg.harmonics]; DOF];
    let mut freq = vec![vec![0.0; cfg.harmonics]; DOF];
    let mut phase = vec![vec![0.0; cfg.harmonics]; DOF];
    for j in 0..DOF {
        for h in 0..cfg.harmonics {
            amp[j][h] = rng.uniform_in(0.2, 0.9) / (h + 1) as f64;
            freq[j][h] = rng.uniform_in(0.3, 2.0) * (h + 1) as f64;
            phase[j][h] = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
        }
    }

    let mut x = Mat::zeros(cfg.n_samples, INPUT_DIM);
    let mut y = Vec::with_capacity(cfg.n_samples);
    for s in 0..cfg.n_samples {
        let t = rng.uniform_in(0.0, 60.0);
        let mut q = [0.0; DOF];
        let mut qd = [0.0; DOF];
        let mut qdd = [0.0; DOF];
        for j in 0..DOF {
            for h in 0..cfg.harmonics {
                let wt = freq[j][h] * t + phase[j][h];
                q[j] += amp[j][h] * wt.sin();
                qd[j] += amp[j][h] * freq[j][h] * wt.cos();
                qdd[j] -= amp[j][h] * freq[j][h] * freq[j][h] * wt.sin();
            }
        }
        let tau = arm.inverse_dynamics(&q, &qd, &qdd);
        for j in 0..DOF {
            x[(s, j)] = q[j];
            x[(s, DOF + j)] = qd[j];
            x[(s, 2 * DOF + j)] = qdd[j];
        }
        y.push(tau[0] + cfg.noise_std * rng.normal());
    }
    let mut ds = Dataset::new(x, y);
    ds.rescale_y(13.7, 20.5);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn vec3_algebra() {
        let a = Vec3(1.0, 0.0, 0.0);
        let b = Vec3(0.0, 1.0, 0.0);
        assert_eq!(a.cross(b), Vec3(0.0, 0.0, 1.0));
        assert_eq!(b.cross(a), Vec3(0.0, 0.0, -1.0));
        assert_eq!(a.dot(b), 0.0);
        assert_eq!(a.add(b).sub(b), a);
    }

    #[test]
    fn rotation_orthogonality() {
        let r = Rot3::dh(0.7, -0.4);
        let v = Vec3(0.3, -1.2, 0.8);
        let back = r.t_mul_vec(r.mul_vec(v));
        assert_close(back.0, v.0, 1e-12, 1e-12);
        assert_close(back.1, v.1, 1e-12, 1e-12);
        assert_close(back.2, v.2, 1e-12, 1e-12);
    }

    #[test]
    fn static_torques_resist_gravity() {
        // at rest, torques are pure gravity loads; a configuration with
        // the arm stretched horizontally must load the shoulder more than
        // the same arm hanging straight down (zero moment arm).
        let arm = Arm::sarcos_like();
        let zeros = [0.0; DOF];
        let hanging = arm.inverse_dynamics(&zeros, &zeros, &zeros);
        let mut q = [0.0; DOF];
        q[1] = std::f64::consts::FRAC_PI_2;
        let stretched = arm.inverse_dynamics(&q, &zeros, &zeros);
        assert!(
            stretched[1].abs() > hanging[1].abs(),
            "stretched {} vs hanging {}",
            stretched[1],
            hanging[1]
        );
    }

    #[test]
    fn inertial_torque_scales_with_acceleration() {
        let arm = Arm::sarcos_like();
        let q = [0.1; DOF];
        let qd = [0.0; DOF];
        let mut qdd1 = [0.0; DOF];
        qdd1[0] = 1.0;
        let mut qdd2 = [0.0; DOF];
        qdd2[0] = 2.0;
        let t0 = arm.inverse_dynamics(&q, &qd, &[0.0; DOF]);
        let t1 = arm.inverse_dynamics(&q, &qd, &qdd1);
        let t2 = arm.inverse_dynamics(&q, &qd, &qdd2);
        // torque is affine in qdd: t2 - t0 == 2 (t1 - t0)
        assert_close(t2[0] - t0[0], 2.0 * (t1[0] - t0[0]), 1e-9, 1e-9);
    }

    #[test]
    fn friction_adds_to_velocity_sign() {
        let arm = Arm::sarcos_like();
        let q = [0.0; DOF];
        let mut qd = [0.0; DOF];
        let base = arm.inverse_dynamics(&q, &qd, &[0.0; DOF]);
        qd[3] = 1.0;
        let moved = arm.inverse_dynamics(&q, &qd, &[0.0; DOF]);
        // viscous term contributes friction * qd to joint 3
        assert!(moved[3] > base[3]);
    }

    #[test]
    fn dataset_statistics_match_paper() {
        let ds = generate(&SarcosConfig { n_samples: 800, ..Default::default() });
        assert_eq!(ds.len(), 800);
        assert_eq!(ds.dim(), 21);
        assert!((ds.y_mean() - 13.7).abs() < 1e-6);
        assert!((ds.y_std() - 20.5).abs() < 1e-6);
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = SarcosConfig { n_samples: 50, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn inputs_have_pos_vel_acc_blocks() {
        let ds = generate(&SarcosConfig { n_samples: 200, ..Default::default() });
        // velocities/accelerations have larger spread than positions
        // (multi-sine: |qd| ~ amp*freq, |qdd| ~ amp*freq²)
        let col_std = |c: usize| -> f64 {
            let m: f64 = (0..ds.len()).map(|r| ds.x[(r, c)]).sum::<f64>()
                / ds.len() as f64;
            ((0..ds.len()).map(|r| (ds.x[(r, c)] - m).powi(2)).sum::<f64>()
                / ds.len() as f64)
                .sqrt()
        };
        let q_std = col_std(0);
        let qdd_std = col_std(14);
        assert!(qdd_std > q_std, "qdd {qdd_std} vs q {q_std}");
    }
}
