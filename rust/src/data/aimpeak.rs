//! Synthetic AIMPEAK-like spatiotemporal traffic workload.
//!
//! The paper's AIMPEAK dataset (41850 records) holds traffic speeds over
//! 775 road segments × 54 five-minute morning-peak slots; each input is a
//! 5-d feature vector, and the road network is embedded into Euclidean
//! space with MDS (footnote 2) so the SE kernel applies.
//!
//! This generator reproduces that *structure*:
//!  1. build an urban road network — a perturbed grid of intersections
//!     with highway / arterial / slip-road segments carrying (length,
//!     lanes, speed-limit, direction) attributes;
//!  2. compute segment-to-segment shortest-path distances (Dijkstra over
//!     the line graph) and embed segments into `EMBED_DIM` Euclidean
//!     coordinates with classical MDS — the paper's relational→Euclidean
//!     trick;
//!  3. inputs are `(embedding…, time)` (d = EMBED_DIM + 1 = 5, matching
//!     the paper's dimensionality);
//!  4. speeds = smooth GP field over the embedding (RFF draw, long
//!     length-scales — the regime low-rank methods are built for)
//!     + a road-class baseline + a morning-peak congestion dip,
//!     rescaled to the paper's mean 49.5 / sd 21.7 km/h.

use super::rff::RffSampler;
use super::Dataset;
use crate::kernel::SeArd;
use crate::linalg::mds::classical_mds;
use crate::linalg::Mat;
use crate::util::Pcg64;

/// Euclidean embedding dimensionality (spatial part of the input).
pub const EMBED_DIM: usize = 4;
/// Number of five-minute slots in the paper's 6:00–10:30 window.
pub const TIME_SLOTS: usize = 54;

/// Road segment classes with distinct attribute distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoadClass {
    Highway,
    Arterial,
    SlipRoad,
}

/// One directed road segment.
#[derive(Debug, Clone)]
pub struct Segment {
    pub from: usize,
    pub to: usize,
    pub class: RoadClass,
    pub length_km: f64,
    pub lanes: usize,
    pub speed_limit: f64,
    /// heading in radians
    pub direction: f64,
}

/// A generated road network: intersections on a jittered grid plus the
/// segment list (line-graph adjacency is derived on demand).
#[derive(Debug, Clone)]
pub struct RoadNetwork {
    pub nodes: Vec<(f64, f64)>,
    pub segments: Vec<Segment>,
}

impl RoadNetwork {
    /// Generate a `gw×gh` jittered-grid city with a highway ring and
    /// slip-road connectors. Total segments ≈ 2·(2·gw·gh − gw − gh).
    pub fn generate(gw: usize, gh: usize, rng: &mut Pcg64) -> RoadNetwork {
        assert!(gw >= 2 && gh >= 2);
        let mut nodes = Vec::with_capacity(gw * gh);
        for iy in 0..gh {
            for ix in 0..gw {
                nodes.push((
                    ix as f64 + rng.uniform_in(-0.2, 0.2),
                    iy as f64 + rng.uniform_in(-0.2, 0.2),
                ));
            }
        }
        let id = |ix: usize, iy: usize| iy * gw + ix;
        let mut segments = Vec::new();
        let mut add_bidirectional =
            |a: usize, b: usize, class: RoadClass, rng: &mut Pcg64, nodes: &[(f64, f64)]| {
                let (ax, ay) = nodes[a];
                let (bx, by) = nodes[b];
                let base_len = ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt();
                let (lanes, limit, len_scale) = match class {
                    RoadClass::Highway => (rng.below(2) + 3, 90.0, 1.6),
                    RoadClass::Arterial => (rng.below(2) + 2, 60.0, 1.0),
                    RoadClass::SlipRoad => (1, 40.0, 0.35),
                };
                for (f, t) in [(a, b), (b, a)] {
                    let (fx, fy) = nodes[f];
                    let (tx, ty) = nodes[t];
                    segments.push(Segment {
                        from: f,
                        to: t,
                        class,
                        length_km: base_len * len_scale * rng.uniform_in(0.85, 1.15),
                        lanes,
                        speed_limit: limit,
                        direction: (ty - fy).atan2(tx - fx),
                    });
                }
            };
        // arterial grid
        for iy in 0..gh {
            for ix in 0..gw {
                if ix + 1 < gw {
                    add_bidirectional(id(ix, iy), id(ix + 1, iy),
                                      RoadClass::Arterial, rng, &nodes);
                }
                if iy + 1 < gh {
                    add_bidirectional(id(ix, iy), id(ix, iy + 1),
                                      RoadClass::Arterial, rng, &nodes);
                }
            }
        }
        // highway ring on the border rows/cols (upgrade class)
        for ix in 0..gw - 1 {
            add_bidirectional(id(ix, 0), id(ix + 1, 0), RoadClass::Highway,
                              rng, &nodes);
            add_bidirectional(id(ix, gh - 1), id(ix + 1, gh - 1),
                              RoadClass::Highway, rng, &nodes);
        }
        // slip roads: a few random diagonal connectors
        let n_slip = (gw * gh) / 4;
        for _ in 0..n_slip {
            let a = rng.below(nodes.len());
            let b = rng.below(nodes.len());
            if a != b {
                add_bidirectional(a, b, RoadClass::SlipRoad, rng, &nodes);
            }
        }
        RoadNetwork { nodes, segments }
    }

    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Segment-to-segment shortest-path distance matrix over the line
    /// graph: two segments are adjacent when one ends where the other
    /// starts; edge weight = mean of their lengths. Dijkstra from every
    /// segment (sizes here are a few hundred, so O(s² log s) is fine).
    pub fn segment_distances(&self) -> Mat {
        let s = self.segments.len();
        // adjacency: for each node, outgoing segment ids
        let mut out_of: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        for (i, seg) in self.segments.iter().enumerate() {
            out_of[seg.from].push(i);
        }
        let mut dist = Mat::from_fn(s, s, |_, _| f64::INFINITY);
        for src in 0..s {
            // binary-heap Dijkstra over segments
            use std::cmp::Reverse;
            use std::collections::BinaryHeap;
            #[derive(PartialEq)]
            struct Entry(f64, usize);
            impl Eq for Entry {}
            impl PartialOrd for Entry {
                fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                    Some(self.cmp(o))
                }
            }
            impl Ord for Entry {
                fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                    self.0.partial_cmp(&o.0).unwrap()
                        .then(self.1.cmp(&o.1))
                }
            }
            let mut heap = BinaryHeap::new();
            dist[(src, src)] = 0.0;
            heap.push(Reverse(Entry(0.0, src)));
            while let Some(Reverse(Entry(d, seg))) = heap.pop() {
                if d > dist[(src, seg)] {
                    continue;
                }
                let end = self.segments[seg].to;
                for &next in &out_of[end] {
                    let w = 0.5
                        * (self.segments[seg].length_km
                            + self.segments[next].length_km);
                    let nd = d + w;
                    if nd < dist[(src, next)] {
                        dist[(src, next)] = nd;
                        heap.push(Reverse(Entry(nd, next)));
                    }
                }
            }
        }
        // symmetrize (directed graph → metric for MDS) and cap
        // unreachable pairs at a large finite value.
        let mut maxfin: f64 = 0.0;
        for v in dist.data.iter() {
            if v.is_finite() {
                maxfin = maxfin.max(*v);
            }
        }
        for v in dist.data.iter_mut() {
            if !v.is_finite() {
                *v = 2.0 * maxfin;
            }
        }
        let mut sym = dist.clone();
        for i in 0..s {
            for j in 0..s {
                let v = 0.5 * (dist[(i, j)] + dist[(j, i)]);
                sym[(i, j)] = v;
                sym[(j, i)] = v;
            }
        }
        sym
    }
}

/// Configuration for the AIMPEAK-like dataset.
#[derive(Debug, Clone)]
pub struct AimpeakConfig {
    pub grid_w: usize,
    pub grid_h: usize,
    pub time_slots: usize,
    /// RFF features for the latent field draw.
    pub rff_features: usize,
    /// observation noise std-dev (km/h) before rescaling
    pub noise_std: f64,
    pub seed: u64,
}

impl Default for AimpeakConfig {
    fn default() -> Self {
        AimpeakConfig {
            grid_w: 8,
            grid_h: 6,
            time_slots: TIME_SLOTS,
            rff_features: 512,
            noise_std: 0.35,
            seed: 2013,
        }
    }
}

/// Generate the dataset: one record per (segment, time-slot).
///
/// Inputs are 5-d: the 4-d MDS embedding of the segment (scaled to unit
/// std per axis) plus the slot time scaled to [0, 3] — comparable ranges
/// so an isotropic initial length-scale is sane.
pub fn generate(cfg: &AimpeakConfig) -> (RoadNetwork, Dataset) {
    let mut rng = Pcg64::new(cfg.seed, 0xA1);
    let net = RoadNetwork::generate(cfg.grid_w, cfg.grid_h, &mut rng);
    let s = net.n_segments();
    let dist = net.segment_distances();
    let emb = classical_mds(&dist, EMBED_DIM);

    // normalize embedding columns to unit std
    let mut emb_n = emb.clone();
    for c in 0..EMBED_DIM {
        let mean: f64 = (0..s).map(|r| emb[(r, c)]).sum::<f64>() / s as f64;
        let var: f64 = (0..s)
            .map(|r| (emb[(r, c)] - mean).powi(2))
            .sum::<f64>()
            / s as f64;
        let std = var.sqrt().max(1e-9);
        for r in 0..s {
            emb_n[(r, c)] = (emb[(r, c)] - mean) / std;
        }
    }

    // latent smooth field over (embedding, time): long length-scales
    let field_hyp = SeArd {
        log_ls: vec![
            1.2f64.ln(), 1.2f64.ln(), 1.2f64.ln(), 1.2f64.ln(), // space
            1.0f64.ln(),                                        // time
        ],
        log_sf2: 0.0,
        log_sn2: (1e-6f64).ln(),
    };
    let field = RffSampler::draw(&field_hyp, cfg.rff_features, &mut rng);

    let n = s * cfg.time_slots;
    let mut x = Mat::zeros(n, EMBED_DIM + 1);
    let mut y = Vec::with_capacity(n);
    let mut row = 0;
    for seg in 0..s {
        let class = net.segments[seg].class;
        let base = match class {
            RoadClass::Highway => 1.2,
            RoadClass::Arterial => 0.0,
            RoadClass::SlipRoad => -0.8,
        };
        for t in 0..cfg.time_slots {
            let time = 3.0 * t as f64 / cfg.time_slots.max(1) as f64;
            for c in 0..EMBED_DIM {
                x[(row, c)] = emb_n[(seg, c)];
            }
            x[(row, EMBED_DIM)] = time;
            // morning-peak dip: worst congestion mid-window
            let peak = -1.1
                * (-((time - 1.3) * (time - 1.3)) / 0.5).exp()
                * (1.0 + 0.3 * base);
            let latent = field.eval(x.row(row)) + base + peak;
            y.push(latent + cfg.noise_std * rng.normal());
            row += 1;
        }
    }
    let mut ds = Dataset::new(x, y);
    // match the paper's reported statistics
    ds.rescale_y(49.5, 21.7);
    (net, ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> AimpeakConfig {
        AimpeakConfig {
            grid_w: 4,
            grid_h: 3,
            time_slots: 6,
            rff_features: 64,
            noise_std: 0.3,
            seed: 1,
        }
    }

    #[test]
    fn network_shape_and_classes() {
        let mut rng = Pcg64::seed(5);
        let net = RoadNetwork::generate(5, 4, &mut rng);
        assert_eq!(net.nodes.len(), 20);
        assert!(net.n_segments() > 40);
        let classes: Vec<_> = net.segments.iter().map(|s| s.class).collect();
        assert!(classes.contains(&RoadClass::Highway));
        assert!(classes.contains(&RoadClass::Arterial));
        // bidirectional pairs
        assert_eq!(net.n_segments() % 2, 0);
    }

    #[test]
    fn segment_attributes_sane() {
        let mut rng = Pcg64::seed(6);
        let net = RoadNetwork::generate(4, 4, &mut rng);
        for s in &net.segments {
            assert!(s.length_km > 0.0 && s.length_km < 10.0);
            assert!(s.lanes >= 1 && s.lanes <= 4);
            assert!([40.0, 60.0, 90.0].contains(&s.speed_limit));
            assert!(s.from < net.nodes.len() && s.to < net.nodes.len());
        }
    }

    #[test]
    fn distance_matrix_is_metric_like() {
        let mut rng = Pcg64::seed(7);
        let net = RoadNetwork::generate(3, 3, &mut rng);
        let d = net.segment_distances();
        let s = net.n_segments();
        for i in 0..s {
            assert_eq!(d[(i, i)], 0.0);
            for j in 0..s {
                assert!(d[(i, j)] >= 0.0);
                assert!((d[(i, j)] - d[(j, i)]).abs() < 1e-12);
                assert!(d[(i, j)].is_finite());
            }
        }
    }

    #[test]
    fn dataset_statistics_match_paper() {
        let (net, ds) = generate(&small_cfg());
        assert_eq!(ds.len(), net.n_segments() * 6);
        assert_eq!(ds.dim(), 5);
        assert!((ds.y_mean() - 49.5).abs() < 1e-6);
        assert!((ds.y_std() - 21.7).abs() < 1e-6);
    }

    #[test]
    fn deterministic_by_seed() {
        let (_, a) = generate(&small_cfg());
        let (_, b) = generate(&small_cfg());
        assert_eq!(a.y, b.y);
        let mut cfg2 = small_cfg();
        cfg2.seed = 2;
        let (_, c) = generate(&cfg2);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn time_feature_spans_slots() {
        let (_, ds) = generate(&small_cfg());
        let times: Vec<f64> = (0..ds.len()).map(|i| ds.x[(i, EMBED_DIM)]).collect();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(min, 0.0);
        assert!(max > 2.0 && max < 3.0);
    }

    #[test]
    fn spatially_close_segments_correlated() {
        // same segment consecutive slots should have closer speeds than
        // random pairs on average (smooth latent field)
        let (_, ds) = generate(&AimpeakConfig { time_slots: 10, ..small_cfg() });
        let mut near = 0.0;
        let mut cnt = 0.0;
        for seg in 0..ds.len() / 10 {
            for t in 0..9 {
                let i = seg * 10 + t;
                near += (ds.y[i] - ds.y[i + 1]).abs();
                cnt += 1.0;
            }
        }
        near /= cnt;
        let std = ds.y_std();
        assert!(near < std, "near-slot diff {near} should be < std {std}");
    }
}
