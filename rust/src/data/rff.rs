//! Random-Fourier-feature (RFF) GP sampler (Rahimi & Recht 2007).
//!
//! Drawing an exact GP sample at n points costs O(n³); the synthetic
//! datasets need smooth latent fields at n ≈ 10⁴–10⁵, so we sample from
//! the RFF approximation instead: for the ARD-SE kernel,
//! `f(x) = sqrt(2·sf2/m) · Σ_j a_j · cos(w_j·x + b_j)` with
//! `w_j ~ N(0, diag(1/ls²))`, `b_j ~ U[0, 2π)`, `a_j ~ N(0,1)` is a GP
//! draw whose covariance converges to the SE kernel as m → ∞.

use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::util::Pcg64;

/// A fixed draw of RFF weights defining one sampled function.
#[derive(Debug, Clone)]
pub struct RffSampler {
    /// m×d frequency matrix (rows w_j, already scaled by 1/ls).
    w: Mat,
    /// phase offsets b_j.
    b: Vec<f64>,
    /// amplitudes a_j.
    a: Vec<f64>,
    /// sqrt(2·sf2/m).
    scale: f64,
}

impl RffSampler {
    /// Draw a function from GP(0, k_hyp) using `m` Fourier features.
    pub fn draw(hyp: &SeArd, m: usize, rng: &mut Pcg64) -> RffSampler {
        let d = hyp.dim();
        let inv_ls: Vec<f64> = hyp.log_ls.iter().map(|l| (-l).exp()).collect();
        let mut w = Mat::zeros(m, d);
        for j in 0..m {
            for c in 0..d {
                w[(j, c)] = rng.normal() * inv_ls[c];
            }
        }
        let b = (0..m)
            .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        let a = rng.normals(m);
        RffSampler {
            w,
            b,
            a,
            scale: (2.0 * hyp.sf2() / m as f64).sqrt(),
        }
    }

    /// Evaluate the sampled function at one point.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for j in 0..self.b.len() {
            let phase = crate::linalg::dot(self.w.row(j), x) + self.b[j];
            s += self.a[j] * phase.cos();
        }
        self.scale * s
    }

    /// Evaluate at every row of `x`.
    pub fn eval_all(&self, x: &Mat) -> Vec<f64> {
        (0..x.rows).map(|i| self.eval(x.row(i))).collect()
    }
}

/// A synthetic regression problem drawn from a known GP: inputs uniform
/// on [-3, 3]^d, latent field an RFF draw from GP(0, k_truth), outputs
/// with N(0, sn2_truth) observation noise. The ground-truth workload
/// for hyperparameter-recovery experiments (`pgpr train`,
/// `bench_support::train_bench`): the training methods see only (x, y)
/// and must rediscover `truth`'s length-scales and variances.
pub fn synthetic_regression(
    truth: &SeArd,
    n: usize,
    features: usize,
    rng: &mut Pcg64,
) -> crate::data::Dataset {
    let d = truth.dim();
    let f = RffSampler::draw(truth, features, rng);
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        for c in 0..d {
            x[(i, c)] = rng.uniform_in(-3.0, 3.0);
        }
    }
    let noise = truth.sn2().sqrt();
    let y: Vec<f64> = (0..n)
        .map(|i| f.eval(x.row(i)) + noise * rng.normal())
        .collect();
    crate::data::Dataset::new(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empirical covariance of RFF draws approximates the SE kernel.
    #[test]
    fn covariance_converges_to_kernel() {
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 1e-6);
        let mut rng = Pcg64::seed(42);
        let x1 = [0.0, 0.0];
        let x2 = [0.7, -0.3];
        let n_draws = 400;
        let mut sum11 = 0.0;
        let mut sum12 = 0.0;
        for _ in 0..n_draws {
            let s = RffSampler::draw(&hyp, 256, &mut rng);
            let f1 = s.eval(&x1);
            let f2 = s.eval(&x2);
            sum11 += f1 * f1;
            sum12 += f1 * f2;
        }
        let var = sum11 / n_draws as f64;
        let cov = sum12 / n_draws as f64;
        assert!((var - hyp.sf2()).abs() < 0.15, "var={var}");
        assert!((cov - hyp.k(&x1, &x2)).abs() < 0.15, "cov={cov}");
    }

    #[test]
    fn smoothness_with_long_lengthscale() {
        let hyp = SeArd::isotropic(1, 5.0, 1.0, 1e-6);
        let mut rng = Pcg64::seed(7);
        let s = RffSampler::draw(&hyp, 512, &mut rng);
        // nearby points give nearby values
        let f0 = s.eval(&[0.0]);
        let f1 = s.eval(&[0.05]);
        assert!((f0 - f1).abs() < 0.1, "not smooth: {f0} vs {f1}");
    }

    #[test]
    fn eval_all_matches_eval() {
        let hyp = SeArd::isotropic(3, 1.0, 2.0, 1e-6);
        let mut rng = Pcg64::seed(9);
        let s = RffSampler::draw(&hyp, 64, &mut rng);
        let x = Mat::from_vec(4, 3, rng.normals(12));
        let all = s.eval_all(&x);
        for i in 0..4 {
            assert_eq!(all[i], s.eval(x.row(i)));
        }
    }

    #[test]
    fn synthetic_regression_shapes_and_determinism() {
        let truth = SeArd::isotropic(3, 1.0, 1.5, 0.04);
        let a = synthetic_regression(&truth, 40, 64, &mut Pcg64::seed(6));
        assert_eq!(a.len(), 40);
        assert_eq!(a.dim(), 3);
        assert!(a.x.data.iter().all(|v| (-3.0..3.0).contains(v)));
        let b = synthetic_regression(&truth, 40, 64, &mut Pcg64::seed(6));
        assert_eq!(a.y, b.y);
        // output variance is in the ballpark of sf2 + sn2
        let var = a.y_std() * a.y_std();
        assert!(var > 0.2 && var < 6.0, "var={var}");
    }

    #[test]
    fn deterministic_given_seed() {
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 1e-6);
        let s1 = RffSampler::draw(&hyp, 32, &mut Pcg64::seed(3));
        let s2 = RffSampler::draw(&hyp, 32, &mut Pcg64::seed(3));
        assert_eq!(s1.eval(&[0.3, 0.4]), s2.eval(&[0.3, 0.4]));
    }
}
