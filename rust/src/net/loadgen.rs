//! Open-loop load generator over real sockets → `BENCH_e2e.json`.
//!
//! Sweeps target arrival rates against a running `pgpr node`,
//! recording achieved qps, sojourn-time percentiles (p50/p99/p999),
//! shed counts (429/503) and the node's own queue-depth peaks scraped
//! from `/stats?format=json` after each step.
//!
//! **Open loop**: every request has a scheduled send time `i / qps`
//! fixed up front, and the generator sleeps until that instant
//! regardless of how the previous response is doing. A closed-loop
//! generator (send-after-response) self-throttles exactly when the
//! server saturates and so hides the latency cliff this harness
//! exists to measure; the classic failure mode is *coordinated
//! omission*, which the sojourn-time definition here (response time
//! measured from the scheduled send, not the actual send) avoids.
//! `max_send_lag_s` reports how far behind schedule the generator
//! itself fell, so an undersized client pool is visible in the data
//! instead of silently shrinking the offered load.
//!
//! The per-step admission-bound checks (`net.queue_depth_peak` ≤
//! `queue_cap`, batcher depth ≤ `machines × max_batch`) are hard
//! errors: if they fail, backpressure is broken.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::http::HttpReader;
use crate::util::json::{self, Json};
use crate::util::Pcg64;

/// Minimal blocking HTTP/1.1 client for loopback benchmarking: one
/// keep-alive connection, `Content-Length`-framed bodies only
/// (exactly what the node emits). Transparently reconnects before the
/// next request when the server signalled `connection: close`.
pub struct HttpClient {
    target: String,
    timeout_s: f64,
    w: TcpStream,
    r: HttpReader<TcpStream>,
    close_pending: bool,
}

impl HttpClient {
    /// Connect to `target` (`host:port`) with per-op timeouts.
    pub fn connect(target: &str, timeout_s: f64) -> Result<HttpClient> {
        let stream = TcpStream::connect(target)
            .with_context(|| format!("connect {target}"))?;
        let _ = stream.set_nodelay(true);
        let to = Some(Duration::from_secs_f64(timeout_s));
        stream.set_read_timeout(to)?;
        stream.set_write_timeout(to)?;
        let r = HttpReader::new(stream.try_clone()?);
        Ok(HttpClient {
            target: target.to_string(),
            timeout_s,
            w: stream,
            r,
            close_pending: false,
        })
    }

    /// Issue one request and read the full response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>)> {
        if self.close_pending {
            let fresh = HttpClient::connect(&self.target.clone(),
                                            self.timeout_s)?;
            *self = fresh;
        }
        let mut head = String::with_capacity(128);
        use std::fmt::Write as _;
        let _ = write!(
            head,
            "{method} {path} HTTP/1.1\r\nhost: pgpr\r\n\
             content-length: {}\r\n\r\n",
            body.len()
        );
        self.w.write_all(head.as_bytes())?;
        self.w.write_all(body)?;
        self.w.flush()?;
        self.read_response()
    }

    /// `GET path` → `(status, body)`.
    pub fn get(&mut self, path: &str) -> Result<(u16, Vec<u8>)> {
        self.request("GET", path, b"")
    }

    /// `POST path` → `(status, body)`.
    pub fn post(&mut self, path: &str, body: &[u8])
        -> Result<(u16, Vec<u8>)>
    {
        self.request("POST", path, body)
    }

    /// `GET path`, require 200, parse the body as JSON.
    pub fn get_json(&mut self, path: &str) -> Result<Json> {
        let (status, body) = self.get(path)?;
        anyhow::ensure!(status == 200, "GET {path}: status {status}");
        let text = std::str::from_utf8(&body)
            .with_context(|| format!("GET {path}: body not utf-8"))?;
        Json::parse(text)
            .map_err(|e| anyhow!("GET {path}: bad json: {e:?}"))
    }

    fn read_line(&mut self) -> Result<Vec<u8>> {
        match self.r.read_line(65536) {
            Ok(Some(l)) => Ok(l),
            Ok(None) => Err(anyhow!("server closed connection")),
            Err(e) => Err(anyhow!("read error: {e:?}")),
        }
    }

    fn read_response(&mut self) -> Result<(u16, Vec<u8>)> {
        let status_line = self.read_line()?;
        let s = String::from_utf8_lossy(&status_line).into_owned();
        let status: u16 = s
            .split_whitespace()
            .nth(1)
            .and_then(|p| p.parse().ok())
            .ok_or_else(|| anyhow!("bad status line: {s:?}"))?;
        let mut content_length = 0usize;
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let text = String::from_utf8_lossy(&line).into_owned();
            if let Some((name, value)) = text.split_once(':') {
                let value = value.trim();
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.parse().with_context(|| {
                        format!("bad content-length {value:?}")
                    })?;
                } else if name.eq_ignore_ascii_case("connection")
                    && value.eq_ignore_ascii_case("close")
                {
                    self.close_pending = true;
                }
            }
        }
        let body = self
            .r
            .read_body(content_length)
            .map_err(|e| anyhow!("body read: {e}"))?;
        Ok((status, body))
    }
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// `host:port` of a running `pgpr node`.
    pub target: String,
    /// Target arrival rates to sweep, in requests/second.
    pub qps_steps: Vec<f64>,
    /// Seconds of offered load per step.
    pub duration_s: f64,
    /// Client connections (one thread each).
    pub conns: usize,
    /// Query-vector RNG seed (deterministic per step × connection).
    pub seed: u64,
}

impl LoadgenConfig {
    /// Small fixed sweep for CI: finishes in a few seconds.
    pub fn smoke(target: &str) -> LoadgenConfig {
        LoadgenConfig {
            target: target.to_string(),
            qps_steps: vec![200.0, 800.0],
            duration_s: 1.0,
            conns: 4,
            seed: 1,
        }
    }

    /// Full sweep to saturation for bench-full runs.
    pub fn full(target: &str) -> LoadgenConfig {
        LoadgenConfig {
            target: target.to_string(),
            qps_steps: vec![500.0, 1000.0, 2000.0, 4000.0, 8000.0,
                            16000.0],
            duration_s: 5.0,
            conns: 16,
            seed: 1,
        }
    }
}

/// What `/healthz` reports about the node under test.
#[derive(Debug, Clone)]
struct NodeInfo {
    d: usize,
    machines: usize,
    queue_cap: usize,
    max_batch: usize,
}

/// One sweep step's results.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub target_qps: f64,
    /// Requests actually sent (offered load).
    pub offered: usize,
    pub ok: usize,
    pub shed_429: usize,
    pub shed_503: usize,
    /// Responses with any other status.
    pub http_errors: usize,
    /// Transport failures (reconnected after each).
    pub io_errors: usize,
    pub achieved_qps: f64,
    pub wall_s: f64,
    /// Sojourn-time percentiles over 200s, measured from the
    /// *scheduled* send instant (coordinated-omission safe).
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    /// How far behind schedule the generator fell (client-side).
    pub max_send_lag_s: f64,
    /// `net.queue_depth_peak` scraped from `/stats` after the step.
    pub queue_depth_peak: i64,
    /// `serve.queue_depth_peak` (batcher) scraped after the step.
    pub batcher_depth_peak: i64,
}

impl StepStats {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("target_qps", self.target_qps.into()),
            ("offered", self.offered.into()),
            ("ok", self.ok.into()),
            ("shed_429", self.shed_429.into()),
            ("shed_503", self.shed_503.into()),
            ("http_errors", self.http_errors.into()),
            ("io_errors", self.io_errors.into()),
            ("achieved_qps", self.achieved_qps.into()),
            ("wall_s", self.wall_s.into()),
            ("p50_s", self.p50_s.into()),
            ("p99_s", self.p99_s.into()),
            ("p999_s", self.p999_s.into()),
            ("max_send_lag_s", self.max_send_lag_s.into()),
            ("queue_depth_peak", (self.queue_depth_peak.max(0) as usize)
                .into()),
            ("batcher_depth_peak",
             (self.batcher_depth_peak.max(0) as usize).into()),
        ])
    }
}

/// Full sweep results → `BENCH_e2e.json`.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub d: usize,
    pub machines: usize,
    pub queue_cap: usize,
    pub max_batch: usize,
    pub steps: Vec<StepStats>,
}

impl LoadgenReport {
    /// Render with the `pgpr-bench-e2e/1` schema.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", "pgpr-bench-e2e/1".into()),
            ("d", self.d.into()),
            ("machines", self.machines.into()),
            ("queue_cap", self.queue_cap.into()),
            ("max_batch", self.max_batch.into()),
            ("steps",
             Json::Arr(self.steps.iter().map(StepStats::to_json)
                 .collect())),
        ])
    }

    /// Write the pretty-printed report to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
    }
}

/// Exact percentile by nearest-rank over an ascending-sorted slice;
/// 0.0 for an empty slice (never NaN — the report must stay valid
/// JSON).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn predict_body(x: &[f64]) -> String {
    json::obj(vec![(
        "x",
        Json::Arr(x.iter().map(|&v| Json::Num(v)).collect()),
    )])
    .to_string_compact()
}

fn probe(target: &str) -> Result<NodeInfo> {
    let mut c = HttpClient::connect(target, 10.0)?;
    let doc = c.get_json("/healthz")?;
    let field = |k: &str| -> Result<usize> {
        doc.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("/healthz missing {k:?}"))
    };
    Ok(NodeInfo {
        d: field("d")?,
        machines: field("machines")?,
        queue_cap: field("queue_cap")?,
        max_batch: field("max_batch")?,
    })
}

#[derive(Default)]
struct StepRaw {
    ok_latencies: Vec<f64>,
    shed_429: usize,
    shed_503: usize,
    http_errors: usize,
    io_errors: usize,
    max_send_lag_s: f64,
}

fn run_step(
    cfg: &LoadgenConfig,
    info: &NodeInfo,
    step_idx: usize,
    qps: f64,
) -> StepStats {
    let n = ((qps * cfg.duration_s).ceil() as usize).max(1);
    let k = cfg.conns.max(1);
    let start = Instant::now();
    let mut merged: Vec<StepRaw> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..k {
            handles.push(s.spawn(move || -> StepRaw {
                let mut raw = StepRaw::default();
                let mut client =
                    HttpClient::connect(&cfg.target, 10.0).ok();
                let mut rng =
                    Pcg64::new(cfg.seed, (step_idx * 1000 + t) as u64);
                // connection t owns requests t, t+k, t+2k, ...
                let mut i = t;
                while i < n {
                    let t_sched = i as f64 / qps;
                    let now = start.elapsed().as_secs_f64();
                    if t_sched > now {
                        std::thread::sleep(Duration::from_secs_f64(
                            t_sched - now,
                        ));
                    } else {
                        raw.max_send_lag_s =
                            raw.max_send_lag_s.max(now - t_sched);
                    }
                    let body = predict_body(&rng.normals(info.d));
                    let resp = match client.as_mut() {
                        Some(c) => c.post("/v1/predict",
                                          body.as_bytes()),
                        None => Err(anyhow!("not connected")),
                    };
                    match resp {
                        Ok((200, _)) => {
                            let done = start.elapsed().as_secs_f64();
                            raw.ok_latencies.push(done - t_sched);
                        }
                        Ok((429, _)) => raw.shed_429 += 1,
                        Ok((503, _)) => raw.shed_503 += 1,
                        Ok(_) => raw.http_errors += 1,
                        Err(_) => {
                            raw.io_errors += 1;
                            client =
                                HttpClient::connect(&cfg.target, 10.0)
                                    .ok();
                        }
                    }
                    i += k;
                }
                raw
            }));
        }
        for h in handles {
            if let Ok(r) = h.join() {
                merged.push(r);
            }
        }
    });
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);
    let mut lat: Vec<f64> = merged
        .iter()
        .flat_map(|r| r.ok_latencies.iter().copied())
        .collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ok = lat.len();
    StepStats {
        target_qps: qps,
        offered: n,
        ok,
        shed_429: merged.iter().map(|r| r.shed_429).sum(),
        shed_503: merged.iter().map(|r| r.shed_503).sum(),
        http_errors: merged.iter().map(|r| r.http_errors).sum(),
        io_errors: merged.iter().map(|r| r.io_errors).sum(),
        achieved_qps: ok as f64 / wall_s,
        wall_s,
        p50_s: percentile(&lat, 0.50),
        p99_s: percentile(&lat, 0.99),
        p999_s: percentile(&lat, 0.999),
        max_send_lag_s: merged
            .iter()
            .map(|r| r.max_send_lag_s)
            .fold(0.0, f64::max),
        queue_depth_peak: 0,
        batcher_depth_peak: 0,
    }
}

/// Run the sweep against `cfg.target`, scraping `/stats` after each
/// step and hard-checking the admission bounds.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(!cfg.qps_steps.is_empty(), "no qps steps");
    let info = probe(&cfg.target)?;
    let mut steps = Vec::new();
    for (idx, &qps) in cfg.qps_steps.iter().enumerate() {
        let mut st = run_step(cfg, &info, idx, qps);
        let mut c = HttpClient::connect(&cfg.target, 10.0)?;
        let stats = c.get_json("/stats?format=json")?;
        let gauge = |name: &str| -> i64 {
            stats
                .get("gauges")
                .and_then(|g| g.get(name))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as i64
        };
        st.queue_depth_peak = gauge("net.queue_depth_peak");
        st.batcher_depth_peak = gauge("serve.queue_depth_peak");
        // backpressure invariants: queues stay bounded under any load
        anyhow::ensure!(
            st.queue_depth_peak <= info.queue_cap as i64,
            "net.queue_depth_peak {} exceeded queue_cap {}",
            st.queue_depth_peak,
            info.queue_cap
        );
        anyhow::ensure!(
            st.batcher_depth_peak
                <= (info.machines * info.max_batch) as i64,
            "batcher depth peak {} exceeded machines*max_batch {}",
            st.batcher_depth_peak,
            info.machines * info.max_batch
        );
        steps.push(st);
    }
    Ok(LoadgenReport {
        d: info.d,
        machines: info.machines,
        queue_cap: info.queue_cap,
        max_batch: info.max_batch,
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 51.0); // round(99*0.5)=50
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.999), 7.0);
    }

    #[test]
    fn predict_body_roundtrips_exactly() {
        let x = [1.5, -0.25, 3.0e-7];
        let doc = Json::parse(&predict_body(&x)).unwrap();
        let arr = doc.get("x").and_then(Json::as_arr).unwrap();
        let back: Vec<f64> =
            arr.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(back, x); // shortest-roundtrip printing is exact
    }

    #[test]
    fn report_json_has_schema_and_steps() {
        let rep = LoadgenReport {
            d: 2,
            machines: 4,
            queue_cap: 256,
            max_batch: 16,
            steps: vec![StepStats {
                target_qps: 100.0,
                offered: 100,
                ok: 90,
                shed_429: 4,
                shed_503: 6,
                http_errors: 0,
                io_errors: 0,
                achieved_qps: 90.0,
                wall_s: 1.0,
                p50_s: 0.001,
                p99_s: 0.005,
                p999_s: 0.009,
                max_send_lag_s: 0.0,
                queue_depth_peak: 12,
                batcher_depth_peak: 30,
            }],
        };
        let doc = Json::parse(&rep.to_json().to_string_pretty())
            .unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str),
                   Some("pgpr-bench-e2e/1"));
        let steps =
            doc.get("steps").and_then(Json::as_arr).unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("ok").and_then(Json::as_usize),
                   Some(90));
    }
}
