//! A minimal, hardened HTTP/1.1 core for the serving node.
//!
//! Scope: exactly what a prediction node needs and nothing more —
//! `GET`/`POST`, `Content-Length` bodies, keep-alive — implemented
//! over blocking [`std::io::Read`]/[`std::io::Write`] transports so it
//! works on `std::net` sockets and on in-memory cursors in tests.
//!
//! Hardening posture (every cap is a [`HttpLimits`] knob):
//! * the request line is capped ([`HttpError::UriTooLong`], 414);
//! * header count and cumulative header bytes are capped
//!   ([`HttpError::TooManyHeaders`] / [`HttpError::HeaderTooLarge`],
//!   431);
//! * declared bodies over the cap are rejected **before** reading them
//!   ([`HttpError::PayloadTooLarge`], 413);
//! * `POST` without `Content-Length` is rejected
//!   ([`HttpError::LengthRequired`], 411) and `Transfer-Encoding`
//!   (chunked) is not implemented ([`HttpError::NotImplemented`], 501)
//!   — responses are always `Content-Length`-framed, never chunked;
//! * slow or stalled peers surface as timeouts through the transport's
//!   read timeout ([`HttpError::Timeout`] mid-request → 408;
//!   [`Parsed::TimeoutIdle`] between requests so the caller can run
//!   its idle-close policy);
//! * a peer closing mid-request is [`HttpError::Closed`] (just drop
//!   the connection), and closing cleanly between requests is
//!   [`Parsed::ClosedIdle`].

use std::io::{Read, Write};

/// Parser caps; every limit is inclusive ("at most").
#[derive(Debug, Clone)]
pub struct HttpLimits {
    /// Max request-line bytes (method + target + version).
    pub max_line_bytes: usize,
    /// Max number of header lines.
    pub max_headers: usize,
    /// Max cumulative header bytes across all header lines.
    pub max_header_bytes: usize,
    /// Max declared `Content-Length`.
    pub max_body_bytes: usize,
}

impl Default for HttpLimits {
    fn default() -> HttpLimits {
        HttpLimits {
            max_line_bytes: 8 * 1024,
            max_headers: 64,
            max_header_bytes: 16 * 1024,
            max_body_bytes: 64 * 1024,
        }
    }
}

/// Supported request methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
}

/// One parsed request. Header names are lowercased at parse time.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: Method,
    /// Path component of the target (before any `?`).
    pub path: String,
    /// Raw query string (after `?`), if any.
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Connection persistence after this request: HTTP/1.1 defaults
    /// to true (`connection: close` clears it), HTTP/1.0 to false
    /// (`connection: keep-alive` sets it).
    pub keep_alive: bool,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// Query-string flag: true when the query contains `key=value`
    /// as one `&`-separated component.
    pub fn query_has(&self, key: &str, value: &str) -> bool {
        self.query
            .as_deref()
            .is_some_and(|q| {
                q.split('&').any(|kv| {
                    kv.split_once('=') == Some((key, value))
                })
            })
    }
}

/// Non-request outcomes of waiting for the next request on an idle
/// keep-alive connection.
#[derive(Debug)]
pub enum Parsed {
    Request(Request),
    /// Peer closed cleanly at a request boundary.
    ClosedIdle,
    /// The transport's read timeout elapsed with no request bytes:
    /// one idle tick (the caller counts these against its idle-close
    /// budget and otherwise just calls parse again).
    TimeoutIdle,
}

/// Everything that can go wrong parsing one request.
#[derive(Debug)]
pub enum HttpError {
    /// 400 — malformed request line / header / body framing.
    BadRequest(&'static str),
    /// 414 — request line exceeded [`HttpLimits::max_line_bytes`].
    UriTooLong,
    /// 431 — one header line or the cumulative header bytes exceeded
    /// the cap.
    HeaderTooLarge,
    /// 431 — more than [`HttpLimits::max_headers`] header lines.
    TooManyHeaders,
    /// 411 — POST without `Content-Length`.
    LengthRequired,
    /// 413 — declared `Content-Length` over
    /// [`HttpLimits::max_body_bytes`] (rejected before reading).
    PayloadTooLarge,
    /// 501 — a protocol feature this core deliberately omits
    /// (chunked transfer encoding, methods beyond GET/POST).
    NotImplemented(&'static str),
    /// 408 — read timeout after the request started arriving.
    Timeout,
    /// Peer closed mid-request; no response is deliverable.
    Closed,
    /// Transport error; no response is deliverable.
    Io(std::io::Error),
}

impl HttpError {
    /// The status line to answer with, or `None` for connection-level
    /// conditions where no response can be delivered.
    pub fn status(&self) -> Option<(u16, &'static str)> {
        match self {
            HttpError::BadRequest(m) => Some((400, m)),
            HttpError::UriTooLong => Some((414, "request line too long")),
            HttpError::HeaderTooLarge => Some((431, "headers too large")),
            HttpError::TooManyHeaders => Some((431, "too many headers")),
            HttpError::LengthRequired => Some((411, "content-length required")),
            HttpError::PayloadTooLarge => Some((413, "body too large")),
            HttpError::NotImplemented(m) => Some((501, m)),
            HttpError::Timeout => Some((408, "request timed out")),
            HttpError::Closed | HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "transport error: {e}"),
            HttpError::Closed => write!(f, "peer closed mid-request"),
            other => match other.status() {
                Some((code, msg)) => write!(f, "{code} {msg}"),
                None => write!(f, "http error"),
            },
        }
    }
}

/// Line-reading failures, before they are mapped to a position-aware
/// [`HttpError`] by the parser (a too-long *request line* is 414, a
/// too-long *header line* is 431).
#[derive(Debug)]
pub enum LineError {
    /// The line exceeded the caller's cap.
    TooLong,
    /// Read timeout; `partial` is true when some bytes of the line had
    /// already arrived.
    Timeout { partial: bool },
    /// EOF mid-line.
    Closed,
    Io(std::io::Error),
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

const READ_CHUNK: usize = 4096;

/// A bounded buffered reader that owns its buffer and never reads an
/// unbounded line (the reason this exists instead of
/// [`std::io::BufRead::read_line`], whose accumulation is uncapped).
/// Leftover bytes persist across calls, which is what makes pipelined
/// keep-alive requests work.
pub struct HttpReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

impl<R: Read> HttpReader<R> {
    pub fn new(inner: R) -> HttpReader<R> {
        HttpReader { inner, buf: vec![0; READ_CHUNK], start: 0, end: 0 }
    }

    fn buffered(&self) -> &[u8] {
        &self.buf[self.start..self.end]
    }

    /// Pull more bytes from the transport; `Ok(0)` is EOF.
    fn fill(&mut self) -> std::io::Result<usize> {
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
        if self.end == self.buf.len() {
            self.buf.resize(self.buf.len() + READ_CHUNK, 0);
        }
        let n = self.inner.read(&mut self.buf[self.end..])?;
        self.end += n;
        Ok(n)
    }

    /// Read one LF-terminated line (CR stripped), at most `cap` bytes
    /// long (terminator excluded). `Ok(None)` is clean EOF at a line
    /// boundary.
    pub fn read_line(&mut self, cap: usize)
        -> Result<Option<Vec<u8>>, LineError>
    {
        let mut line: Vec<u8> = Vec::new();
        loop {
            if let Some(pos) =
                self.buffered().iter().position(|&b| b == b'\n')
            {
                line.extend_from_slice(&self.buf[self.start..self.start + pos]);
                self.start += pos + 1;
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if line.len() > cap {
                    return Err(LineError::TooLong);
                }
                return Ok(Some(line));
            }
            line.extend_from_slice(self.buffered());
            self.start = self.end;
            if line.len() > cap {
                return Err(LineError::TooLong);
            }
            match self.fill() {
                Ok(0) => {
                    return if line.is_empty() {
                        Ok(None)
                    } else {
                        Err(LineError::Closed)
                    };
                }
                Ok(_) => {}
                Err(e) if is_timeout(&e) => {
                    return Err(LineError::Timeout {
                        partial: !line.is_empty(),
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(LineError::Io(e)),
            }
        }
    }

    /// Read exactly `n` body bytes.
    pub fn read_body(&mut self, n: usize) -> Result<Vec<u8>, HttpError> {
        let mut out = Vec::with_capacity(n);
        loop {
            let avail = self.buffered();
            let take = avail.len().min(n - out.len());
            out.extend_from_slice(&avail[..take]);
            self.start += take;
            if out.len() == n {
                return Ok(out);
            }
            match self.fill() {
                Ok(0) => return Err(HttpError::Closed),
                Ok(_) => {}
                Err(e) if is_timeout(&e) => return Err(HttpError::Timeout),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
    }
}

/// Parse one request from the reader under `limits`.
///
/// The distinction between "nothing arrived" and "a request broke off"
/// matters for both timeouts and closes: idle outcomes come back as
/// [`Parsed::TimeoutIdle`] / [`Parsed::ClosedIdle`] (not errors), while
/// the same conditions mid-request are [`HttpError::Timeout`] /
/// [`HttpError::Closed`].
pub fn parse_request<R: Read>(
    r: &mut HttpReader<R>,
    limits: &HttpLimits,
) -> Result<Parsed, HttpError> {
    // request line; tolerate up to 2 blank lines before it (RFC 7230
    // robustness) — each costs one loop turn, so it cannot spin
    let mut line = Vec::new();
    for blanks in 0..3 {
        match r.read_line(limits.max_line_bytes) {
            Ok(None) => return Ok(Parsed::ClosedIdle),
            Ok(Some(l)) if l.is_empty() && blanks < 2 => continue,
            Ok(Some(l)) => {
                line = l;
                break;
            }
            Err(LineError::TooLong) => return Err(HttpError::UriTooLong),
            Err(LineError::Timeout { partial: false }) => {
                return Ok(Parsed::TimeoutIdle)
            }
            Err(LineError::Timeout { partial: true }) => {
                return Err(HttpError::Timeout)
            }
            Err(LineError::Closed) => return Err(HttpError::Closed),
            Err(LineError::Io(e)) => return Err(HttpError::Io(e)),
        }
    }
    if line.is_empty() {
        return Err(HttpError::BadRequest("blank request line"));
    }
    let line = std::str::from_utf8(&line)
        .map_err(|_| HttpError::BadRequest("request line not utf-8"))?;
    let mut parts = line.split_whitespace();
    let (method_s, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => return Err(HttpError::BadRequest("malformed request line")),
        };
    let method = match method_s {
        "GET" => Method::Get,
        "POST" => Method::Post,
        _ => return Err(HttpError::NotImplemented("method not supported")),
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::BadRequest("unsupported HTTP version")),
    };
    if !target.starts_with('/') {
        return Err(HttpError::BadRequest("target must be origin-form"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    // headers
    let mut headers: Vec<(String, String)> = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let hl = match r.read_line(limits.max_header_bytes) {
            Ok(Some(l)) => l,
            Ok(None) | Err(LineError::Closed) => return Err(HttpError::Closed),
            Err(LineError::TooLong) => return Err(HttpError::HeaderTooLarge),
            Err(LineError::Timeout { .. }) => return Err(HttpError::Timeout),
            Err(LineError::Io(e)) => return Err(HttpError::Io(e)),
        };
        if hl.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::TooManyHeaders);
        }
        header_bytes += hl.len();
        if header_bytes > limits.max_header_bytes {
            return Err(HttpError::HeaderTooLarge);
        }
        let hl = std::str::from_utf8(&hl)
            .map_err(|_| HttpError::BadRequest("header not utf-8"))?;
        let Some((name, value)) = hl.split_once(':') else {
            return Err(HttpError::BadRequest("header without colon"));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::BadRequest("malformed header name"));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let find = |name: &str| {
        headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    };
    if find("transfer-encoding").is_some() {
        return Err(HttpError::NotImplemented(
            "transfer-encoding not supported",
        ));
    }
    let keep_alive = match find("connection").map(str::to_ascii_lowercase) {
        Some(c) if c.contains("close") => false,
        Some(c) if c.contains("keep-alive") => true,
        _ => http11,
    };

    // body framing
    let content_length = match find("content-length") {
        None => None,
        Some(v) => Some(v.trim().parse::<usize>().map_err(|_| {
            HttpError::BadRequest("malformed content-length")
        })?),
    };
    let body = match content_length {
        Some(n) if n > limits.max_body_bytes => {
            return Err(HttpError::PayloadTooLarge)
        }
        Some(0) | None if method == Method::Post => {
            // POST bodies are how predict requests arrive; an absent
            // Content-Length means we could not frame one
            match content_length {
                Some(0) => Vec::new(),
                _ => return Err(HttpError::LengthRequired),
            }
        }
        Some(n) => r.read_body(n)?,
        None => Vec::new(),
    };

    Ok(Parsed::Request(Request {
        method,
        path,
        query,
        headers,
        body,
        keep_alive,
    }))
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one `Content-Length`-framed response (never chunked). The
/// `connection` header always states the server's persistence decision
/// explicitly so clients need not infer it from the version.
pub fn write_response(
    w: &mut dyn Write,
    status: u16,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let mut head = String::with_capacity(128);
    let _ = write!(head, "HTTP/1.1 {} {}\r\n", status, reason_phrase(status));
    let _ = write!(head, "content-length: {}\r\n", body.len());
    for (k, v) in extra_headers {
        let _ = write!(head, "{k}: {v}\r\n");
    }
    let _ = write!(
        head,
        "connection: {}\r\n\r\n",
        if keep_alive { "keep-alive" } else { "close" }
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn limits() -> HttpLimits {
        HttpLimits::default()
    }

    fn parse_str(s: &str) -> Result<Parsed, HttpError> {
        let mut r = HttpReader::new(Cursor::new(s.as_bytes().to_vec()));
        parse_request(&mut r, &limits())
    }

    fn req(p: Parsed) -> Request {
        match p {
            Parsed::Request(r) => r,
            other => panic!("expected request, got {other:?}"),
        }
    }

    #[test]
    fn get_and_post_roundtrip() {
        let r = req(parse_str("GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n")
            .unwrap());
        assert_eq!(r.method, Method::Get);
        assert_eq!(r.path, "/healthz");
        assert!(r.query.is_none());
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(r.header("Host"), Some("x"));

        let r = req(parse_str(
            "POST /v1/predict HTTP/1.1\r\ncontent-length: 11\r\n\r\n\
             {\"x\":[1.0]}",
        )
        .unwrap());
        assert_eq!(r.method, Method::Post);
        assert_eq!(r.body, b"{\"x\":[1.0]}");
    }

    #[test]
    fn query_string_split() {
        let r = req(parse_str("GET /stats?format=json HTTP/1.1\r\n\r\n")
            .unwrap());
        assert_eq!(r.path, "/stats");
        assert_eq!(r.query.as_deref(), Some("format=json"));
        assert!(r.query_has("format", "json"));
        assert!(!r.query_has("format", "prom"));
    }

    #[test]
    fn connection_header_controls_persistence() {
        let r = req(parse_str(
            "GET / HTTP/1.1\r\nconnection: close\r\n\r\n",
        )
        .unwrap());
        assert!(!r.keep_alive);
        let r = req(parse_str("GET / HTTP/1.0\r\n\r\n").unwrap());
        assert!(!r.keep_alive, "HTTP/1.0 defaults to close");
        let r = req(parse_str(
            "GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n",
        )
        .unwrap());
        assert!(r.keep_alive);
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for bad in [
            "GARBAGE\r\n\r\n",
            "GET /\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
            "GET / HTTP/2.0\r\n\r\n",
            "GET noslash HTTP/1.1\r\n\r\n",
        ] {
            let e = parse_str(bad).unwrap_err();
            assert_eq!(e.status().unwrap().0, 400, "{bad:?} -> {e:?}");
        }
    }

    #[test]
    fn unknown_method_is_501() {
        let e = parse_str("BREW /coffee HTTP/1.1\r\n\r\n").unwrap_err();
        assert!(matches!(e, HttpError::NotImplemented(_)));
        assert_eq!(e.status().unwrap().0, 501);
    }

    #[test]
    fn chunked_transfer_encoding_is_501() {
        let e = parse_str(
            "POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(e.status().unwrap().0, 501);
    }

    #[test]
    fn post_without_content_length_is_411() {
        let e = parse_str("POST /v1/predict HTTP/1.1\r\n\r\n").unwrap_err();
        assert!(matches!(e, HttpError::LengthRequired));
        assert_eq!(e.status().unwrap().0, 411);
    }

    #[test]
    fn oversized_request_line_is_414() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000));
        let e = parse_str(&long).unwrap_err();
        assert!(matches!(e, HttpError::UriTooLong));
        assert_eq!(e.status().unwrap().0, 414);
    }

    #[test]
    fn too_many_headers_is_431() {
        let mut s = String::from("GET / HTTP/1.1\r\n");
        for i in 0..70 {
            s.push_str(&format!("h{i}: v\r\n"));
        }
        s.push_str("\r\n");
        let e = parse_str(&s).unwrap_err();
        assert!(matches!(e, HttpError::TooManyHeaders));
        assert_eq!(e.status().unwrap().0, 431);
    }

    #[test]
    fn oversized_headers_are_431() {
        let s = format!(
            "GET / HTTP/1.1\r\nbig: {}\r\n\r\n",
            "v".repeat(20_000)
        );
        let e = parse_str(&s).unwrap_err();
        assert!(matches!(e, HttpError::HeaderTooLarge));
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        // the declared length alone triggers the rejection: no body
        // bytes follow, yet the parse fails fast with 413, not a hang
        let s = "POST / HTTP/1.1\r\ncontent-length: 1000000\r\n\r\n";
        let e = parse_str(s).unwrap_err();
        assert!(matches!(e, HttpError::PayloadTooLarge));
        assert_eq!(e.status().unwrap().0, 413);
    }

    #[test]
    fn malformed_content_length_is_400() {
        let e = parse_str("POST / HTTP/1.1\r\ncontent-length: ten\r\n\r\n")
            .unwrap_err();
        assert_eq!(e.status().unwrap().0, 400);
    }

    #[test]
    fn header_without_colon_is_400() {
        let e = parse_str("GET / HTTP/1.1\r\nnocolonhere\r\n\r\n")
            .unwrap_err();
        assert_eq!(e.status().unwrap().0, 400);
    }

    #[test]
    fn clean_eof_is_idle_close_and_mid_request_eof_is_closed() {
        assert!(matches!(parse_str("").unwrap(), Parsed::ClosedIdle));
        // request broke off after the request line: headers never ended
        let e = parse_str("GET / HTTP/1.1\r\nhost: x\r\n").unwrap_err();
        assert!(matches!(e, HttpError::Closed));
        assert!(e.status().is_none(), "no response deliverable");
        // and mid-body
        let e = parse_str("POST / HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc")
            .unwrap_err();
        assert!(matches!(e, HttpError::Closed));
    }

    #[test]
    fn leading_blank_lines_tolerated_bounded() {
        let r = req(parse_str("\r\n\r\nGET / HTTP/1.1\r\n\r\n").unwrap());
        assert_eq!(r.path, "/");
        // three blank lines exhaust the tolerance
        let e = parse_str("\r\n\r\n\r\nGET / HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status().unwrap().0, 400);
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let s = "GET /a HTTP/1.1\r\n\r\n\
                 POST /b HTTP/1.1\r\ncontent-length: 2\r\n\r\nhi\
                 GET /c HTTP/1.1\r\nconnection: close\r\n\r\n";
        let mut r = HttpReader::new(Cursor::new(s.as_bytes().to_vec()));
        let a = req(parse_request(&mut r, &limits()).unwrap());
        assert_eq!(a.path, "/a");
        let b = req(parse_request(&mut r, &limits()).unwrap());
        assert_eq!((b.path.as_str(), b.body.as_slice()),
                   ("/b", b"hi".as_slice()));
        let c = req(parse_request(&mut r, &limits()).unwrap());
        assert_eq!(c.path, "/c");
        assert!(!c.keep_alive);
        assert!(matches!(parse_request(&mut r, &limits()).unwrap(),
                         Parsed::ClosedIdle));
    }

    /// A transport that yields its chunks then times out — the shape
    /// of a slow-loris peer under a socket read timeout.
    struct SlowThenStall {
        chunks: Vec<Vec<u8>>,
        i: usize,
    }
    impl Read for SlowThenStall {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.i < self.chunks.len() {
                let c = std::mem::take(&mut self.chunks[self.i]);
                self.i += 1;
                buf[..c.len()].copy_from_slice(&c);
                Ok(c.len())
            } else {
                Err(std::io::Error::new(
                    std::io::ErrorKind::WouldBlock,
                    "stalled",
                ))
            }
        }
    }

    #[test]
    fn idle_timeout_vs_mid_request_timeout() {
        // no bytes at all: idle tick
        let mut r = HttpReader::new(SlowThenStall { chunks: vec![], i: 0 });
        assert!(matches!(parse_request(&mut r, &limits()).unwrap(),
                         Parsed::TimeoutIdle));
        // half a request line then stall: 408
        let mut r = HttpReader::new(SlowThenStall {
            chunks: vec![b"GET /heal".to_vec()],
            i: 0,
        });
        let e = parse_request(&mut r, &limits()).unwrap_err();
        assert!(matches!(e, HttpError::Timeout));
        assert_eq!(e.status().unwrap().0, 408);
        // full request line then stall in headers: also 408
        let mut r = HttpReader::new(SlowThenStall {
            chunks: vec![b"GET / HTTP/1.1\r\nhos".to_vec()],
            i: 0,
        });
        assert!(matches!(parse_request(&mut r, &limits()).unwrap_err(),
                         HttpError::Timeout));
    }

    #[test]
    fn response_writer_frames_with_content_length() {
        let mut out = Vec::new();
        write_response(&mut out, 200, &[("content-type", "text/plain")],
                       b"hello", true)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("content-length: 5\r\n"));
        assert!(s.contains("connection: keep-alive\r\n"));
        assert!(s.ends_with("\r\n\r\nhello"));

        let mut out = Vec::new();
        write_response(&mut out, 503, &[("retry-after", "1")], b"", false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("retry-after: 1\r\n"));
        assert!(s.contains("connection: close\r\n"));
    }

    #[test]
    fn reason_phrases_cover_emitted_statuses() {
        for code in [200, 400, 404, 405, 408, 409, 411, 413, 414, 429,
                     431, 500, 501, 503] {
            assert_ne!(reason_phrase(code), "Unknown", "{code}");
        }
    }
}
