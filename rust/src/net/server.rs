//! The serving node: acceptor + bounded worker pool + batch loop.
//!
//! Thread topology (all `std::net` blocking I/O, no async runtime):
//!
//! ```text
//!             acceptor ──► bounded conn queue ──► N conn workers
//!                                                    │  POST /v1/predict
//!                                                    ▼
//!                              bounded job queue (sync_channel)
//!                                                    │
//!                                                    ▼
//!                      batch loop: Router → DynamicBatcher →
//!                      ServedModel::predict_batch_fast → fulfill slots
//! ```
//!
//! Admission control happens at three doors, each bounded and each
//! shedding with an explicit status instead of queueing unboundedly:
//! the conn backlog (acceptor sheds `503`), the in-flight cap (worker
//! sheds `429` + `Retry-After`), and the job queue (worker sheds `503`
//! + `Retry-After`). Requests whose deadline passes before the batch
//! loop dequeues them are expired with `503` and counted
//! (`net.shed.deadline`). Every time decision reads one
//! [`MonoClock`] — never the wall clock (see the batcher's
//! clock-step pin tests for why).
//!
//! Graceful drain: `POST /v1/admin/shutdown` (or
//! [`NodeHandle::shutdown`]) stops the acceptor, workers finish their
//! current connections, the job channel disconnects, and the batch
//! loop flushes every open batch before exiting — every admitted
//! request gets a response. The node's own [`Registry`] is installed
//! on every thread, so `/stats` is live regardless of the
//! `PGPR_TELEMETRY` environment gate and isolated from other nodes in
//! the same process.
//!
//! Durability: with [`NodeConfig::checkpoint_path`] set the batch loop
//! snapshots the serving state periodically (atomic temp + fsync +
//! rename), `POST /v1/admin/snapshot` forces one, and `POST
//! /v1/admin/reload` hot-swaps in a checkpoint from disk — open
//! batches are flushed against the outgoing model first and predicts
//! arriving during the restore window shed `503` + `Retry-After`, so
//! every admitted request is answered by exactly one model. `/healthz`
//! reports the model family, checkpoint version hash, model age and
//! swap count.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize,
                        Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender,
                      TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::http::{parse_request, write_response, HttpLimits, Method,
                  Parsed, Request};
use crate::linalg::LinalgCtx;
use crate::obsv::{Registry, SnapshotMode, Unit};
use crate::runtime::NativeBackend;
use crate::server::{Batch, DynamicBatcher, ServeScratch, ServedModel};
use crate::util::json::{self, Json};
use crate::util::MonoClock;

/// Admission, batching and transport knobs for one node.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// Connection-worker threads (each owns one connection at a time).
    pub workers: usize,
    /// Accepted-but-unclaimed connection backlog; past it the acceptor
    /// sheds with an immediate 503.
    pub conn_backlog: usize,
    /// Bounded job-queue depth between workers and the batch loop;
    /// past it predicts shed with 503 + `Retry-After`.
    pub queue_cap: usize,
    /// Admitted-but-unanswered predict cap; past it predicts shed with
    /// 429 + `Retry-After`.
    pub max_inflight: usize,
    /// Admission deadline (seconds, monotonic): a request still queued
    /// this long after admission is expired with 503 instead of batched.
    pub deadline_s: f64,
    /// Batch size bound (the batcher's flush-on-size trigger and the
    /// fast path's padded AOT shape).
    pub max_batch: usize,
    /// Batch age bound (the batcher's flush-on-age trigger).
    pub batch_wait_s: f64,
    /// `Retry-After` seconds advertised on 429/503 sheds.
    pub retry_after_s: u64,
    /// Per-read socket timeout (bounds slow-peer stalls).
    pub read_timeout_s: f64,
    /// Idle keep-alive connections are closed after about this long.
    pub idle_close_s: f64,
    /// HTTP parser caps.
    pub limits: HttpLimits,
    /// Checkpoint file this node snapshots to, and the default target
    /// of `POST /v1/admin/snapshot` / `/v1/admin/reload`. `None`
    /// disables periodic snapshotting.
    pub checkpoint_path: Option<String>,
    /// Seconds between periodic background snapshots (0 disables;
    /// needs a `checkpoint_path`). Snapshots run on the batch loop
    /// between batches, write-to-temp + fsync + atomic rename, so a
    /// crash at any instant leaves the last complete image on disk.
    pub snapshot_every_s: f64,
}

impl Default for NodeConfig {
    fn default() -> NodeConfig {
        NodeConfig {
            workers: 8,
            conn_backlog: 64,
            queue_cap: 256,
            max_inflight: 512,
            deadline_s: 0.25,
            max_batch: 16,
            batch_wait_s: 2e-3,
            retry_after_s: 1,
            read_timeout_s: 5.0,
            idle_close_s: 30.0,
            limits: HttpLimits::default(),
            checkpoint_path: None,
            snapshot_every_s: 0.0,
        }
    }
}

/// Batch-loop verdict on one admitted predict request.
enum PredictOutcome {
    Done { mean: f64, var: f64 },
    /// Deadline passed before the request reached a batch.
    Expired,
}

/// One-shot rendezvous between a waiting worker and the batch loop.
struct Slot<T> {
    state: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> Slot<T> {
    fn new() -> Arc<Slot<T>> {
        Arc::new(Slot { state: Mutex::new(None), cv: Condvar::new() })
    }

    fn fulfill(&self, v: T) {
        let mut g = self.state.lock().unwrap();
        *g = Some(v);
        self.cv.notify_all();
    }

    fn wait(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state.lock().unwrap();
        while g.is_none() {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
        g.take()
    }
}

/// Work items flowing from connection workers to the batch loop.
/// Control messages share the queue so they serialize naturally with
/// traffic (a rebalance happens at a well-defined point in the request
/// stream).
enum Job {
    Predict {
        x: Vec<f64>,
        /// Monotonic expiry instant (admission time + deadline).
        deadline_s: f64,
        slot: Arc<Slot<PredictOutcome>>,
    },
    LoseMachine {
        machine: usize,
        done: Arc<Slot<Result<usize, String>>>,
    },
    /// Write the live model to `path`. Read-only; runs on the batch
    /// loop so the image is a consistent point-in-time state. Fulfills
    /// (bytes written, version hash).
    Snapshot {
        path: String,
        done: Arc<Slot<Result<(u64, u32), String>>>,
    },
    /// Replace the live model with the checkpoint at `path`. Open
    /// batches are flushed against the outgoing model first, so no
    /// admitted request straddles the swap. Fulfills (machine count,
    /// version hash).
    Reload {
        path: String,
        done: Arc<Slot<Result<(u64, u32), String>>>,
    },
}

/// State shared by every node thread.
struct NodeShared {
    cfg: NodeConfig,
    registry: Arc<Registry>,
    clock: MonoClock,
    addr: SocketAddr,
    d: usize,
    machines: AtomicUsize,
    inflight: AtomicUsize,
    inflight_peak: AtomicI64,
    queue_depth: AtomicI64,
    queue_peak: AtomicI64,
    shutdown: AtomicBool,
    /// True from reload admission until the new model serves; predicts
    /// shed 503 + `Retry-After` for the duration.
    restoring: AtomicBool,
    /// Completed hot-swaps (reloads) since start.
    swaps: AtomicU64,
    /// CRC-32 of the serving state's checkpoint image (the `/healthz`
    /// "model_version"); widened into an atomic for lock-free reads.
    version: AtomicU64,
    /// Monotonic instant the serving state was installed, as f64 bits.
    born_bits: AtomicU64,
}

impl NodeShared {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Seconds since the current serving state was installed.
    fn model_age_s(&self) -> f64 {
        let born = f64::from_bits(self.born_bits.load(Ordering::Acquire));
        (self.clock.now_s() - born).max(0.0)
    }

    /// Record a new serving state: version hash + birth instant.
    fn set_model(&self, version: u32) {
        self.version.store(u64::from(version), Ordering::Release);
        self.born_bits
            .store(self.clock.now_s().to_bits(), Ordering::Release);
    }

    /// Idempotent drain trigger: stop accepting and poke the acceptor
    /// out of its blocking `accept`.
    fn begin_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        self.registry.counter_add("net.shutdowns", 1);
        let _ = TcpStream::connect_timeout(&self.addr,
                                           Duration::from_secs(1));
    }
}

/// Entry point: bind, spawn the thread topology, return the handle.
pub struct NodeServer;

impl NodeServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving `model`.
    pub fn start(
        model: ServedModel,
        addr: &str,
        cfg: NodeConfig,
    ) -> std::io::Result<NodeHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let (job_tx, job_rx) = mpsc::sync_channel::<Job>(cfg.queue_cap);
        let (conn_tx, conn_rx) =
            mpsc::sync_channel::<TcpStream>(cfg.conn_backlog);
        let version0 = model.to_checkpoint().version_hash();
        let shared = Arc::new(NodeShared {
            d: model.xs.cols,
            machines: AtomicUsize::new(model.machines()),
            cfg,
            registry: Arc::new(Registry::new()),
            clock: MonoClock::new(),
            addr: local,
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicI64::new(0),
            queue_depth: AtomicI64::new(0),
            queue_peak: AtomicI64::new(0),
            shutdown: AtomicBool::new(false),
            restoring: AtomicBool::new(false),
            swaps: AtomicU64::new(0),
            version: AtomicU64::new(u64::from(version0)),
            born_bits: AtomicU64::new(0.0f64.to_bits()),
        });
        let mut threads = Vec::new();
        {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("pgpr-net-batch".into())
                    .spawn(move || batch_loop(sh, model, job_rx))?,
            );
        }
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..shared.cfg.workers.max(1) {
            let sh = shared.clone();
            let rx = conn_rx.clone();
            let tx = job_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pgpr-net-worker-{i}"))
                    .spawn(move || worker_loop(sh, rx, tx))?,
            );
        }
        // workers hold the only job senders now: when they all exit
        // (after the acceptor drops conn_tx), the batch loop sees a
        // disconnect and drains
        drop(job_tx);
        {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("pgpr-net-accept".into())
                    .spawn(move || acceptor_loop(sh, listener, conn_tx))?,
            );
        }
        Ok(NodeHandle { shared, threads: Mutex::new(threads) })
    }
}

/// Running node: address, registry access, shutdown/join.
pub struct NodeHandle {
    shared: Arc<NodeShared>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl NodeHandle {
    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The node's private metrics registry (what `/stats` renders).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.shared.registry
    }

    /// True once a drain has begun.
    pub fn draining(&self) -> bool {
        self.shared.draining()
    }

    /// Begin a graceful drain (idempotent; also reachable over HTTP as
    /// `POST /v1/admin/shutdown`).
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Wait for every node thread to exit. Idempotent; returns
    /// immediately if already joined.
    pub fn join(&self) {
        let hs: Vec<JoinHandle<()>> =
            self.threads.lock().unwrap().drain(..).collect();
        for h in hs {
            let _ = h.join();
        }
    }

    /// [`NodeHandle::shutdown`] then [`NodeHandle::join`].
    pub fn shutdown_and_join(&self) {
        self.shutdown();
        self.join();
    }
}

// ---------------------------------------------------------------------
// acceptor
// ---------------------------------------------------------------------

fn acceptor_loop(
    shared: Arc<NodeShared>,
    listener: TcpListener,
    conn_tx: SyncSender<TcpStream>,
) {
    let _g = shared.registry.install();
    for inc in listener.incoming() {
        if shared.draining() {
            break;
        }
        let stream = match inc {
            Ok(s) => s,
            Err(_) => continue,
        };
        crate::obsv::counter_add("net.conns.accepted", 1);
        match conn_tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(s)) => {
                // bounded backlog: shed at the door rather than queue
                crate::obsv::counter_add("net.shed.conns", 1);
                let retry = shared.cfg.retry_after_s.to_string();
                let mut w = &s;
                let _ = write_response(
                    &mut w,
                    503,
                    &[("content-type", "application/json"),
                      ("retry-after", &retry)],
                    &error_body("connection backlog full"),
                    false,
                );
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    // conn_tx drops here: workers drain queued conns, then exit
}

// ---------------------------------------------------------------------
// connection workers
// ---------------------------------------------------------------------

const JSON_CT: &[(&str, &str)] = &[("content-type", "application/json")];

fn json_body(pairs: Vec<(&str, Json)>) -> Vec<u8> {
    json::obj(pairs).to_string_compact().into_bytes()
}

fn error_body(msg: &str) -> Vec<u8> {
    json_body(vec![("error", msg.into())])
}

/// Write a response, bumping the `net.responses.{2xx,4xx,5xx}`
/// counter; returns whether the connection should stay open.
fn send(
    w: &mut dyn Write,
    status: u16,
    extra: &[(&str, &str)],
    body: &[u8],
    keep: bool,
) -> bool {
    let class = match status {
        200..=299 => "2xx",
        400..=499 => "4xx",
        _ => "5xx",
    };
    crate::obsv::counter_add_labeled("net.responses", class, 1);
    match write_response(w, status, extra, body, keep) {
        Ok(()) => keep,
        Err(_) => false,
    }
}

fn worker_loop(
    shared: Arc<NodeShared>,
    conn_rx: Arc<Mutex<Receiver<TcpStream>>>,
    job_tx: SyncSender<Job>,
) {
    let _g = shared.registry.install();
    loop {
        // hold the lock only while waiting for a connection; handling
        // happens outside it so workers serve concurrently
        let conn = {
            let rx = conn_rx.lock().unwrap();
            rx.recv()
        };
        match conn {
            Ok(stream) => handle_conn(stream, &shared, &job_tx),
            Err(_) => break,
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs_f64(
        shared.cfg.read_timeout_s,
    )));
    crate::obsv::gauge_add("net.conns", 1);
    let mut reader = super::http::HttpReader::new(&stream);
    let mut w: &TcpStream = &stream;
    let idle_budget = (shared.cfg.idle_close_s / shared.cfg.read_timeout_s)
        .ceil()
        .max(1.0) as u32;
    let mut idle = 0u32;
    loop {
        match parse_request(&mut reader, &shared.cfg.limits) {
            Ok(Parsed::Request(req)) => {
                idle = 0;
                crate::obsv::counter_add("net.requests", 1);
                let keep = respond(&req, &mut w, shared, job_tx);
                if !keep || shared.draining() {
                    break;
                }
            }
            Ok(Parsed::ClosedIdle) => break,
            Ok(Parsed::TimeoutIdle) => {
                idle += 1;
                if idle >= idle_budget || shared.draining() {
                    break;
                }
            }
            Err(e) => {
                crate::obsv::counter_add("net.http.errors", 1);
                if let Some((status, msg)) = e.status() {
                    send(&mut w, status, JSON_CT, &error_body(msg), false);
                }
                break;
            }
        }
    }
    crate::obsv::gauge_add("net.conns", -1);
}

fn respond(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) -> bool {
    const ROUTES: &[&str] = &["/healthz", "/stats", "/v1/predict",
                              "/v1/admin/lose_machine",
                              "/v1/admin/snapshot", "/v1/admin/reload",
                              "/v1/admin/shutdown"];
    match (req.method, req.path.as_str()) {
        (Method::Get, "/healthz") => handle_healthz(req, w, shared),
        (Method::Get, "/stats") => handle_stats(req, w, shared),
        (Method::Post, "/v1/predict") => {
            handle_predict(req, w, shared, job_tx)
        }
        (Method::Post, "/v1/admin/lose_machine") => {
            handle_lose_machine(req, w, shared, job_tx)
        }
        (Method::Post, "/v1/admin/snapshot") => {
            handle_snapshot(req, w, shared, job_tx)
        }
        (Method::Post, "/v1/admin/reload") => {
            handle_reload(req, w, shared, job_tx)
        }
        (Method::Post, "/v1/admin/shutdown") => {
            send(w, 200, JSON_CT,
                 &json_body(vec![("status", "draining".into())]), false);
            shared.begin_shutdown();
            false
        }
        (_, p) if ROUTES.contains(&p) => {
            send(w, 405, JSON_CT, &error_body("method not allowed"),
                 req.keep_alive)
        }
        _ => send(w, 404, JSON_CT, &error_body("not found"),
                  req.keep_alive),
    }
}

fn handle_healthz(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
) -> bool {
    let status = if shared.draining() {
        "draining"
    } else if shared.restoring.load(Ordering::Acquire) {
        "restoring"
    } else {
        "ok"
    };
    let version = shared.version.load(Ordering::Acquire) as u32;
    let body = json_body(vec![
        ("status", status.into()),
        ("method", "served".into()),
        ("model_version", format!("{version:08x}").into()),
        ("model_age_s", shared.model_age_s().into()),
        ("swaps", (shared.swaps.load(Ordering::Acquire) as usize).into()),
        ("d", shared.d.into()),
        ("machines", shared.machines.load(Ordering::Acquire).into()),
        ("queue_cap", shared.cfg.queue_cap.into()),
        ("max_batch", shared.cfg.max_batch.into()),
        ("max_inflight", shared.cfg.max_inflight.into()),
        ("deadline_s", shared.cfg.deadline_s.into()),
    ]);
    send(w, 200, JSON_CT, &body, req.keep_alive)
}

fn handle_stats(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
) -> bool {
    let snap = shared.registry.snapshot(SnapshotMode::Full);
    if req.query_has("format", "json") {
        let body = snap.to_json().to_string_pretty() + "\n";
        send(w, 200, JSON_CT, body.as_bytes(), req.keep_alive)
    } else {
        send(w, 200,
             &[("content-type", "text/plain; version=0.0.4")],
             snap.to_prometheus().as_bytes(), req.keep_alive)
    }
}

fn parse_predict_body(
    body: &[u8],
    d: usize,
) -> Result<Vec<f64>, &'static str> {
    let s = std::str::from_utf8(body).map_err(|_| "body not utf-8")?;
    let doc = Json::parse(s).map_err(|_| "body not valid json")?;
    let arr = doc
        .get("x")
        .and_then(Json::as_arr)
        .ok_or("body must be {\"x\": [f64; d]}")?;
    if arr.len() != d {
        return Err("wrong query dimension");
    }
    let mut x = Vec::with_capacity(arr.len());
    for v in arr {
        x.push(v.as_f64().ok_or("non-numeric x element")?);
    }
    Ok(x)
}

fn release_inflight(shared: &NodeShared) {
    shared.inflight.fetch_sub(1, Ordering::AcqRel);
    crate::obsv::gauge_add("net.inflight", -1);
}

fn handle_predict(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) -> bool {
    let retry = shared.cfg.retry_after_s.to_string();
    let shed_headers: [(&str, &str); 2] =
        [("content-type", "application/json"), ("retry-after", &retry)];
    let x = match parse_predict_body(&req.body, shared.d) {
        Ok(x) => x,
        Err(msg) => {
            return send(w, 400, JSON_CT, &error_body(msg), req.keep_alive)
        }
    };
    if shared.draining() {
        return send(w, 503, &shed_headers, &error_body("draining"), false);
    }
    // restore window: a reload is in flight; the client retries after
    // the swap rather than waiting on a model that is being replaced
    if shared.restoring.load(Ordering::Acquire) {
        crate::obsv::counter_add("net.shed.restoring", 1);
        return send(w, 503, &shed_headers, &error_body("model restoring"),
                    req.keep_alive);
    }

    // door 1: in-flight cap (429 — the client itself should back off)
    let cur = shared.inflight.fetch_add(1, Ordering::AcqRel);
    if cur >= shared.cfg.max_inflight {
        shared.inflight.fetch_sub(1, Ordering::AcqRel);
        crate::obsv::counter_add("net.shed.inflight", 1);
        return send(w, 429, &shed_headers,
                    &error_body("too many requests in flight"),
                    req.keep_alive);
    }
    shared.inflight_peak.fetch_max(cur as i64 + 1, Ordering::AcqRel);
    crate::obsv::gauge_add("net.inflight", 1);

    // door 2: bounded job queue (503 — the node is saturated)
    let enq_s = shared.clock.now_s();
    let slot = Slot::new();
    let job = Job::Predict {
        x,
        deadline_s: enq_s + shared.cfg.deadline_s,
        slot: slot.clone(),
    };
    match job_tx.try_send(job) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            release_inflight(shared);
            crate::obsv::counter_add("net.shed.queue", 1);
            return send(w, 503, &shed_headers,
                        &error_body("request queue full"), req.keep_alive);
        }
        Err(TrySendError::Disconnected(_)) => {
            release_inflight(shared);
            return send(w, 503, &shed_headers,
                        &error_body("serving loop stopped"), false);
        }
    }
    let depth = shared.queue_depth.fetch_add(1, Ordering::AcqRel) + 1;
    shared.queue_peak.fetch_max(depth, Ordering::AcqRel);
    crate::obsv::gauge_add("net.queue_depth", 1);

    // the batch loop owes every admitted request an answer; the extra
    // margin covers batching wait + compute on a loaded host
    let budget = Duration::from_secs_f64(
        shared.cfg.deadline_s + shared.cfg.batch_wait_s + 30.0,
    );
    let outcome = slot.wait(budget);
    release_inflight(shared);
    match outcome {
        Some(PredictOutcome::Done { mean, var }) => {
            let lat = shared.clock.now_s() - enq_s;
            crate::obsv::observe("net.latency_s", Unit::Seconds, lat);
            crate::obsv::counter_add("net.predict.ok", 1);
            let body =
                json_body(vec![("mean", mean.into()), ("var", var.into())]);
            send(w, 200, JSON_CT, &body, req.keep_alive)
        }
        Some(PredictOutcome::Expired) => send(
            w, 503, &shed_headers,
            &error_body("deadline expired before batching"),
            req.keep_alive,
        ),
        None => {
            crate::obsv::counter_add("net.serve.stuck", 1);
            send(w, 500, JSON_CT, &error_body("serving timeout"), false)
        }
    }
}

fn handle_lose_machine(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) -> bool {
    let machine = match std::str::from_utf8(&req.body)
        .ok()
        .and_then(|s| Json::parse(s).ok())
        .and_then(|d| d.get("machine").and_then(Json::as_usize))
    {
        Some(m) => m,
        None => {
            return send(w, 400, JSON_CT,
                        &error_body("body must be {\"machine\": k}"),
                        req.keep_alive)
        }
    };
    let done = Slot::new();
    let job = Job::LoseMachine { machine, done: done.clone() };
    if job_tx.try_send(job).is_err() {
        return send(w, 503, JSON_CT,
                    &error_body("serving loop unavailable"),
                    req.keep_alive);
    }
    // the rebalance refits every survivor's summaries — allow for it
    match done.wait(Duration::from_secs(120)) {
        Some(Ok(survivors)) => {
            let body = json_body(vec![("machines", survivors.into())]);
            send(w, 200, JSON_CT, &body, req.keep_alive)
        }
        Some(Err(msg)) => {
            send(w, 409, JSON_CT, &error_body(&msg), req.keep_alive)
        }
        None => send(w, 500, JSON_CT, &error_body("rebalance timed out"),
                     false),
    }
}

/// Resolve the checkpoint path for an admin snapshot/reload request:
/// explicit `{"path": "..."}` body, else the node's configured
/// `checkpoint_path`.
fn admin_ckpt_path(
    req: &Request,
    shared: &NodeShared,
) -> Result<String, &'static str> {
    let explicit = std::str::from_utf8(&req.body)
        .ok()
        .filter(|s| !s.trim().is_empty())
        .and_then(|s| Json::parse(s).ok())
        .and_then(|d| {
            d.get("path").and_then(|p| p.as_str().map(str::to_string))
        });
    match explicit.or_else(|| shared.cfg.checkpoint_path.clone()) {
        Some(p) => Ok(p),
        None => Err("no path given and node has no --checkpoint"),
    }
}

fn handle_snapshot(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) -> bool {
    let path = match admin_ckpt_path(req, shared) {
        Ok(p) => p,
        Err(msg) => {
            return send(w, 400, JSON_CT, &error_body(msg), req.keep_alive)
        }
    };
    let done = Slot::new();
    if job_tx.try_send(Job::Snapshot { path, done: done.clone() }).is_err()
    {
        return send(w, 503, JSON_CT,
                    &error_body("serving loop unavailable"),
                    req.keep_alive);
    }
    match done.wait(Duration::from_secs(120)) {
        Some(Ok((bytes, version))) => {
            let body = json_body(vec![
                ("bytes", (bytes as usize).into()),
                ("version", format!("{version:08x}").into()),
            ]);
            send(w, 200, JSON_CT, &body, req.keep_alive)
        }
        Some(Err(msg)) => {
            send(w, 500, JSON_CT, &error_body(&msg), req.keep_alive)
        }
        None => send(w, 500, JSON_CT, &error_body("snapshot timed out"),
                     false),
    }
}

fn handle_reload(
    req: &Request,
    w: &mut dyn Write,
    shared: &Arc<NodeShared>,
    job_tx: &SyncSender<Job>,
) -> bool {
    let path = match admin_ckpt_path(req, shared) {
        Ok(p) => p,
        Err(msg) => {
            return send(w, 400, JSON_CT, &error_body(msg), req.keep_alive)
        }
    };
    // close the predict door for the restore window; the batch loop
    // reopens it once the swap (or the failure) is complete
    shared.restoring.store(true, Ordering::Release);
    let done = Slot::new();
    if job_tx.try_send(Job::Reload { path, done: done.clone() }).is_err() {
        shared.restoring.store(false, Ordering::Release);
        return send(w, 503, JSON_CT,
                    &error_body("serving loop unavailable"),
                    req.keep_alive);
    }
    match done.wait(Duration::from_secs(120)) {
        Some(Ok((machines, version))) => {
            let body = json_body(vec![
                ("machines", (machines as usize).into()),
                ("version", format!("{version:08x}").into()),
                ("swaps",
                 (shared.swaps.load(Ordering::Acquire) as usize).into()),
            ]);
            send(w, 200, JSON_CT, &body, req.keep_alive)
        }
        Some(Err(msg)) => {
            send(w, 409, JSON_CT, &error_body(&msg), req.keep_alive)
        }
        None => {
            send(w, 500, JSON_CT, &error_body("reload timed out"), false)
        }
    }
}

// ---------------------------------------------------------------------
// batch loop
// ---------------------------------------------------------------------

fn execute_batch(
    model: &ServedModel,
    batch: &Batch,
    pad_to: usize,
    lctx: &LinalgCtx,
    scratch: &mut ServeScratch,
    pending: &mut HashMap<u64, Arc<Slot<PredictOutcome>>>,
) {
    let rows = batch.ids.len();
    let (mean, var) = if model.mixed_precision() {
        model.predict_batch_fast_f32(batch.machine, &batch.xs, rows,
                                     pad_to, lctx, scratch)
    } else {
        model.predict_batch_fast(batch.machine, &batch.xs, rows, pad_to,
                                 lctx, scratch)
    };
    crate::obsv::counter_add("net.batches", 1);
    crate::obsv::observe("net.batch_rows", Unit::Count, rows as f64);
    for (k, id) in batch.ids.iter().enumerate() {
        if let Some(slot) = pending.remove(id) {
            slot.fulfill(PredictOutcome::Done {
                mean: mean[k],
                var: var[k],
            });
        }
    }
}

fn batch_loop(
    shared: Arc<NodeShared>,
    mut model: ServedModel,
    rx: Receiver<Job>,
) {
    let _g = shared.registry.install();
    let pad_to = shared.cfg.max_batch;
    let lctx = LinalgCtx::serial();
    let mut scratch = ServeScratch::new();
    let mut batcher = DynamicBatcher::new(
        model.machines(),
        shared.d,
        shared.cfg.max_batch,
        shared.cfg.batch_wait_s,
    );
    let mut pending: HashMap<u64, Arc<Slot<PredictOutcome>>> =
        HashMap::new();
    let mut next_id = 0u64;
    let mut batcher_peak = 0i64;
    let snap_path = shared.cfg.checkpoint_path.clone();
    let snap_every = shared.cfg.snapshot_every_s;
    let mut last_snap_s = 0.0f64;
    // wake at least as often as the age bound so expiry flushes are
    // prompt, but never busy-spin
    let tick = Duration::from_secs_f64(
        shared.cfg.batch_wait_s.clamp(1e-4, 0.05),
    );
    loop {
        match rx.recv_timeout(tick) {
            Ok(Job::Predict { x, deadline_s, slot }) => {
                shared.queue_depth.fetch_sub(1, Ordering::AcqRel);
                crate::obsv::gauge_add("net.queue_depth", -1);
                let now = shared.clock.now_s();
                if now >= deadline_s {
                    crate::obsv::counter_add("net.shed.deadline", 1);
                    slot.fulfill(PredictOutcome::Expired);
                } else {
                    let m = model.router.route(&x);
                    let id = next_id;
                    next_id += 1;
                    pending.insert(id, slot);
                    if let Some(full) = batcher.push(m, id, &x, now) {
                        execute_batch(&model, &full, pad_to, &lctx,
                                      &mut scratch, &mut pending);
                        batcher.recycle(full);
                    }
                }
            }
            Ok(Job::LoseMachine { machine, done }) => {
                // finish open batches against the pre-loss model so no
                // admitted request straddles the swap
                for b in batcher.flush_all() {
                    execute_batch(&model, &b, pad_to, &lctx, &mut scratch,
                                  &mut pending);
                    batcher.recycle(b);
                }
                match model.lose_machine(machine, &NativeBackend) {
                    Ok(()) => {
                        shared.machines
                            .store(model.machines(), Ordering::Release);
                        shared.set_model(
                            model.to_checkpoint().version_hash());
                        batcher = DynamicBatcher::new(
                            model.machines(),
                            shared.d,
                            shared.cfg.max_batch,
                            shared.cfg.batch_wait_s,
                        );
                        crate::obsv::counter_add("net.machines.lost", 1);
                        done.fulfill(Ok(model.machines()));
                    }
                    Err(e) => done.fulfill(Err(e.to_string())),
                }
            }
            Ok(Job::Snapshot { path, done }) => {
                // read-only: open batches keep their model; the image
                // is the state every in-flight request is served from
                let ck = model.to_checkpoint();
                match ck.write_file(&path) {
                    Ok(bytes) => {
                        let vh = ck.version_hash();
                        shared.version
                            .store(u64::from(vh), Ordering::Release);
                        done.fulfill(Ok((bytes, vh)));
                    }
                    Err(e) => done.fulfill(Err(e.to_string())),
                }
            }
            Ok(Job::Reload { path, done }) => {
                // finish open batches against the outgoing model first:
                // every admitted request is answered by exactly one
                // model, never a half-swapped state
                for b in batcher.flush_all() {
                    execute_batch(&model, &b, pad_to, &lctx, &mut scratch,
                                  &mut pending);
                    batcher.recycle(b);
                }
                match ServedModel::load(&path) {
                    Ok(next) if next.xs.cols != shared.d => {
                        done.fulfill(Err(format!(
                            "checkpoint dim {} != serving dim {}",
                            next.xs.cols, shared.d)));
                    }
                    Ok(next) => {
                        let vh = next.to_checkpoint().version_hash();
                        let _retired = model.swap_in(next);
                        shared.machines
                            .store(model.machines(), Ordering::Release);
                        shared.swaps.fetch_add(1, Ordering::AcqRel);
                        shared.set_model(vh);
                        batcher = DynamicBatcher::new(
                            model.machines(),
                            shared.d,
                            shared.cfg.max_batch,
                            shared.cfg.batch_wait_s,
                        );
                        crate::obsv::counter_add("net.reloads", 1);
                        done.fulfill(Ok((model.machines() as u64, vh)));
                    }
                    Err(e) => done.fulfill(Err(e.to_string())),
                }
                shared.restoring.store(false, Ordering::Release);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        let now = shared.clock.now_s();
        for b in batcher.flush_expired(now) {
            execute_batch(&model, &b, pad_to, &lctx, &mut scratch,
                          &mut pending);
            batcher.recycle(b);
        }
        let depth = batcher.pending() as i64;
        if depth > batcher_peak {
            batcher_peak = depth;
            crate::obsv::gauge_set("serve.queue_depth_peak", batcher_peak);
        }
        crate::obsv::gauge_set(
            "net.queue_depth_peak",
            shared.queue_peak.load(Ordering::Acquire),
        );
        crate::obsv::gauge_set(
            "net.inflight_peak",
            shared.inflight_peak.load(Ordering::Acquire),
        );
        // periodic background snapshot: same atomic write path as the
        // admin endpoint, between batches so the image is consistent
        if snap_every > 0.0 && now - last_snap_s >= snap_every {
            if let Some(path) = &snap_path {
                last_snap_s = now;
                let ck = model.to_checkpoint();
                match ck.write_file(path) {
                    Ok(_) => {
                        shared.version.store(
                            u64::from(ck.version_hash()),
                            Ordering::Release,
                        );
                        crate::obsv::counter_add("net.snapshot.auto", 1);
                    }
                    Err(_) => {
                        crate::obsv::counter_add("net.snapshot.errors", 1);
                    }
                }
            }
        }
        crate::obsv::gauge_set("net.model.age_s",
                               shared.model_age_s() as i64);
        crate::obsv::gauge_set(
            "net.model.version",
            shared.version.load(Ordering::Acquire) as i64,
        );
        crate::obsv::gauge_set(
            "net.model.swaps",
            shared.swaps.load(Ordering::Acquire) as i64,
        );
    }
    // drain: every admitted request still open gets its answer
    for b in batcher.flush_all() {
        execute_batch(&model, &b, pad_to, &lctx, &mut scratch,
                      &mut pending);
        batcher.recycle(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_bounded() {
        let c = NodeConfig::default();
        assert!(c.queue_cap > 0 && c.max_inflight > 0);
        assert!(c.conn_backlog > 0 && c.workers > 0);
        assert!(c.deadline_s > 0.0 && c.batch_wait_s > 0.0);
        assert!(c.limits.max_body_bytes > 0);
    }

    #[test]
    fn slot_rendezvous_and_timeout() {
        let s: Arc<Slot<u32>> = Slot::new();
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.fulfill(7);
        });
        assert_eq!(s.wait(Duration::from_secs(5)), Some(7));
        t.join().unwrap();
        // an unfulfilled slot times out with None
        let empty: Arc<Slot<u32>> = Slot::new();
        assert_eq!(empty.wait(Duration::from_millis(5)), None);
    }

    #[test]
    fn predict_body_parsing() {
        assert_eq!(parse_predict_body(b"{\"x\":[1.0,2.0]}", 2).unwrap(),
                   vec![1.0, 2.0]);
        assert!(parse_predict_body(b"{\"x\":[1.0]}", 2).is_err());
        assert!(parse_predict_body(b"{\"y\":[1.0,2.0]}", 2).is_err());
        assert!(parse_predict_body(b"not json", 2).is_err());
        assert!(parse_predict_body(b"{\"x\":[1.0,\"a\"]}", 2).is_err());
        assert!(parse_predict_body(&[0xff, 0xfe], 2).is_err());
    }
}
