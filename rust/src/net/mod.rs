//! TCP serving front-end: a real network edge for the serving stack.
//!
//! Everything upstream of this module treats serving as a library
//! call ([`crate::server::ServedModel::predict_batch_fast`] behind a
//! [`crate::server::DynamicBatcher`]). This module puts that stack
//! behind a socket with the properties a real deployment needs and a
//! benchmark can measure:
//!
//! - [`http`] — a minimal, hardened HTTP/1.1 core: bounded request
//!   lines/headers/bodies, keep-alive and pipelining, slow-peer
//!   timeouts, `Content-Length`-framed responses only. Std-only by
//!   design — blocking `std::net` sockets and threads, no async
//!   runtime, no new dependencies.
//! - [`server`] — the serving node: acceptor → bounded worker pool →
//!   bounded job queue → batch loop. Admission control sheds with
//!   `429`/`503` + `Retry-After` instead of queueing unboundedly, and
//!   expires requests whose deadline passed before batching; all time
//!   arithmetic runs on a monotonic clock
//!   ([`crate::util::MonoClock`]). Graceful drain flushes every open
//!   batch so each admitted request gets an answer.
//! - [`loadgen`] — an open-loop (coordinated-omission-safe) load
//!   generator that sweeps arrival rates over real sockets and writes
//!   `BENCH_e2e.json` with achieved qps, sojourn percentiles, shed
//!   counts and scraped queue-depth peaks.
//!
//! Endpoints served by a node: `POST /v1/predict` (JSON in/out,
//! bitwise-identical to a direct in-process
//! `predict_batch_fast` call on the same query), `GET /stats`
//! (Prometheus text, or the `pgpr-telemetry/1` JSON document with
//! `?format=json`), `GET /healthz`, and the admin verbs
//! `POST /v1/admin/lose_machine` / `POST /v1/admin/shutdown`.
//!
//! Exposed on the CLI as `pgpr node --listen ADDR` and
//! `pgpr loadgen --target ADDR`.

pub mod http;
pub mod loadgen;
pub mod server;

pub use http::{HttpLimits, Method, Parsed, Request};
pub use loadgen::{run_loadgen, HttpClient, LoadgenConfig,
                  LoadgenReport, StepStats};
pub use server::{NodeConfig, NodeHandle, NodeServer};
