//! General-purpose substrates: PRNG, JSON, timing, thread pool, logging.

pub mod json;
pub mod log;
pub mod pool;
pub mod rng;
pub mod time;

pub use rng::Pcg64;
pub use time::{MonoClock, Stopwatch};
