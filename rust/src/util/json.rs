//! Minimal JSON parser/writer.
//!
//! Exists because the offline environment vendors no serde facade. Scope:
//! everything the repo needs — the AOT `artifacts/manifest.json`, CLI
//! config files, and experiment-result dumps. Full RFC 8259 value model
//! (null/bool/number/string/array/object), `\uXXXX` escapes, and a
//! writer with stable key order (sorted) for reproducible outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly (sorted keys).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation (sorted keys).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Builder conveniences used by result dumps.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m": {"x": [1, 2.5, true, null], "s": "q\"uote"}, "n": -3}"#;
        let v = Json::parse(src).unwrap();
        for rendered in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&rendered).unwrap(), v);
        }
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 1.5, "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn reads_real_manifest_shape() {
        let src = r#"{"profiles": {"tiny": {"d": 3, "graphs":
            {"g": {"file": "f.hlo.txt", "inputs": [["arg0", [32, 3],
            "float64"]], "outputs": 3}}}}}"#;
        let v = Json::parse(src).unwrap();
        let g = v.get("profiles").unwrap().get("tiny").unwrap()
            .get("graphs").unwrap().get("g").unwrap();
        assert_eq!(g.get("outputs").unwrap().as_usize(), Some(3));
        let shape = g.get("inputs").unwrap().as_arr().unwrap()[0]
            .as_arr().unwrap()[1].as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(32));
    }

    #[test]
    fn obj_builder_sorted_output() {
        let v = obj(vec![("z", 1usize.into()), ("a", "x".into())]);
        assert_eq!(v.to_string_compact(), r#"{"a":"x","z":1}"#);
    }
}
