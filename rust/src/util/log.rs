//! Tiny leveled logger (stderr), controlled by `PGPR_LOG` (error|warn|info|debug).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset

fn current() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let from_env = match std::env::var("PGPR_LOG").ok().as_deref() {
        Some("error") => Level::Error as u8,
        Some("warn") => Level::Warn as u8,
        Some("debug") => Level::Debug as u8,
        Some("info") | _ => Level::Info as u8,
    };
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= current()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[pgpr {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
