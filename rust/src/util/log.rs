//! Tiny leveled logger (stderr), controlled by `PGPR_LOG` (error|warn|info|debug).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset

/// Parse a `PGPR_LOG` value: the level, plus the rejected string when
/// the value is not one of the accepted set (callers warn once and
/// fall back to Info — a typo like `PGPR_LOG=trace` must not silently
/// become "info with no explanation").
fn parse_level(val: Option<&str>) -> (u8, Option<&str>) {
    match val {
        Some("error") => (Level::Error as u8, None),
        Some("warn") => (Level::Warn as u8, None),
        Some("info") | None => (Level::Info as u8, None),
        Some("debug") => (Level::Debug as u8, None),
        Some(other) => (Level::Info as u8, Some(other)),
    }
}

fn current() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let env = std::env::var("PGPR_LOG").ok();
    let (from_env, rejected) = parse_level(env.as_deref());
    if let Some(bad) = rejected {
        // One-time: LEVEL is set below, so this branch never re-runs.
        eprintln!(
            "[pgpr WARN ] unrecognized PGPR_LOG value {bad:?} \
             (accepted: error|warn|info|debug); defaulting to info"
        );
    }
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= current()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[pgpr {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Accepted values map to their level; anything else is rejected
    /// (named, so `current()` can warn) and falls back to Info.
    #[test]
    fn parse_level_rejects_typos() {
        assert_eq!(parse_level(Some("error")), (Level::Error as u8, None));
        assert_eq!(parse_level(Some("warn")), (Level::Warn as u8, None));
        assert_eq!(parse_level(Some("info")), (Level::Info as u8, None));
        assert_eq!(parse_level(Some("debug")), (Level::Debug as u8, None));
        assert_eq!(parse_level(None), (Level::Info as u8, None));
        assert_eq!(
            parse_level(Some("trace")),
            (Level::Info as u8, Some("trace"))
        );
        assert_eq!(
            parse_level(Some("INFO")),
            (Level::Info as u8, Some("INFO"))
        );
    }

    #[test]
    fn levels_ordered() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
