//! A small scoped thread pool.
//!
//! No tokio/rayon in the offline vendor set, so the coordinator brings its
//! own worker pool. Design: fixed worker threads, a shared FIFO injector
//! guarded by `Mutex + Condvar`, and a `scope`-style API (`run_batch`)
//! that blocks until every submitted job finishes, so jobs may borrow from
//! the caller's stack via the usual `'static`-erasing scope trick.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    work_ready: Condvar,
    all_done: Condvar,
    outstanding: AtomicUsize,
}

struct QueueState {
    jobs: Vec<Job>,
    shutdown: bool,
}

/// Fixed-size thread pool with batch-join semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: Vec::new(), shutdown: false }),
            work_ready: Condvar::new(),
            all_done: Condvar::new(),
            outstanding: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|_| {
                let sh = Arc::clone(&shared);
                thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit one fire-and-forget job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push(Box::new(job));
        drop(q);
        self.shared.work_ready.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while self.shared.outstanding.load(Ordering::SeqCst) != 0 {
            q = self.shared.all_done.wait(q).unwrap();
        }
    }

    /// Run a batch of closures (which may borrow locally) to completion.
    ///
    /// Safety of the lifetime erasure: `join` below blocks until all jobs
    /// finished, so borrowed data outlives every job.
    pub fn run_batch<'env, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        for job in jobs {
            // Erase the lifetime: justified by the join() barrier below.
            let erased: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
            let erased: Job = unsafe { std::mem::transmute(erased) };
            self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push(erased);
            drop(q);
            self.shared.work_ready.notify_one();
        }
        self.join();
    }

    /// Map `f` over `0..n` in parallel, collecting results in order.
    pub fn par_map<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<(usize, &mut Option<T>)> =
                out.iter_mut().enumerate().collect();
            let fref = &f;
            self.run_batch(
                slots
                    .into_iter()
                    .map(|(i, slot)| {
                        move || {
                            *slot = Some(fref(i));
                        }
                    })
                    .collect(),
            );
        }
        out.into_iter().map(|v| v.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_ready.wait(q).unwrap();
            }
        };
        job();
        if shared.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            // last job: wake joiners (lock to avoid missed wakeups)
            let _q = shared.queue.lock().unwrap();
            shared.all_done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_batch_borrows_locals() {
        let pool = ThreadPool::new(3);
        let mut outputs = vec![0usize; 8];
        {
            let jobs: Vec<_> = outputs
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| move || *slot = i * i)
                .collect();
            pool.run_batch(jobs);
        }
        assert_eq!(outputs, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn par_map_ordered() {
        let pool = ThreadPool::new(2);
        let out = pool.par_map(16, |i| i * 2);
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(1);
        pool.join(); // must not hang
    }

    #[test]
    fn reusable_across_batches() {
        let pool = ThreadPool::new(2);
        for round in 0..5 {
            let out = pool.par_map(4, |i| i + round);
            assert_eq!(out, (0..4).map(|i| i + round).collect::<Vec<_>>());
        }
    }
}
