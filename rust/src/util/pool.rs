//! A small scoped thread pool.
//!
//! No tokio/rayon in the offline vendor set, so the coordinator brings its
//! own worker pool. Design: fixed worker threads, a shared FIFO injector
//! guarded by `Mutex + Condvar`, and a `scope`-style API (`run_batch`)
//! that blocks until every job of *its own batch* finishes — so jobs may
//! borrow from the caller's stack via the usual `'static`-erasing scope
//! trick, and concurrent batches on one shared pool don't wait on each
//! other.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    work_ready: Condvar,
    all_done: Condvar,
    outstanding: AtomicUsize,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Completion tracking for one `run_batch` call.
struct BatchState {
    remaining: AtomicUsize,
    lock: Mutex<()>,
    done: Condvar,
}

/// Decrements its batch's `remaining` on drop — drop runs even when the
/// wrapped job panics, so the batch waiter can never hang.
struct BatchGuard(Arc<BatchState>);

impl Drop for BatchGuard {
    fn drop(&mut self) {
        if self.0.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            // lock to avoid a missed wakeup against the waiter's check
            let _g = self.0.lock.lock().unwrap();
            self.0.done.notify_all();
        }
    }
}

thread_local! {
    /// On a pool worker thread: the address of that pool's `Shared`
    /// (0 elsewhere). Guards against *same-pool* reentrant `run_batch`,
    /// which would deadlock; nesting across distinct pools (disjoint
    /// workers) is deadlock-free and stays allowed.
    static WORKER_OF_POOL: std::cell::Cell<usize> =
        const { std::cell::Cell::new(0) };
}

/// Fixed-size thread pool with batch-join semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work_ready: Condvar::new(),
            all_done: Condvar::new(),
            outstanding: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|_| {
                let sh = Arc::clone(&shared);
                thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// True when the calling thread is one of *this* pool's workers.
    ///
    /// Used by [`crate::linalg::LinalgCtx`] to degrade pool-nested
    /// linalg calls to serial execution instead of tripping the
    /// same-pool reentrancy assert in [`ThreadPool::run_batch`]: a
    /// worker running one simulated machine's math must not wait on
    /// jobs that need the very worker it occupies.
    pub fn is_worker(&self) -> bool {
        WORKER_OF_POOL.with(|w| w.get()) == Arc::as_ptr(&self.shared) as usize
    }

    /// Submit one fire-and-forget job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.shared.work_ready.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while self.shared.outstanding.load(Ordering::SeqCst) != 0 {
            q = self.shared.all_done.wait(q).unwrap();
        }
    }

    /// Run a batch of closures (which may borrow locally) to completion.
    ///
    /// Waits on a *batch-local* counter, not the pool-global one, so
    /// concurrent `run_batch`/`par_map` callers sharing one pool do not
    /// block on each other's jobs. Must not be called from inside a pool
    /// worker (the caller would occupy the worker its own jobs need) —
    /// asserted below; run nested work inline instead.
    ///
    /// Safety of the lifetime erasure: the batch-local wait blocks until
    /// every job's body has finished (the completion guard drops even on
    /// panic), so borrowed data outlives every job.
    pub fn run_batch<'env, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        assert!(
            WORKER_OF_POOL.with(|w| w.get())
                != Arc::as_ptr(&self.shared) as usize,
            "ThreadPool::run_batch called from one of this same pool's \
             worker threads — this deadlocks (the caller occupies the \
             worker its own jobs need); run nested work inline instead"
        );
        let batch = Arc::new(BatchState {
            remaining: AtomicUsize::new(jobs.len()),
            lock: Mutex::new(()),
            done: Condvar::new(),
        });
        for job in jobs {
            // The guard decrements `remaining` when dropped — i.e. even
            // when `job()` panics (the worker's catch_unwind runs the
            // unwind through this frame).
            let guard = BatchGuard(Arc::clone(&batch));
            let wrapped = move || {
                let _guard = guard;
                job();
            };
            // Erase the lifetime: justified by the batch wait below.
            let erased: Box<dyn FnOnce() + Send + 'env> = Box::new(wrapped);
            let erased: Job = unsafe { std::mem::transmute(erased) };
            self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(erased);
            drop(q);
            self.shared.work_ready.notify_one();
        }
        let mut g = batch.lock.lock().unwrap();
        while batch.remaining.load(Ordering::SeqCst) != 0 {
            g = batch.done.wait(g).unwrap();
        }
    }

    /// Map `f` over `0..n` in parallel, collecting results in order.
    pub fn par_map<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<(usize, &mut Option<T>)> =
                out.iter_mut().enumerate().collect();
            let fref = &f;
            self.run_batch(
                slots
                    .into_iter()
                    .map(|(i, slot)| {
                        move || {
                            *slot = Some(fref(i));
                        }
                    })
                    .collect(),
            );
        }
        out.into_iter()
            .map(|v| v.expect("parallel task panicked (original message \
                               printed by the panic hook above)"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    WORKER_OF_POOL.with(|w| w.set(Arc::as_ptr(&shared) as usize));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_ready.wait(q).unwrap();
            }
        };
        // Contain panics: a panicking job must still decrement
        // `outstanding` (else `join` deadlocks) and must not kill this
        // worker. The panic payload is dropped here — the default hook
        // has already printed it — and propagation to the caller happens
        // in `par_map`, whose result slot stays unfilled.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if shared.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            // last job: wake joiners (lock to avoid missed wakeups)
            let _q = shared.queue.lock().unwrap();
            shared.all_done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_batch_borrows_locals() {
        let pool = ThreadPool::new(3);
        let mut outputs = vec![0usize; 8];
        {
            let jobs: Vec<_> = outputs
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| move || *slot = i * i)
                .collect();
            pool.run_batch(jobs);
        }
        assert_eq!(outputs, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn par_map_ordered() {
        let pool = ThreadPool::new(2);
        let out = pool.par_map(16, |i| i * 2);
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(1);
        pool.join(); // must not hang
    }

    #[test]
    fn panicking_job_neither_deadlocks_nor_kills_workers() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for i in 0..6 {
            let d = Arc::clone(&done);
            pool.submit(move || {
                if i == 2 {
                    panic!("boom");
                }
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join(); // must return despite the panic
        assert_eq!(done.load(Ordering::SeqCst), 5);
        // the pool is still fully operational afterwards
        let out = pool.par_map(8, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_propagates_task_panic_to_caller() {
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.par_map(4, |i| {
                    if i == 3 {
                        panic!("task failed");
                    }
                    i
                })
            }),
        );
        assert!(res.is_err(), "panic must surface on the calling thread");
    }

    #[test]
    fn concurrent_batches_do_not_wait_on_each_other() {
        // two threads drive disjoint batches through one pool; each
        // run_batch waits on its own batch-local counter, so both finish
        let pool = Arc::new(ThreadPool::new(4));
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let p = Arc::clone(&pool);
                thread::spawn(move || p.par_map(8, move |i| t * 100 + i))
            })
            .collect();
        for (t, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap();
            assert_eq!(out, (0..8).map(|i| t * 100 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn cross_pool_nesting_is_allowed() {
        // a worker of pool A driving a batch on pool B is deadlock-free
        // (disjoint workers) and must not trip the same-pool guard
        let a = ThreadPool::new(2);
        let b = Arc::new(ThreadPool::new(2));
        let out = a.par_map(3, move |i| b.par_map(2, move |j| i * 10 + j));
        assert_eq!(out, vec![vec![0, 1], vec![10, 11], vec![20, 21]]);
    }

    #[test]
    fn reentrant_run_batch_asserts_instead_of_deadlocking() {
        let pool = Arc::new(ThreadPool::new(2));
        let p = Arc::clone(&pool);
        // nested par_map on the same pool from inside a worker: the
        // reentrancy assert panics (contained), surfacing at the caller
        // instead of hanging forever
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || pool.par_map(1, move |_| p.par_map(2, |i| i)),
        ));
        assert!(res.is_err(), "reentrant use must fail loudly, not hang");
    }

    #[test]
    fn is_worker_distinguishes_threads() {
        let pool = Arc::new(ThreadPool::new(2));
        assert!(!pool.is_worker(), "caller thread is not a worker");
        let p = Arc::clone(&pool);
        let on_worker = pool.par_map(3, move |_| p.is_worker());
        assert_eq!(on_worker, vec![true; 3]);
        // workers of a *different* pool are not this pool's workers
        let other = ThreadPool::new(1);
        let p2 = Arc::clone(&pool);
        let cross = other.par_map(1, move |_| p2.is_worker());
        assert_eq!(cross, vec![false]);
    }

    #[test]
    fn reusable_across_batches() {
        let pool = ThreadPool::new(2);
        for round in 0..5 {
            let out = pool.par_map(4, |i| i + round);
            assert_eq!(out, (0..4).map(|i| i + round).collect::<Vec<_>>());
        }
    }
}
