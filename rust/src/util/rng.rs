//! PCG64 pseudo-random number generator (O'Neill 2014, PCG-XSL-RR 128/64).
//!
//! The environment vendors no `rand` crate, so experiments get their own
//! deterministic, seedable, stream-splittable generator. All randomness in
//! the repository (datasets, partitions, workloads, property tests) flows
//! through this type so every experiment is reproducible from a seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, xor-shift-low + random rotation
/// output. Period 2^128 per stream; `inc` selects the stream.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded constructor; `seq` selects an independent stream.
    pub fn new(seed: u64, seq: u64) -> Self {
        let inc = (((seq as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child stream (for per-machine / per-worker
    /// determinism irrespective of scheduling order).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_sub(n) % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; GP workloads are not RNG-bound).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seed(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seed(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(13);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seed(17);
        let s = rng.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }
}
