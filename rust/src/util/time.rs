//! Timing utilities: a stopwatch and simple duration statistics.

use std::time::{Duration, Instant};

/// A restartable stopwatch measuring wall-clock seconds.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds elapsed since construction / last reset.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_duration(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }

    /// Time a closure, returning (result, seconds).
    pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
        let sw = Stopwatch::new();
        let out = f();
        (out, sw.elapsed())
    }
}

/// A monotonic seconds-since-anchor clock for serving-loop age math.
///
/// [`crate::server::DynamicBatcher`] takes caller-supplied `now`
/// timestamps; feeding it wall-clock time makes batch expiry hostage
/// to NTP steps (a backward step stalls flushes, a forward step
/// prematurely flushes — both pinned in the batcher tests). The
/// network serving loop reads every timestamp from one `MonoClock`
/// instead: `Instant`-anchored, so readings only ever move forward
/// regardless of what the system wall clock does.
#[derive(Debug, Clone)]
pub struct MonoClock {
    anchor: Instant,
}

impl Default for MonoClock {
    fn default() -> Self {
        Self::new()
    }
}

impl MonoClock {
    /// Anchor the clock at the current instant (readings start near 0).
    pub fn new() -> MonoClock {
        MonoClock { anchor: Instant::now() }
    }

    /// Monotone non-decreasing seconds since the anchor.
    pub fn now_s(&self) -> f64 {
        self.anchor.elapsed().as_secs_f64()
    }
}

/// Summary statistics over a set of duration samples (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct DurationStats {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl DurationStats {
    /// Summarize `samples`. `n`/`mean`/`min`/`max` are exact;
    /// `p50`/`p95`/`p99` come from the shared telemetry histogram
    /// ([`crate::obsv::Histogram`] — the tree's one percentile
    /// implementation), so they match a sort-based oracle to within
    /// one bucket width ([`crate::obsv::RELATIVE_BUCKET_WIDTH`], ≈19%
    /// relative).
    pub fn from_samples(samples: &[f64]) -> Option<DurationStats> {
        if samples.is_empty() {
            return None;
        }
        let h = crate::obsv::Histogram::new(crate::obsv::Unit::Seconds);
        let mut sum = 0.0;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &v in samples {
            h.observe(v);
            sum += v;
            min = min.min(v);
            max = max.max(v);
        }
        Some(DurationStats {
            n: samples.len(),
            mean: sum / samples.len() as f64,
            min,
            max,
            p50: h.quantile(0.50),
            p95: h.quantile(0.95),
            p99: h.quantile(0.99),
        })
    }
}

/// Human-readable seconds (`1.23s`, `45.6ms`, `789us`).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::new();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn time_closure() {
        let (v, secs) = Stopwatch::time(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(secs >= 0.0);
    }

    #[test]
    fn stats_basic() {
        let s = DurationStats::from_samples(&[3.0, 1.0, 2.0]).unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        // p50 is histogram-interpolated: exact to one bucket width
        assert!(
            (s.p50 - 2.0).abs() <= 2.0 * crate::obsv::RELATIVE_BUCKET_WIDTH,
            "p50 {} vs 2.0",
            s.p50
        );
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_empty() {
        assert!(DurationStats::from_samples(&[]).is_none());
    }

    #[test]
    fn stats_percentiles_ordered() {
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = DurationStats::from_samples(&samples).unwrap();
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn mono_clock_is_monotone_and_nonnegative() {
        let c = MonoClock::new();
        let mut prev = c.now_s();
        assert!(prev >= 0.0);
        for _ in 0..100 {
            let t = c.now_s();
            assert!(t >= prev, "clock went backward: {t} < {prev}");
            prev = t;
        }
    }

    #[test]
    fn mono_clocks_have_independent_anchors() {
        let a = MonoClock::new();
        std::thread::sleep(Duration::from_millis(2));
        let b = MonoClock::new();
        // `a` was anchored earlier, so it has strictly more elapsed time
        assert!(a.now_s() > b.now_s());
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.005).ends_with("ms"));
        assert!(fmt_secs(2e-5).ends_with("us"));
    }
}
