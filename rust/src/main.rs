fn main() {
    std::process::exit(pgpr::cli::main());
}
