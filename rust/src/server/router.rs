//! Request routing: pick the serving machine whose local data is most
//! correlated with the query (nearest cluster center in kernel-scaled
//! input space) — the serving-time analogue of the paper's clustering
//! scheme (Remark 2 after Definition 5), which is what makes pPIC's
//! local term effective per request.

use crate::kernel::SeArd;
use crate::linalg::Mat;

/// Nearest-center router over M machine centroids.
#[derive(Debug, Clone)]
pub struct Router {
    /// M × d centroids (machine m's local-data mean)
    centers: Mat,
    /// 1/length-scale per input dimension (kernel-relevant metric)
    inv_ls: Vec<f64>,
}

impl Router {
    /// Build from each machine's local input block.
    pub fn from_blocks(hyp: &SeArd, blocks: &[&Mat]) -> Router {
        assert!(!blocks.is_empty());
        let d = blocks[0].cols;
        let mut centers = Mat::zeros(blocks.len(), d);
        for (m, blk) in blocks.iter().enumerate() {
            assert!(blk.rows > 0, "machine {m} has no data");
            for c in 0..d {
                let mean: f64 =
                    (0..blk.rows).map(|r| blk[(r, c)]).sum::<f64>()
                        / blk.rows as f64;
                centers[(m, c)] = mean;
            }
        }
        Router {
            centers,
            inv_ls: hyp.log_ls.iter().map(|l| (-l).exp()).collect(),
        }
    }

    pub fn machines(&self) -> usize {
        self.centers.rows
    }

    /// Machine for one query (nearest centroid in scaled space).
    pub fn route(&self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.centers.cols);
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for m in 0..self.centers.rows {
            let mut s = 0.0;
            for c in 0..x.len() {
                let diff = (x[c] - self.centers[(m, c)]) * self.inv_ls[c];
                s += diff * diff;
            }
            if s < best_d {
                best_d = s;
                best = m;
            }
        }
        best
    }

    /// Route a whole matrix of queries; returns per-row machine ids.
    pub fn route_all(&self, x: &Mat) -> Vec<usize> {
        (0..x.rows).map(|r| self.route(x.row(r))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blob_router() -> Router {
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 0.1);
        let a = Mat::from_vec(3, 2, vec![-5.0, 0.0, -5.1, 0.1, -4.9, -0.1]);
        let b = Mat::from_vec(3, 2, vec![5.0, 0.0, 5.1, 0.1, 4.9, -0.1]);
        Router::from_blocks(&hyp, &[&a, &b])
    }

    #[test]
    fn routes_to_nearest_blob() {
        let r = two_blob_router();
        assert_eq!(r.route(&[-4.0, 0.0]), 0);
        assert_eq!(r.route(&[4.0, 0.0]), 1);
        assert_eq!(r.machines(), 2);
    }

    #[test]
    fn route_all_matches_route() {
        let r = two_blob_router();
        let q = Mat::from_vec(3, 2, vec![-1.0, 0.0, 6.0, 1.0, -9.0, 2.0]);
        let routed = r.route_all(&q);
        assert_eq!(routed, vec![r.route(q.row(0)), r.route(q.row(1)),
                                r.route(q.row(2))]);
    }

    #[test]
    fn lengthscales_shape_the_metric() {
        // dimension 1 has a tiny length-scale => dominates distance
        let hyp = SeArd {
            log_ls: vec![0.0, (0.01f64).ln()],
            log_sf2: 0.0,
            log_sn2: -2.0,
        };
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Mat::from_vec(1, 2, vec![100.0, 0.3]);
        let r = Router::from_blocks(&hyp, &[&a, &b]);
        // near b in dim0, but the tiny dim-1 length-scale dominates:
        // dim-1 distance decides the route under the scaled metric
        assert_eq!(r.route(&[90.0, -1.2]), 0);
        assert_eq!(r.route(&[90.0, 0.3]), 1);
    }

    /// Tie-breaking: a query equidistant from several centroids routes
    /// to the lowest machine index (strict `<` keeps the first winner) —
    /// the stability the batcher relies on for replayable streams.
    #[test]
    fn route_ties_prefer_lowest_index() {
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let a = Mat::from_vec(1, 1, vec![-1.0]);
        let b = Mat::from_vec(1, 1, vec![1.0]);
        let c = Mat::from_vec(1, 1, vec![-1.0]); // duplicate of a
        let r = Router::from_blocks(&hyp, &[&a, &b, &c]);
        // 0.0 is exactly between machines 0 and 1; -1.0 ties 0 and 2
        assert_eq!(r.route(&[0.0]), 0);
        assert_eq!(r.route(&[-1.0]), 0);
        // determinism: repeated calls agree
        assert_eq!(r.route(&[0.0]), r.route(&[0.0]));
    }

    #[test]
    #[should_panic]
    fn empty_block_rejected() {
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let empty = Mat::zeros(0, 1);
        Router::from_blocks(&hyp, &[&empty]);
    }
}
