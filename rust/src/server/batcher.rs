//! Dynamic batching: group routed requests into per-machine batches,
//! flushing on size (the AOT `pred_block`) or age (max wait). Classic
//! serving trade-off: bigger batches amortize the per-call overhead of
//! the compiled graph; the wait bound caps tail latency.

/// One flushed batch for a machine.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub machine: usize,
    /// request ids in batch order
    pub ids: Vec<u64>,
    /// row-major query inputs (ids.len() × d)
    pub xs: Vec<f64>,
    /// arrival time (seconds) of the oldest request in the batch
    pub oldest_arrival: f64,
}

/// Size-or-age batcher with one open batch per machine.
///
/// Timestamps (`now` on [`DynamicBatcher::push`] /
/// [`DynamicBatcher::flush_expired`]) are caller-supplied seconds on
/// whatever clock the caller chooses, and the age math trusts them
/// verbatim. Trace-replay drivers exploit this (simulated arrival
/// times), but a live serving loop must NOT feed wall-clock time: an
/// NTP step backward stalls expiry and a step forward prematurely
/// flushes (both pinned in the tests below). The network loop
/// ([`crate::net`]) reads every timestamp from one
/// [`crate::util::time::MonoClock`] instead.
///
/// Executed batches can be handed back via [`DynamicBatcher::recycle`]:
/// their `ids`/`xs` buffers go on a free list that [`DynamicBatcher::push`]
/// drains before allocating, so a steady-state serve loop reuses the
/// same handful of buffers forever instead of reallocating two `Vec`s
/// per flush (the serve hot-loop churn fix).
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    max_wait_s: f64,
    d: usize,
    open: Vec<Option<Batch>>,
    /// Cleared (ids, xs) buffer pairs from recycled batches.
    free: Vec<(Vec<u64>, Vec<f64>)>,
}

impl DynamicBatcher {
    pub fn new(machines: usize, d: usize, max_batch: usize, max_wait_s: f64)
        -> DynamicBatcher
    {
        assert!(max_batch >= 1);
        DynamicBatcher {
            max_batch,
            max_wait_s,
            d,
            open: (0..machines).map(|_| None).collect(),
            free: Vec::new(),
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Add a routed request; returns a batch if the machine's batch
    /// became full.
    pub fn push(&mut self, machine: usize, id: u64, x: &[f64], now: f64)
        -> Option<Batch>
    {
        assert_eq!(x.len(), self.d, "query dim");
        let slot = &mut self.open[machine];
        let batch = match slot {
            Some(b) => b,
            None => {
                let (ids, xs) = self.free.pop().unwrap_or_else(|| {
                    (Vec::with_capacity(self.max_batch),
                     Vec::with_capacity(self.max_batch * self.d))
                });
                slot.insert(Batch { machine, ids, xs, oldest_arrival: now })
            }
        };
        batch.ids.push(id);
        batch.xs.extend_from_slice(x);
        if crate::obsv::enabled() {
            crate::obsv::gauge_add("serve.queue_depth", 1);
        }
        if batch.ids.len() >= self.max_batch {
            let full = slot.take();
            if let Some(b) = &full {
                Self::note_drained(b.ids.len());
            }
            full
        } else {
            None
        }
    }

    /// Flushed requests leave the queue: keep the
    /// `serve.queue_depth` gauge honest (it mirrors
    /// [`DynamicBatcher::pending`] whenever telemetry stays enabled
    /// for the batcher's whole lifetime).
    fn note_drained(rows: usize) {
        if crate::obsv::enabled() {
            crate::obsv::gauge_add("serve.queue_depth", -(rows as i64));
        }
    }

    /// Return an executed batch's buffers to the free list (cleared,
    /// capacity kept). The list is capped at one spare per machine —
    /// the most a flush wave can consume before the next recycle.
    pub fn recycle(&mut self, batch: Batch) {
        if self.free.len() >= self.open.len() {
            return;
        }
        let Batch { mut ids, mut xs, .. } = batch;
        ids.clear();
        xs.clear();
        self.free.push((ids, xs));
    }

    /// Flush batches whose oldest request has waited past the bound.
    pub fn flush_expired(&mut self, now: f64) -> Vec<Batch> {
        let mut out = Vec::new();
        for slot in self.open.iter_mut() {
            let expired = slot
                .as_ref()
                .is_some_and(|b| now - b.oldest_arrival >= self.max_wait_s);
            if expired {
                let b = slot.take().unwrap();
                Self::note_drained(b.ids.len());
                out.push(b);
            }
        }
        out
    }

    /// Flush everything (end of stream).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let out: Vec<Batch> =
            self.open.iter_mut().filter_map(Option::take).collect();
        for b in &out {
            Self::note_drained(b.ids.len());
        }
        out
    }

    /// Number of requests currently waiting.
    pub fn pending(&self) -> usize {
        self.open.iter().flatten().map(|b| b.ids.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_on_size() {
        let mut b = DynamicBatcher::new(2, 1, 3, 1.0);
        assert!(b.push(0, 1, &[0.1], 0.0).is_none());
        assert!(b.push(0, 2, &[0.2], 0.0).is_none());
        let full = b.push(0, 3, &[0.3], 0.0).unwrap();
        assert_eq!(full.ids, vec![1, 2, 3]);
        assert_eq!(full.xs, vec![0.1, 0.2, 0.3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_age() {
        let mut b = DynamicBatcher::new(1, 2, 10, 0.5);
        b.push(0, 1, &[1.0, 2.0], 0.0);
        assert!(b.flush_expired(0.4).is_empty());
        let out = b.flush_expired(0.6);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].oldest_arrival, 0.0);
        assert_eq!(b.pending(), 0);
    }

    /// The age bound is inclusive: a batch whose oldest request has
    /// waited *exactly* `max_wait_s` flushes (`>=` in `flush_expired`),
    /// and one epsilon earlier does not.
    #[test]
    fn flush_boundary_at_exactly_max_wait() {
        let mut b = DynamicBatcher::new(1, 1, 10, 0.5);
        b.push(0, 1, &[0.0], 1.0);
        assert!(b.flush_expired(1.5 - 1e-9).is_empty(), "just under");
        let out = b.flush_expired(1.5);
        assert_eq!(out.len(), 1, "exactly at the bound flushes");
        assert_eq!(out[0].ids, vec![1]);
        // flushing consumed the batch: the same instant again is empty
        assert!(b.flush_expired(1.5).is_empty());
    }

    /// Filling to max_batch returns the batch on the exact push that
    /// completes it (never one early or late), and the slot restarts
    /// clean with a fresh oldest_arrival.
    #[test]
    fn push_fills_to_exactly_max_batch() {
        let mut b = DynamicBatcher::new(1, 1, 3, 100.0);
        assert!(b.push(0, 0, &[0.0], 0.0).is_none());
        assert!(b.push(0, 1, &[0.1], 0.5).is_none());
        assert_eq!(b.pending(), 2);
        let full = b.push(0, 2, &[0.2], 1.0).expect("third push completes");
        assert_eq!(full.ids, vec![0, 1, 2]);
        assert_eq!(full.oldest_arrival, 0.0);
        assert_eq!(b.pending(), 0);
        // next batch starts fresh: its age is measured from its own
        // first push, not the previous batch's
        assert!(b.push(0, 3, &[0.3], 9.0).is_none());
        let out = b.flush_all();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].oldest_arrival, 9.0);
        assert_eq!(out[0].ids, vec![3]);
    }

    /// max_batch == 1 degenerates to flush-on-every-push.
    #[test]
    fn unit_batch_flushes_every_push() {
        let mut b = DynamicBatcher::new(2, 1, 1, 100.0);
        for i in 0..4u64 {
            let out = b.push((i % 2) as usize, i, &[0.0], i as f64);
            assert_eq!(out.unwrap().ids, vec![i]);
        }
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn per_machine_isolation() {
        let mut b = DynamicBatcher::new(3, 1, 2, 1.0);
        b.push(0, 1, &[0.0], 0.0);
        b.push(2, 2, &[0.0], 0.0);
        assert_eq!(b.pending(), 2);
        let full = b.push(0, 3, &[0.0], 0.1).unwrap();
        assert_eq!(full.machine, 0);
        assert_eq!(b.pending(), 1);
        let rest = b.flush_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].machine, 2);
    }

    /// Recycled buffers are reused by later pushes with identical
    /// observable behavior: same ids, same xs, fresh oldest_arrival,
    /// and the reused Vecs keep their capacity (no regrowth for
    /// batches up to max_batch).
    #[test]
    fn recycle_reuses_buffers_without_behavior_change() {
        let mut b = DynamicBatcher::new(2, 1, 2, 1.0);
        b.push(0, 1, &[0.1], 0.0);
        let full = b.push(0, 2, &[0.2], 0.1).unwrap();
        let cap_ids = full.ids.capacity();
        let cap_xs = full.xs.capacity();
        b.recycle(full);
        // the next batch on ANY machine draws from the free list
        b.push(1, 3, &[0.3], 5.0);
        let out = b.flush_all();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].ids, vec![3]);
        assert_eq!(out[0].xs, vec![0.3]);
        assert_eq!(out[0].oldest_arrival, 5.0);
        assert!(out[0].ids.capacity() >= cap_ids.min(2));
        assert!(out[0].xs.capacity() >= cap_xs.min(2));
    }

    /// The free list is bounded by the machine count: recycling more
    /// batches than machines drops the excess.
    #[test]
    fn recycle_free_list_bounded() {
        let mut b = DynamicBatcher::new(2, 1, 1, 1.0);
        for i in 0..5u64 {
            let full = b.push((i % 2) as usize, i, &[0.0], 0.0).unwrap();
            b.recycle(full);
        }
        assert!(b.free.len() <= 2);
    }

    #[test]
    fn oldest_arrival_tracked() {
        let mut b = DynamicBatcher::new(1, 1, 5, 10.0);
        b.push(0, 1, &[0.0], 3.0);
        b.push(0, 2, &[0.0], 4.0);
        let out = b.flush_all();
        assert_eq!(out[0].oldest_arrival, 3.0);
    }

    #[test]
    #[should_panic]
    fn wrong_dim_rejected() {
        let mut b = DynamicBatcher::new(1, 2, 2, 1.0);
        b.push(0, 1, &[0.0], 0.0);
    }

    /// Pin the wall-clock hazard that motivates the monotonic path: the
    /// batcher trusts caller timestamps verbatim, so a clock stepped
    /// BACKWARD (NTP correction) stalls expiry — the batch sits past
    /// its real age bound until the clock re-passes `oldest + max_wait`.
    /// This is the documented caller contract, not a batcher bug; the
    /// live network loop avoids it by timestamping from
    /// [`crate::util::time::MonoClock`].
    #[test]
    fn wall_clock_step_backward_stalls_expiry() {
        let mut b = DynamicBatcher::new(1, 1, 10, 0.5);
        b.push(0, 1, &[0.0], 100.0);
        // clock steps back 10s: even though >0.5s of real time may have
        // passed, the age math sees a negative age and never flushes
        assert!(b.flush_expired(90.0).is_empty(), "stalled by back-step");
        assert!(b.flush_expired(100.4).is_empty(), "still under bound");
        assert_eq!(b.flush_expired(100.5).len(), 1,
                   "flushes only once the clock re-passes the bound");
    }

    /// The mirror hazard: a clock stepped FORWARD prematurely flushes a
    /// batch that has waited almost no real time.
    #[test]
    fn wall_clock_step_forward_prematurely_flushes() {
        let mut b = DynamicBatcher::new(1, 1, 10, 0.5);
        b.push(0, 1, &[0.0], 100.0);
        // an NTP step jumps the wall clock +1h: the age math reads
        // 3600s >= 0.5s and flushes immediately
        let out = b.flush_expired(3700.0);
        assert_eq!(out.len(), 1, "premature flush on forward step");
    }

    /// The monotonic path: driving the same batcher from a
    /// [`crate::util::time::MonoClock`] gives non-decreasing timestamps
    /// by construction, so neither hazard above can occur — a batch
    /// never flushes before its real age reaches the bound.
    #[test]
    fn mono_clock_drives_age_math_safely() {
        let clock = crate::util::time::MonoClock::new();
        let mut b = DynamicBatcher::new(1, 1, 10, 0.05);
        let t0 = clock.now_s();
        b.push(0, 1, &[0.0], t0);
        // immediately after push, the real age is ~0 — no flush
        assert!(b.flush_expired(clock.now_s()).is_empty());
        // after sleeping past the bound, it must flush
        std::thread::sleep(std::time::Duration::from_millis(60));
        let out = b.flush_expired(clock.now_s());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].oldest_arrival, t0);
    }

    fn queue_depth(reg: &crate::obsv::Registry) -> i64 {
        reg.gauge_get("serve.queue_depth")
    }

    /// The `serve.queue_depth` gauge mirrors [`DynamicBatcher::pending`]
    /// through every drain path: it rises on push, falls by the batch
    /// size on a size flush, falls on expiry, and returns to zero after
    /// the end-of-stream drain — including across recycle() reuse.
    #[test]
    fn queue_depth_gauge_tracks_pending() {
        use std::sync::Arc;
        let reg = Arc::new(crate::obsv::Registry::new());
        let _g = reg.install();
        let mut b = DynamicBatcher::new(2, 1, 3, 0.5);

        b.push(0, 0, &[0.0], 0.0);
        b.push(1, 1, &[0.0], 0.0);
        assert_eq!(queue_depth(&reg), 2);
        assert_eq!(queue_depth(&reg), b.pending() as i64);

        // size flush drains machine 0's three requests at once
        b.push(0, 2, &[0.0], 0.1);
        let full = b.push(0, 3, &[0.0], 0.1).expect("size flush");
        assert_eq!(full.ids.len(), 3);
        assert_eq!(queue_depth(&reg), 1);
        assert_eq!(queue_depth(&reg), b.pending() as i64);

        // recycle must not touch the gauge (the batch already drained)
        b.recycle(full);
        assert_eq!(queue_depth(&reg), 1);

        // expiry drains machine 1
        let expired = b.flush_expired(1.0);
        assert_eq!(expired.len(), 1);
        assert_eq!(queue_depth(&reg), 0);

        // end-of-stream drain from a refilled (recycled-buffer) state
        b.push(0, 4, &[0.0], 2.0);
        b.push(1, 5, &[0.0], 2.0);
        assert_eq!(queue_depth(&reg), 2);
        let rest = b.flush_all();
        assert_eq!(rest.len(), 2);
        assert_eq!(queue_depth(&reg), 0);
        assert_eq!(b.pending(), 0);
    }

    /// max_batch == 1 never holds a request: every push flushes
    /// immediately, so the gauge reads zero at every observation point.
    #[test]
    fn queue_depth_gauge_honest_at_unit_batch() {
        use std::sync::Arc;
        let reg = Arc::new(crate::obsv::Registry::new());
        let _g = reg.install();
        let mut b = DynamicBatcher::new(2, 1, 1, 100.0);
        for i in 0..4u64 {
            let out = b.push((i % 2) as usize, i, &[0.0], i as f64);
            assert!(out.is_some());
            assert_eq!(queue_depth(&reg), 0, "push {i}");
        }
    }
}
