//! Real-time prediction serving — the paper's motivating use case
//! ("real-time predictions necessary in many time-critical
//! applications"): a request router, a dynamic batcher, and a serving
//! loop over a fitted parallel-GP state with the PJRT artifacts on the
//! hot path (vLLM-router-shaped, scaled to this problem).
//!
//! Flow: requests arrive with timestamps → the [`batcher::DynamicBatcher`]
//! groups them per machine (routed by [`router::Router`] to the machine
//! whose data is nearest, pPIC-style) → batches are padded to the AOT
//! `pred_block` shape, executed on a [`crate::runtime::Backend`], and
//! per-request latencies recorded.
//!
//! Per-machine batches are independent given the fitted summaries
//! (that's Theorem 2 at serving time: each machine's block prediction is
//! a pure function of the shared global summary and its own local
//! block), so batches that become ready at the same stream event can
//! execute concurrently — pass a thread-backed
//! [`crate::cluster::ParallelExecutor`] to
//! [`service::ServedModel::serve_with`] (CLI: `pgpr serve
//! --parallel-threads N`). Predicted means and variances are identical
//! to serial execution; reported latencies are not, since each batch's
//! measured compute time — which sets its requests' completion — now
//! reflects concurrent execution (including any core contention).
//!
//! Native deployments serve through
//! [`service::ServedModel::serve_fast`]: fit-staged predictive
//! operators ([`crate::gp::predictor`]) replace the per-batch
//! triangular solves and support/global re-factorizations with one
//! feature GEMM + one GEMV + one fused quadratic-form pass, with
//! per-machine scratch reuse and batcher buffer recycling so the
//! steady-state loop allocates nothing per request beyond the
//! responses (see `BENCH_serve.json` for the measured old-vs-fast
//! per-batch latency sweep).
//!
//! Serving state is durable: [`service::ServedModel::save`] /
//! [`service::ServedModel::load`] checkpoint the fitted summaries
//! through [`crate::store`] (operators are re-staged on load, so a
//! cold-started node serves bitwise what the original served), and
//! [`service::ServedModel::swap_in`] atomically replaces the live
//! model — the hot-swap primitive behind `pgpr node`'s refit/reload
//! paths.

pub mod batcher;
pub mod router;
pub mod service;

pub use batcher::{Batch, DynamicBatcher};
pub use router::Router;
pub use service::{PredictRequest, PredictResponse, ServeReport,
                  ServeScratch, ServedModel};
