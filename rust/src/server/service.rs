//! The serving loop: a fitted parallel-GP state + router + batcher +
//! backend, reporting per-request latency and throughput.

use std::sync::Mutex;

use super::batcher::{Batch, DynamicBatcher};
use super::router::Router;
use crate::api::ApiError;
use crate::cluster::{MachinesLost, ParallelExecutor};
use crate::gp::predictor::{ppic_operators, OpScratch, OpScratchF32,
                           PredictOperator, PredictOperatorF32};
use crate::gp::summaries::{chol_global, try_chol_global_ctx, GlobalSummary,
                           LocalSummary, SupportContext};
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};
use crate::runtime::Backend;
use crate::store::{Checkpoint, ServedCheckpoint, StoreError};
use crate::util::time::{fmt_secs, DurationStats};
use crate::util::Stopwatch;

/// One prediction request.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub id: u64,
    pub x: Vec<f64>,
    /// arrival time offset (seconds from stream start)
    pub arrival_s: f64,
}

/// One prediction response.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    pub id: u64,
    pub mean: f64,
    pub var: f64,
    /// completion − arrival (seconds)
    pub latency_s: f64,
}

/// Serving metrics for one stream run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub responses: Vec<PredictResponse>,
    pub latency: DurationStats,
    /// requests per second of wall time
    pub throughput: f64,
    pub batches: usize,
    pub mean_batch_size: f64,
    pub wall_s: f64,
}

impl ServeReport {
    pub fn summary(&self) -> String {
        format!(
            "{} req in {} | {:.0} req/s | batch x̄ {:.1} | p50 {} p95 {} p99 {}",
            self.responses.len(),
            fmt_secs(self.wall_s),
            self.throughput,
            self.mean_batch_size,
            fmt_secs(self.latency.p50),
            fmt_secs(self.latency.p95),
            fmt_secs(self.latency.p99),
        )
    }
}

/// Per-machine reusable buffers for [`ServedModel::predict_batch_fast`]:
/// the padded input, the operator scratch, and the output vectors. A
/// steady-state serve loop allocates nothing per request beyond the
/// [`PredictResponse`] entries themselves.
#[derive(Debug, Clone, Default)]
pub struct ServeScratch {
    op: OpScratch,
    op_f32: OpScratchF32,
    padded: Vec<f64>,
    mean: Vec<f64>,
    var: Vec<f64>,
}

impl ServeScratch {
    #[must_use]
    pub fn new() -> ServeScratch {
        ServeScratch::default()
    }
}

/// A fitted pPIC model packaged for serving: support context, global
/// summary, each machine's local block + cached summary, and the
/// fit-staged per-machine predictive operators behind
/// [`ServedModel::predict_batch_fast`] / [`ServedModel::serve_fast`].
pub struct ServedModel {
    pub hyp: SeArd,
    pub xs: Mat,
    pub y_mean: f64,
    pub global: GlobalSummary,
    /// per machine: (X_m, centered y_m, local summary)
    pub blocks: Vec<(Mat, Vec<f64>, LocalSummary)>,
    pub router: Router,
    /// Fit-staged Definition-5 operators, one per machine (weight
    /// vector + fused variance operator over `[k(u,S); k(u,X_m)]`
    /// features). Rebuilt by [`ServedModel::refit`].
    pub ops: Vec<PredictOperator>,
    /// Mixed-precision (f32-storage / f64-accumulate) siblings of
    /// `ops`, staged only when opted in via
    /// [`ServedModel::with_mixed_precision`] (or
    /// [`crate::api::GpBuilder::mixed_precision`]). When present,
    /// [`ServedModel::serve_fast`] routes every batch through them;
    /// restaged by refit and machine loss so the mode survives
    /// redeployment events.
    pub ops_f32: Option<Vec<PredictOperatorF32>>,
}

/// Stage the per-machine serve operators (fit/refit shared tail).
fn stage_ops(
    hyp: &SeArd,
    ctx: &SupportContext,
    global: &GlobalSummary,
    blocks: &[(Mat, Vec<f64>, LocalSummary)],
    y_mean: f64,
) -> Vec<PredictOperator> {
    let l_g = chol_global(global);
    ppic_operators(&LinalgCtx::serial(), hyp, ctx, global, &l_g, blocks,
                   y_mean)
}

impl ServedModel {
    /// Fit from partitioned data through `backend` (Steps 1–3 of pPIC;
    /// predictions are then served per request). Prefer building through
    /// [`crate::api::GpBuilder::serve`], which also resolves support
    /// selection and partitioning.
    ///
    /// Rejects empty data ([`ApiError::EmptyData`] — previously an empty
    /// `y` silently produced a zero-mean model) and malformed partitions
    /// ([`ApiError::EmptyPartition`] would break routing;
    /// out-of-range/duplicate/missing rows are
    /// [`ApiError::InvalidPartition`] instead of a deep `select_rows`
    /// panic).
    pub fn fit(
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
        backend: &dyn Backend,
    ) -> Result<ServedModel, ApiError> {
        if y.is_empty() || xd.rows == 0 {
            return Err(ApiError::EmptyData);
        }
        if xd.rows != y.len() {
            return Err(ApiError::ShapeMismatch {
                what: "y length vs xd rows",
                expected: xd.rows,
                got: y.len(),
            });
        }
        crate::api::spec::validate_partition(d_blocks, xd.rows,
                                             d_blocks.len())?;
        let y_mean = y.iter().sum::<f64>() / y.len() as f64;
        let blocks: Vec<(Mat, Vec<f64>, LocalSummary)> = d_blocks
            .iter()
            .map(|blk| {
                let xm = xd.select_rows(blk);
                let ym: Vec<f64> = blk.iter().map(|&i| y[i] - y_mean).collect();
                let loc = backend.local_summary(hyp, &xm, &ym, xs);
                (xm, ym, loc)
            })
            .collect();
        let ctx = SupportContext::new(hyp, xs);
        let refs: Vec<&LocalSummary> = blocks.iter().map(|(_, _, l)| l).collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let xms: Vec<&Mat> = blocks.iter().map(|(x, _, _)| x).collect();
        let router = Router::from_blocks(hyp, &xms);
        let ops = stage_ops(hyp, &ctx, &global, &blocks, y_mean);
        Ok(ServedModel {
            hyp: hyp.clone(),
            xs: xs.clone(),
            y_mean,
            global,
            blocks,
            router,
            ops,
            ops_f32: None,
        })
    }

    pub fn machines(&self) -> usize {
        self.blocks.len()
    }

    /// Opt into the mixed-precision serve mode: demote the staged f64
    /// operators to their f32-storage / f64-accumulate siblings
    /// ([`PredictOperator::demote`]) and route
    /// [`ServedModel::serve_fast`] through them. The f64 operators
    /// stay staged (they remain the accuracy oracle and the
    /// [`ServedModel::predict_batch_fast`] path); predictions through
    /// the f32 path agree with them within
    /// [`crate::gp::predictor::F32_SERVE_REL_BUDGET`] (tested).
    #[must_use]
    pub fn with_mixed_precision(mut self) -> ServedModel {
        self.ops_f32 =
            Some(self.ops.iter().map(PredictOperator::demote).collect());
        self
    }

    /// True when the mixed-precision serve path is staged.
    #[must_use]
    pub fn mixed_precision(&self) -> bool {
        self.ops_f32.is_some()
    }

    /// Rebuild every summary under new hyperparameters (e.g. from
    /// `pgpr train` / [`crate::train::dist::train_pitc`]) while keeping
    /// the data partition and routing topology: the refit hook that lets
    /// a live serving deployment consume trained hypers without
    /// re-sharding. O(M·(|D|/M)³ + |S|³) — the same cost as the original
    /// fit's summary phase, nothing else is touched.
    pub fn refit(&self, hyp: &SeArd, backend: &dyn Backend) -> ServedModel {
        let blocks: Vec<(Mat, Vec<f64>, LocalSummary)> = self
            .blocks
            .iter()
            .map(|(xm, ym, _)| {
                let loc = backend.local_summary(hyp, xm, ym, &self.xs);
                (xm.clone(), ym.clone(), loc)
            })
            .collect();
        let ctx = SupportContext::new(hyp, &self.xs);
        let refs: Vec<&LocalSummary> =
            blocks.iter().map(|(_, _, l)| l).collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let xms: Vec<&Mat> = blocks.iter().map(|(x, _, _)| x).collect();
        let router = Router::from_blocks(hyp, &xms);
        let ops = stage_ops(hyp, &ctx, &global, &blocks, self.y_mean);
        let ops_f32 = self
            .ops_f32
            .as_ref()
            .map(|_| ops.iter().map(PredictOperator::demote).collect());
        ServedModel {
            hyp: hyp.clone(),
            xs: self.xs.clone(),
            y_mean: self.y_mean,
            global,
            blocks,
            router,
            ops,
            ops_f32,
        }
    }

    /// Drop machine `m` from the serving deployment and rebalance its
    /// data rows round-robin across the survivors — the serve-side
    /// analogue of the cluster protocols' death rebalance. Every
    /// summary, the router and the staged operators are rebuilt over
    /// the new partition, so post-loss predictions are **bitwise**
    /// identical to a fresh fit on the merged partition (tested).
    ///
    /// Errors: out-of-range `m` is [`ApiError::InvalidSpec`]; losing
    /// the last machine is [`ApiError::MachinesLost`] (there is nobody
    /// left to absorb the block).
    pub fn lose_machine(
        &mut self,
        m: usize,
        backend: &dyn Backend,
    ) -> Result<(), ApiError> {
        if m >= self.blocks.len() {
            return Err(ApiError::invalid(format!(
                "lose_machine: machine {m} out of range (cluster has {})",
                self.blocks.len()
            )));
        }
        if self.blocks.len() == 1 {
            return Err(MachinesLost::at("serve", 1).into());
        }
        let (xm_dead, ym_dead, _) = self.blocks.remove(m);
        let survivors = self.blocks.len();
        let d = xm_dead.cols;
        let mut extra_x: Vec<Vec<f64>> = vec![Vec::new(); survivors];
        let mut extra_y: Vec<Vec<f64>> = vec![Vec::new(); survivors];
        for i in 0..xm_dead.rows {
            let a = i % survivors;
            extra_x[a].extend_from_slice(xm_dead.row(i));
            extra_y[a].push(ym_dead[i]);
        }
        for (a, (xm, ym, _)) in self.blocks.iter_mut().enumerate() {
            if extra_y[a].is_empty() {
                continue;
            }
            let mut data = std::mem::take(&mut xm.data);
            data.extend_from_slice(&extra_x[a]);
            *xm = Mat::from_vec(xm.rows + extra_y[a].len(), d, data);
            ym.extend_from_slice(&extra_y[a]);
        }
        let ctx = SupportContext::new(&self.hyp, &self.xs);
        for (xm, ym, loc) in self.blocks.iter_mut() {
            *loc = backend.local_summary(&self.hyp, xm, ym, &self.xs);
        }
        let refs: Vec<&LocalSummary> =
            self.blocks.iter().map(|(_, _, l)| l).collect();
        self.global = crate::gp::summaries::global_summary(&ctx, &refs);
        let xms: Vec<&Mat> = self.blocks.iter().map(|(x, _, _)| x).collect();
        self.router = Router::from_blocks(&self.hyp, &xms);
        self.ops = stage_ops(&self.hyp, &ctx, &self.global, &self.blocks,
                             self.y_mean);
        if self.ops_f32.is_some() {
            self.ops_f32 = Some(
                self.ops.iter().map(PredictOperator::demote).collect());
        }
        Ok(())
    }

    /// Snapshot the fitted serving state as a [`Checkpoint`]. The
    /// staged operators are *not* serialized — [`ServedModel::from_checkpoint`]
    /// re-stages them through the same pure constructors `fit` uses, so
    /// a restored model predicts bitwise what this one predicts
    /// (tested). Encoding is a pure function of the state: two
    /// snapshots of the same model are byte-identical.
    #[must_use]
    pub fn to_checkpoint(&self) -> Checkpoint {
        Checkpoint::Served(ServedCheckpoint {
            hyp: self.hyp.clone(),
            xs: self.xs.clone(),
            y_mean: self.y_mean,
            global: self.global.clone(),
            blocks: self.blocks.clone(),
            mixed_precision: self.mixed_precision(),
        })
    }

    /// Rebuild a serving model from a decoded [`ServedCheckpoint`]:
    /// validate structural coherence, rebuild the router, and re-stage
    /// the predictive operators (restoring the mixed-precision mode if
    /// it was staged at snapshot time). No refit — cold start costs one
    /// support factorization plus the operator staging. Crafted but
    /// CRC-valid images that are internally inconsistent (mismatched
    /// block dims, non-SPD support matrix) come back as typed
    /// [`ApiError::Store`] errors, never a panic.
    pub fn from_checkpoint(ck: ServedCheckpoint) -> Result<ServedModel, ApiError> {
        let corrupt = |section: &'static str, reason: String| {
            ApiError::Store(StoreError::Corrupt { section, reason })
        };
        let (s, d) = (ck.xs.rows, ck.xs.cols);
        if ck.blocks.is_empty() {
            return Err(corrupt("blocks", "no machine blocks".into()));
        }
        for (m, (xm, ym, loc)) in ck.blocks.iter().enumerate() {
            if xm.cols != d {
                return Err(corrupt("blocks", format!(
                    "machine {m}: input dim {} != support dim {d}", xm.cols)));
            }
            if xm.rows != ym.len() || xm.rows == 0 {
                return Err(corrupt("blocks", format!(
                    "machine {m}: {} inputs vs {} targets", xm.rows, ym.len())));
            }
            if loc.y_dot.len() != s || loc.s_dot.rows != s || loc.s_dot.cols != s
            {
                return Err(corrupt("blocks", format!(
                    "machine {m}: local summary dim != support size {s}")));
            }
            if loc.l_m.rows != xm.rows || loc.l_m.cols != xm.rows {
                return Err(corrupt("blocks", format!(
                    "machine {m}: block factor is {}x{} for {} rows",
                    loc.l_m.rows, loc.l_m.cols, xm.rows)));
            }
        }
        let lctx = LinalgCtx::serial();
        let ctx = SupportContext::try_new_ctx(&lctx, &ck.hyp, &ck.xs)
            .map_err(|e| corrupt("support", format!("Σ_SS not SPD: {e}")))?;
        let l_g = try_chol_global_ctx(&lctx, &ck.global)
            .map_err(|e| corrupt("moments", format!("Σ̈_SS not SPD: {e}")))?;
        let ops = ppic_operators(&lctx, &ck.hyp, &ctx, &ck.global, &l_g,
                                 &ck.blocks, ck.y_mean);
        let xms: Vec<&Mat> = ck.blocks.iter().map(|(x, _, _)| x).collect();
        let router = Router::from_blocks(&ck.hyp, &xms);
        let mixed = ck.mixed_precision;
        let model = ServedModel {
            hyp: ck.hyp,
            xs: ck.xs,
            y_mean: ck.y_mean,
            global: ck.global,
            blocks: ck.blocks,
            router,
            ops,
            ops_f32: None,
        };
        Ok(if mixed { model.with_mixed_precision() } else { model })
    }

    /// Atomically persist the serving state to `path`
    /// ([`Checkpoint::write_file`]: temp file + fsync + rename).
    /// Returns the byte count written.
    pub fn save(&self, path: &str) -> Result<u64, ApiError> {
        Ok(self.to_checkpoint().write_file(path)?)
    }

    /// Restore a serving model from a checkpoint file written by
    /// [`ServedModel::save`]. A checkpoint of any other model family is
    /// a typed [`StoreError::MethodMismatch`], not a mis-served model.
    pub fn load(path: &str) -> Result<ServedModel, ApiError> {
        match Checkpoint::read_file(path)? {
            Checkpoint::Served(s) => ServedModel::from_checkpoint(s),
            other => Err(ApiError::Store(StoreError::MethodMismatch {
                expected: "served",
                found: other.method_name(),
            })),
        }
    }

    /// Atomically replace this serving state with `next`, returning the
    /// retired model. The swap is a pointer-sized move under `&mut
    /// self` — any request already dispatched against the old model
    /// finishes on it (the caller holds it via the return value or a
    /// prior borrow), and every request dispatched after this call sees
    /// only `next`; there is no half-swapped state a request can
    /// observe (pinned in `tests/integration_store.rs`). Exported as
    /// `serve.swap.count`.
    pub fn swap_in(&mut self, next: ServedModel) -> ServedModel {
        let _span = crate::obsv::span("serve.swap");
        crate::obsv::counter_add("serve.swap.count", 1);
        std::mem::replace(self, next)
    }

    /// Order-sensitive digest of the staged operator state — two models
    /// digest equal iff their served predictions are bitwise-identical
    /// on every input. Cheap enough for `/healthz`.
    #[must_use]
    pub fn state_digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for op in &self.ops {
            h ^= op.state_digest();
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Predict one padded batch on machine `m` (pPIC block prediction).
    /// `xs_batch` is row-major `rows × d`; `pad_to` pads by repeating the
    /// first row up to the AOT shape (extra outputs are discarded) —
    /// safe because predictions are per-row independent given summaries.
    pub fn predict_batch(
        &self,
        backend: &dyn Backend,
        m: usize,
        xs_batch: &[f64],
        rows: usize,
        pad_to: usize,
    ) -> (Vec<f64>, Vec<f64>) {
        let d = self.xs.cols;
        assert_eq!(xs_batch.len(), rows * d);
        assert!(rows >= 1 && rows <= pad_to);
        let mut data = Vec::with_capacity(pad_to * d);
        data.extend_from_slice(xs_batch);
        for _ in rows..pad_to {
            data.extend_from_slice(&xs_batch[..d]);
        }
        let xu = Mat::from_vec(pad_to, d, data);
        let (xm, ym, loc) = &self.blocks[m];
        let mut p = backend.ppic_predict(&self.hyp, &xu, &self.xs, xm, ym,
                                         loc, &self.global);
        p.shift_mean(self.y_mean);
        p.mean.truncate(rows);
        p.var.truncate(rows);
        (p.mean, p.var)
    }

    /// Fast-path batch prediction on machine `m` through the
    /// fit-staged operator: one feature GEMM + one GEMV + one fused
    /// quadratic-form pass, no factorizations, no solves, and no
    /// allocation once `scratch` is warm. Same padding contract as
    /// [`ServedModel::predict_batch`] (repeat the first row to
    /// `pad_to`; per-row outputs are batch-independent, so the
    /// retained rows are **bitwise-identical** to an unpadded call —
    /// tested). Returns slices into `scratch` valid until its next
    /// use. Agrees with the seed solve-based
    /// [`ServedModel::predict_batch`] ≤1e-12 (tested).
    pub fn predict_batch_fast<'s>(
        &self,
        m: usize,
        xs_batch: &[f64],
        rows: usize,
        pad_to: usize,
        lctx: &LinalgCtx,
        scratch: &'s mut ServeScratch,
    ) -> (&'s [f64], &'s [f64]) {
        let d = self.xs.cols;
        assert_eq!(xs_batch.len(), rows * d);
        assert!(rows >= 1 && rows <= pad_to);
        scratch.padded.clear();
        scratch.padded.extend_from_slice(xs_batch);
        for _ in rows..pad_to {
            scratch.padded.extend_from_slice(&xs_batch[..d]);
        }
        self.ops[m].predict_into(lctx, &scratch.padded, pad_to,
                                 &mut scratch.op, &mut scratch.mean,
                                 &mut scratch.var);
        (&scratch.mean[..rows], &scratch.var[..rows])
    }

    /// Mixed-precision sibling of [`ServedModel::predict_batch_fast`]:
    /// same contract (padding transparency, scratch reuse, slices into
    /// `scratch`), served through the staged f32-storage operators.
    /// Agrees with the f64 fast path within
    /// [`crate::gp::predictor::F32_SERVE_REL_BUDGET`] (tested).
    ///
    /// Panics if the mixed-precision mode was never staged — call
    /// [`ServedModel::with_mixed_precision`] (or build with
    /// [`crate::api::GpBuilder::mixed_precision`]) first.
    pub fn predict_batch_fast_f32<'s>(
        &self,
        m: usize,
        xs_batch: &[f64],
        rows: usize,
        pad_to: usize,
        lctx: &LinalgCtx,
        scratch: &'s mut ServeScratch,
    ) -> (&'s [f64], &'s [f64]) {
        let ops = self.ops_f32.as_ref().expect(
            "mixed-precision serve path not staged: call \
             with_mixed_precision() first",
        );
        let d = self.xs.cols;
        assert_eq!(xs_batch.len(), rows * d);
        assert!(rows >= 1 && rows <= pad_to);
        scratch.padded.clear();
        scratch.padded.extend_from_slice(xs_batch);
        for _ in rows..pad_to {
            scratch.padded.extend_from_slice(&xs_batch[..d]);
        }
        ops[m].predict_into(lctx, &scratch.padded, pad_to,
                            &mut scratch.op_f32, &mut scratch.mean,
                            &mut scratch.var);
        (&scratch.mean[..rows], &scratch.var[..rows])
    }

    /// Serve a time-stamped request stream through the fit-staged
    /// operators (the fast path of [`ServedModel::serve_with`]; native
    /// math only — a PJRT deployment keeps using the backend-driven
    /// `serve_with`). Identical trace-replay methodology and identical
    /// batching decisions; per-machine scratch buffers and batcher
    /// buffer recycling make the steady-state loop allocation-free
    /// beyond the response vector. Predicted means/variances agree
    /// with [`ServedModel::serve_with`] ≤1e-12 (tested). When the
    /// model was staged with [`ServedModel::with_mixed_precision`],
    /// batches run through the f32-storage operators instead, within
    /// [`crate::gp::predictor::F32_SERVE_REL_BUDGET`] of the f64 path
    /// (tested).
    pub fn serve_fast(
        &self,
        requests: &[PredictRequest],
        batcher: &mut DynamicBatcher,
        exec: &ParallelExecutor,
    ) -> ServeReport {
        let pad_to = batcher.max_batch();
        let lctx = exec.linalg_ctx();
        // One scratch per machine: batches ready at the same stream
        // event target distinct machines, so the per-batch lock below
        // is uncontended; under a thread-backed exec the nested linalg
        // ctx degrades to serial automatically.
        let scratches: Vec<Mutex<ServeScratch>> =
            (0..self.machines()).map(|_| Mutex::new(ServeScratch::new()))
                .collect();
        let execute = |ready: &[Batch], flush_time: f64,
                       responses: &mut Vec<PredictResponse>| {
            // results are read back out of the per-machine scratches
            // below, which is only sound while one event never carries
            // two batches for the same machine (the batcher's
            // one-open-batch-per-machine invariant)
            debug_assert!(
                (1..ready.len()).all(|k| {
                    ready[..k].iter().all(|b| b.machine != ready[k].machine)
                }),
                "serve_fast: duplicate machine in one flush wave"
            );
            let outs = exec.run_timed(ready.len(), |k| {
                let b = &ready[k];
                let mut s = scratches[b.machine].lock().unwrap();
                if self.ops_f32.is_some() {
                    self.predict_batch_fast_f32(b.machine, &b.xs,
                                                b.ids.len(), pad_to,
                                                &lctx, &mut s);
                } else {
                    self.predict_batch_fast(b.machine, &b.xs, b.ids.len(),
                                            pad_to, &lctx, &mut s);
                }
            });
            for (batch, ((), secs)) in ready.iter().zip(outs) {
                let done = flush_time + secs;
                let s = scratches[batch.machine].lock().unwrap();
                for (k, &id) in batch.ids.iter().enumerate() {
                    let arrival = requests[id as usize].arrival_s;
                    responses.push(PredictResponse {
                        id,
                        mean: s.mean[k],
                        var: s.var[k],
                        latency_s: done - arrival,
                    });
                }
            }
        };
        run_serve_loop(&self.router, requests, batcher, execute)
    }

    /// Serve a time-stamped request stream to completion with serial
    /// batch execution (see [`ServedModel::serve_with`]).
    pub fn serve(
        &self,
        backend: &dyn Backend,
        requests: &[PredictRequest],
        batcher: &mut DynamicBatcher,
    ) -> ServeReport {
        self.serve_with(backend, requests, batcher,
                        &ParallelExecutor::serial())
    }

    /// Serve a time-stamped request stream to completion.
    ///
    /// Arrival times are honored logically (batching decisions use them)
    /// while execution runs as fast as the host allows; latency of a
    /// request = (virtual arrival-aligned completion) − arrival, where
    /// completion = max(arrival of newest batch member, flush time) +
    /// measured batch compute. This is the standard trace-replay
    /// methodology for single-host serving evaluation.
    ///
    /// Batches that become ready at the same stream event (e.g. several
    /// machines' batches expiring on one arrival) execute concurrently
    /// on `exec` — per-machine batches are independent given the fitted
    /// summaries, so predicted means and variances are identical to
    /// serial execution. Reported latencies differ: each batch's own
    /// measured compute time sets its completion, and under concurrency
    /// that measurement includes core contention.
    pub fn serve_with(
        &self,
        backend: &dyn Backend,
        requests: &[PredictRequest],
        batcher: &mut DynamicBatcher,
        exec: &ParallelExecutor,
    ) -> ServeReport {
        let pad_to = batcher.max_batch();
        // Execute every ready batch (concurrently when exec is
        // thread-backed); each batch's own measured compute time sets its
        // requests' completion, exactly as in the serial path.
        let execute = |ready: &[Batch], flush_time: f64,
                       responses: &mut Vec<PredictResponse>| {
            let outs = exec.run_timed(ready.len(), |k| {
                let b = &ready[k];
                self.predict_batch(backend, b.machine, &b.xs, b.ids.len(),
                                   pad_to)
            });
            for (batch, ((mean, var), secs)) in ready.iter().zip(outs) {
                let done = flush_time + secs;
                for (k, &id) in batch.ids.iter().enumerate() {
                    let arrival = requests[id as usize].arrival_s;
                    responses.push(PredictResponse {
                        id,
                        mean: mean[k],
                        var: var[k],
                        latency_s: done - arrival,
                    });
                }
            }
        };
        run_serve_loop(&self.router, requests, batcher, execute)
    }
}

/// The trace-replay event loop shared by [`ServedModel::serve_with`]
/// and [`ServedModel::serve_fast`]: one owner for the batching
/// decisions (expiry flush at the arrival that notices it, size flush
/// on the completing push, end-of-stream drain), the batch-buffer
/// recycling, the latency bookkeeping and the report assembly — so the
/// two execution paths cannot drift. `execute` runs one stream event's
/// ready batches (never empty) and appends their responses.
fn run_serve_loop(
    router: &Router,
    requests: &[PredictRequest],
    batcher: &mut DynamicBatcher,
    execute: impl Fn(&[Batch], f64, &mut Vec<PredictResponse>),
) -> ServeReport {
    let _obsv_span = crate::obsv::span("serve.stream")
        .with_u64("requests", requests.len() as u64);
    let mut responses: Vec<PredictResponse> =
        Vec::with_capacity(requests.len());
    let mut batches = 0usize;
    let mut batch_rows = 0usize;
    let wall = Stopwatch::new();

    let mut handle = |ready: Vec<Batch>, flush_time: f64,
                      batcher: &mut DynamicBatcher,
                      responses: &mut Vec<PredictResponse>| {
        if ready.is_empty() {
            return;
        }
        batches += ready.len();
        batch_rows += ready.iter().map(|b| b.ids.len()).sum::<usize>();
        if crate::obsv::enabled() {
            crate::obsv::counter_add("serve.batches", ready.len() as u64);
            for b in &ready {
                crate::obsv::observe("serve.batch_rows",
                                     crate::obsv::Unit::Count,
                                     b.ids.len() as f64);
            }
        }
        execute(&ready, flush_time, responses);
        for b in ready {
            batcher.recycle(b);
        }
    };

    for (i, req) in requests.iter().enumerate() {
        debug_assert_eq!(req.id as usize, i, "ids must be stream indices");
        let now = req.arrival_s;
        // expired batches are flushed at the arrival that triggered
        // the check — the soonest the loop notices
        let expired = batcher.flush_expired(now);
        handle(expired, now, batcher, &mut responses);
        let machine = router.route(&req.x);
        if let Some(full) = batcher.push(machine, req.id, &req.x, now) {
            handle(vec![full], now, batcher, &mut responses);
        }
    }
    let end = requests.last().map(|r| r.arrival_s).unwrap_or(0.0);
    let rest = batcher.flush_all();
    handle(rest, end, batcher, &mut responses);

    responses.sort_by_key(|r| r.id);
    if crate::obsv::enabled() {
        crate::obsv::counter_add("serve.requests", requests.len() as u64);
        crate::obsv::counter_add("serve.responses",
                                 responses.len() as u64);
        for r in &responses {
            crate::obsv::observe("serve.latency_s",
                                 crate::obsv::Unit::Seconds, r.latency_s);
        }
    }
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
    let wall_s = wall.elapsed();
    ServeReport {
        latency: DurationStats::from_samples(&latencies)
            .unwrap_or(DurationStats {
                n: 0, mean: 0.0, min: 0.0, max: 0.0,
                p50: 0.0, p95: 0.0, p99: 0.0,
            }),
        throughput: responses.len() as f64 / wall_s.max(1e-9),
        batches,
        mean_batch_size: batch_rows as f64 / (batches.max(1)) as f64,
        wall_s,
        responses,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg64;

    fn fitted(seed: u64, m: usize) -> (ServedModel, Mat, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let (n, d, s) = (m * 8, 2, 5);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.05);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = random_partition(n, m, &mut rng);
        let model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();
        (model, xd, y)
    }

    /// Empty data / empty blocks are typed errors, not silent zero-mean
    /// models (the `y.len().max(1)` footgun).
    #[test]
    fn fit_rejects_degenerate_inputs() {
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 0.05);
        let xs = Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 1.0]);
        let empty = ServedModel::fit(&hyp, &Mat::zeros(0, 2), &[], &xs,
                                     &[vec![]], &NativeBackend);
        assert_eq!(empty.err(), Some(ApiError::EmptyData));

        let mut rng = Pcg64::seed(3);
        let xd = Mat::from_vec(4, 2, rng.normals(8));
        let y = rng.normals(4);
        let bad_len = ServedModel::fit(&hyp, &xd, &y[..3], &xs,
                                       &[vec![0, 1, 2, 3]], &NativeBackend);
        assert!(matches!(bad_len.err(),
                         Some(ApiError::ShapeMismatch { .. })));
        let empty_block = ServedModel::fit(&hyp, &xd, &y, &xs,
                                           &[vec![0, 1, 2, 3], vec![]],
                                           &NativeBackend);
        assert_eq!(empty_block.err(),
                   Some(ApiError::EmptyPartition { machine: 1 }));
        let oob = ServedModel::fit(&hyp, &xd, &y, &xs,
                                   &[vec![0, 1], vec![2, 9]],
                                   &NativeBackend);
        assert!(matches!(oob.err(),
                         Some(ApiError::InvalidPartition { .. })));
    }

    #[test]
    fn batch_prediction_matches_protocol_block() {
        let (model, _, _) = fitted(1, 2);
        let mut rng = Pcg64::seed(9);
        let q: Vec<f64> = rng.normals(3 * 2);
        // padded to 6 rows; unpadded results must equal direct pPIC call
        let (mean_pad, var_pad) =
            model.predict_batch(&NativeBackend, 0, &q, 3, 6);
        let xu = Mat::from_vec(3, 2, q.clone());
        let (xm, ym, loc) = &model.blocks[0];
        let mut direct = NativeBackend.ppic_predict(
            &model.hyp, &xu, &model.xs, xm, ym, loc, &model.global);
        direct.shift_mean(model.y_mean);
        crate::testkit::assert_all_close(&mean_pad, &direct.mean, 1e-12, 1e-12);
        crate::testkit::assert_all_close(&var_pad, &direct.var, 1e-12, 1e-12);
    }

    /// Fast-path batch prediction ≡ the seed solve-based oracle
    /// ≤1e-12, and the padded fast batch is **bitwise** identical to
    /// the unpadded fast batch on the retained rows.
    #[test]
    fn fast_batch_matches_oracle_and_padding_is_bitwise() {
        let (model, _, _) = fitted(4, 3);
        let mut rng = Pcg64::seed(19);
        let lctx = LinalgCtx::serial();
        let mut scratch = ServeScratch::new();
        for m in 0..3 {
            for rows in [1usize, 3, 5] {
                let q: Vec<f64> = rng.normals(rows * 2);
                let (mean_o, var_o) =
                    model.predict_batch(&NativeBackend, m, &q, rows, 8);
                let (mean_f, var_f) = model.predict_batch_fast(
                    m, &q, rows, 8, &lctx, &mut scratch);
                crate::testkit::assert_all_close(mean_f, &mean_o,
                                                 1e-12, 1e-12);
                crate::testkit::assert_all_close(var_f, &var_o,
                                                 1e-12, 1e-12);
                // padding transparency, bitwise: pad_to == rows vs 8
                let mut s2 = ServeScratch::new();
                let (mean_u, var_u) = model.predict_batch_fast(
                    m, &q, rows, rows, &lctx, &mut s2);
                let mut s3 = ServeScratch::new();
                let (mean_p, var_p) = model.predict_batch_fast(
                    m, &q, rows, 8, &lctx, &mut s3);
                assert_eq!(mean_u, mean_p, "m={m} rows={rows}");
                assert_eq!(var_u, var_p, "m={m} rows={rows}");
            }
        }
    }

    /// The mixed-precision fast path stays within
    /// [`F32_SERVE_REL_BUDGET`] of the f64 fast path on every machine
    /// and batch shape, its padding is bitwise-transparent, and the
    /// unstaged model panics instead of serving garbage.
    #[test]
    fn mixed_precision_fast_path_within_budget() {
        use crate::gp::predictor::F32_SERVE_REL_BUDGET;
        let (model, _, _) = fitted(4, 3);
        let model = model.with_mixed_precision();
        assert!(model.mixed_precision());
        let c0 = model.hyp.prior_var();
        let mut rng = Pcg64::seed(29);
        let lctx = LinalgCtx::serial();
        let mut s64 = ServeScratch::new();
        for m in 0..3 {
            for rows in [1usize, 3, 5] {
                let q: Vec<f64> = rng.normals(rows * 2);
                let (mean_o, var_o) = {
                    let (a, b) = model.predict_batch_fast(
                        m, &q, rows, 8, &lctx, &mut s64);
                    (a.to_vec(), b.to_vec())
                };
                let mut sf = ServeScratch::new();
                let (mean_f, var_f) = model.predict_batch_fast_f32(
                    m, &q, rows, 8, &lctx, &mut sf);
                for i in 0..rows {
                    let m_tol =
                        F32_SERVE_REL_BUDGET * mean_o[i].abs().max(1.0);
                    assert!((mean_f[i] - mean_o[i]).abs() <= m_tol,
                            "m={m} rows={rows} mean {i}");
                    let v_tol =
                        F32_SERVE_REL_BUDGET * var_o[i].abs().max(c0);
                    assert!((var_f[i] - var_o[i]).abs() <= v_tol,
                            "m={m} rows={rows} var {i}");
                }
                // padding transparency, bitwise, on the f32 path too
                let mut s2 = ServeScratch::new();
                let (mean_u, var_u) = model.predict_batch_fast_f32(
                    m, &q, rows, rows, &lctx, &mut s2);
                let mut s3 = ServeScratch::new();
                let (mean_p, var_p) = model.predict_batch_fast_f32(
                    m, &q, rows, 8, &lctx, &mut s3);
                assert_eq!(mean_u, mean_p, "m={m} rows={rows}");
                assert_eq!(var_u, var_p, "m={m} rows={rows}");
            }
        }

        let (plain, _, _) = fitted(4, 3);
        assert!(!plain.mixed_precision());
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut s = ServeScratch::new();
                plain.predict_batch_fast_f32(0, &[0.0, 0.0], 1, 1, &lctx,
                                             &mut s);
            }));
        assert!(err.is_err(), "unstaged f32 path must panic");
    }

    /// A mixed-precision model's serve_fast makes the identical
    /// batching decisions as the f64 model and every response stays
    /// within the budget; refit and machine loss restage the f32
    /// operators (bitwise vs a fresh mixed fit).
    #[test]
    fn mixed_precision_serve_and_restage() {
        use crate::gp::predictor::F32_SERVE_REL_BUDGET;
        let (model, _, _) = fitted(5, 3);
        // same seed → identical fit; only the f32 staging differs
        let mixed = fitted(5, 3).0.with_mixed_precision();
        let c0 = model.hyp.prior_var();
        let mut rng = Pcg64::seed(37);
        let requests: Vec<PredictRequest> = (0..40)
            .map(|i| PredictRequest {
                id: i as u64,
                x: rng.normals(2),
                arrival_s: i as f64 * 1e-4,
            })
            .collect();
        let exec = ParallelExecutor::serial();
        let mut b1 = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
        let f64_rep = model.serve_fast(&requests, &mut b1, &exec);
        let mut b2 = DynamicBatcher::new(mixed.machines(), 2, 4, 5e-4);
        let f32_rep = mixed.serve_fast(&requests, &mut b2, &exec);
        assert_eq!(f64_rep.responses.len(), f32_rep.responses.len());
        assert_eq!(f64_rep.batches, f32_rep.batches);
        for (a, b) in f64_rep.responses.iter().zip(f32_rep.responses.iter())
        {
            assert_eq!(a.id, b.id);
            assert!((b.mean - a.mean).abs()
                        <= F32_SERVE_REL_BUDGET * a.mean.abs().max(1.0),
                    "req {} mean", a.id);
            assert!((b.var - a.var).abs()
                        <= F32_SERVE_REL_BUDGET * a.var.abs().max(c0),
                    "req {} var", a.id);
        }

        // refit restages: f32 path bitwise vs a fresh mixed fit
        let hyp2 = SeArd::isotropic(2, 1.3, 1.4, 0.06);
        let refit = mixed.refit(&hyp2, &NativeBackend);
        assert!(refit.mixed_precision());
        let fresh = model.refit(&hyp2, &NativeBackend)
            .with_mixed_precision();
        let q: Vec<f64> = rng.normals(4 * 2);
        let lctx = LinalgCtx::serial();
        let mut s1 = ServeScratch::new();
        let mut s2 = ServeScratch::new();
        let (m_r, v_r) =
            refit.predict_batch_fast_f32(1, &q, 4, 4, &lctx, &mut s1);
        let (m_f, v_f) =
            fresh.predict_batch_fast_f32(1, &q, 4, 4, &lctx, &mut s2);
        assert_eq!(m_r, m_f);
        assert_eq!(v_r, v_f);

        // machine loss restages too
        let mut lost = mixed;
        lost.lose_machine(1, &NativeBackend).unwrap();
        assert!(lost.mixed_precision());
        assert_eq!(lost.ops_f32.as_ref().unwrap().len(), 2);
        let mut s3 = ServeScratch::new();
        let (m_l, _) =
            lost.predict_batch_fast_f32(0, &q, 4, 4, &lctx, &mut s3);
        assert!(m_l.iter().all(|v| v.is_finite()));
    }

    /// The builder flag flows through: `.mixed_precision(true).serve()`
    /// stages the f32 operators, the default does not.
    #[test]
    fn builder_serve_stages_mixed_precision() {
        let mut rng = Pcg64::seed(53);
        let (n, d) = (16, 2);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.05);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let base = crate::api::Gp::builder()
            .hyp(hyp)
            .data(xd, y)
            .machines(2)
            .support_size(4);
        let plain = base.clone().serve().unwrap();
        assert!(!plain.mixed_precision());
        let mixed = base.mixed_precision(true).serve().unwrap();
        assert!(mixed.mixed_precision());
        assert_eq!(mixed.ops_f32.as_ref().unwrap().len(), 2);
    }

    /// save → load reproduces the fast path bitwise, re-serialization
    /// is byte-identical, the mixed-precision mode survives the trip,
    /// and `swap_in` retires the old model whole.
    #[test]
    fn checkpoint_roundtrip_and_swap() {
        let (model, _, _) = fitted(8, 3);
        let bytes = model.to_checkpoint().encode();
        let loaded = match Checkpoint::decode(&bytes).unwrap() {
            Checkpoint::Served(s) => ServedModel::from_checkpoint(s).unwrap(),
            _ => unreachable!("served checkpoint decoded to another family"),
        };
        assert_eq!(loaded.to_checkpoint().encode(), bytes,
                   "re-serialization must be byte-identical");
        assert_eq!(loaded.state_digest(), model.state_digest());
        let mut rng = Pcg64::seed(61);
        let q: Vec<f64> = rng.normals(4 * 2);
        let lctx = LinalgCtx::serial();
        for m in 0..3 {
            let mut s1 = ServeScratch::new();
            let mut s2 = ServeScratch::new();
            let (m_a, v_a) =
                model.predict_batch_fast(m, &q, 4, 4, &lctx, &mut s1);
            let (m_b, v_b) =
                loaded.predict_batch_fast(m, &q, 4, 4, &lctx, &mut s2);
            assert_eq!(m_a, m_b, "restored mean drifted on machine {m}");
            assert_eq!(v_a, v_b, "restored var drifted on machine {m}");
        }

        let mixed = fitted(8, 3).0.with_mixed_precision();
        let ck = mixed.to_checkpoint();
        let back = match ck {
            Checkpoint::Served(s) => ServedModel::from_checkpoint(s).unwrap(),
            _ => unreachable!(),
        };
        assert!(back.mixed_precision(), "mixed mode must survive the trip");

        // swap: the retired model comes back whole, the live slot holds
        // exactly the replacement
        let (next, _, _) = fitted(9, 3);
        let next_digest = next.state_digest();
        let mut live = loaded;
        let retired = live.swap_in(next);
        assert_eq!(retired.state_digest(), model.state_digest());
        assert_eq!(live.state_digest(), next_digest);
    }

    /// A batch-family checkpoint refuses to load as a serving model,
    /// and internally inconsistent served images are typed errors.
    #[test]
    fn load_rejects_wrong_family_and_incoherent_images() {
        let dir = std::env::temp_dir();
        let path = dir.join("pgpr_served_mismatch.ckpt");
        let ck = crate::store::Checkpoint::Batch(crate::store::BatchCheckpoint {
            method: crate::api::Method::Fgp,
            hyp: SeArd::isotropic(1, 1.0, 1.0, 0.1),
            xd: Mat::from_vec(2, 1, vec![0.0, 1.0]),
            y: vec![0.5, -0.5],
            machines: 1,
            support: None,
            partition: None,
            rank: None,
            threads: 0,
            seed: 1,
            mixed_precision: false,
        });
        ck.write_file(&path).unwrap();
        let err = ServedModel::load(path.to_str().unwrap()).unwrap_err();
        assert_eq!(err, ApiError::Store(StoreError::MethodMismatch {
            expected: "served",
            found: "FGP",
        }));
        let _ = std::fs::remove_file(&path);

        let (model, _, _) = fitted(8, 2);
        let mut sc = match model.to_checkpoint() {
            Checkpoint::Served(s) => s,
            _ => unreachable!(),
        };
        sc.blocks[1].1.pop(); // one target short on machine 1
        let err = ServedModel::from_checkpoint(sc).unwrap_err();
        assert!(matches!(err, ApiError::Store(
                    StoreError::Corrupt { section: "blocks", .. })),
                "got {err:?}");
    }

    /// serve_fast reproduces the backend-driven serve loop's
    /// predictions request-by-request (≤1e-12) with identical batching
    /// decisions, serial and thread-backed.
    #[test]
    fn serve_fast_matches_backend_serve() {
        let (model, _, _) = fitted(5, 3);
        let mut rng = Pcg64::seed(21);
        let requests: Vec<PredictRequest> = (0..40)
            .map(|i| PredictRequest {
                id: i as u64,
                x: rng.normals(2),
                arrival_s: i as f64 * 1e-4,
            })
            .collect();
        let mut b1 = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
        let slow = model.serve(&NativeBackend, &requests, &mut b1);
        for exec in [ParallelExecutor::serial(),
                     ParallelExecutor::threads(3)] {
            let mut b2 = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
            let fast = model.serve_fast(&requests, &mut b2, &exec);
            assert_eq!(slow.responses.len(), fast.responses.len());
            assert_eq!(slow.batches, fast.batches);
            for (a, b) in slow.responses.iter().zip(fast.responses.iter()) {
                assert_eq!(a.id, b.id);
                crate::testkit::assert_close(b.mean, a.mean, 1e-12, 1e-12);
                crate::testkit::assert_close(b.var, a.var, 1e-12, 1e-12);
            }
        }
    }

    /// refit rebuilds the staged operators: the refit model's fast
    /// path equals a fresh fit's fast path under the new hypers.
    #[test]
    fn refit_rebuilds_staged_operators() {
        let mut rng = Pcg64::seed(33);
        let (n, d, s, m) = (24, 2, 5, 3);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.05);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = random_partition(n, m, &mut rng);
        let model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();
        let hyp2 = SeArd::isotropic(d, 1.3, 1.4, 0.02);
        let refit = model.refit(&hyp2, &NativeBackend);
        let fresh = ServedModel::fit(&hyp2, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();
        let q: Vec<f64> = rng.normals(4 * d);
        let lctx = LinalgCtx::serial();
        let mut s1 = ServeScratch::new();
        let mut s2 = ServeScratch::new();
        let (m_r, v_r) =
            refit.predict_batch_fast(1, &q, 4, 4, &lctx, &mut s1);
        let (m_f, v_f) =
            fresh.predict_batch_fast(1, &q, 4, 4, &lctx, &mut s2);
        assert_eq!(m_r, m_f);
        assert_eq!(v_r, v_f);
    }

    #[test]
    fn serve_stream_end_to_end() {
        let (model, _, _) = fitted(2, 3);
        let mut rng = Pcg64::seed(11);
        let n_req = 40;
        let requests: Vec<PredictRequest> = (0..n_req)
            .map(|i| PredictRequest {
                id: i as u64,
                x: rng.normals(2),
                arrival_s: i as f64 * 1e-4,
            })
            .collect();
        let mut batcher = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
        let report = model.serve(&NativeBackend, &requests, &mut batcher);
        assert_eq!(report.responses.len(), n_req);
        // ids covered exactly once, in order after the sort
        for (i, r) in report.responses.iter().enumerate() {
            assert_eq!(r.id as usize, i);
            assert!(r.latency_s >= 0.0, "negative latency {}", r.latency_s);
            assert!(r.mean.is_finite() && r.var.is_finite());
        }
        assert!(report.batches >= n_req / 4);
        assert!(report.mean_batch_size <= 4.0 + 1e-12);
        assert!(report.throughput > 0.0);
        assert!(!report.summary().is_empty());
    }

    #[test]
    fn serve_with_thread_pool_matches_serial() {
        let (model, _, _) = fitted(7, 4);
        let mut rng = Pcg64::seed(23);
        let requests: Vec<PredictRequest> = (0..48)
            .map(|i| PredictRequest {
                id: i as u64,
                x: rng.normals(2),
                arrival_s: i as f64 * 1e-4,
            })
            .collect();
        let mut b1 = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
        let serial = model.serve(&NativeBackend, &requests, &mut b1);
        let mut b2 = DynamicBatcher::new(model.machines(), 2, 4, 5e-4);
        let par = model.serve_with(&NativeBackend, &requests, &mut b2,
                                   &ParallelExecutor::threads(4));
        assert_eq!(serial.responses.len(), par.responses.len());
        assert_eq!(serial.batches, par.batches);
        for (a, b) in serial.responses.iter().zip(par.responses.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.mean, b.mean, "req {}", a.id);
            assert_eq!(a.var, b.var, "req {}", a.id);
        }
    }

    /// Refit under new hypers == a fresh fit with those hypers on the
    /// same partition (and a same-hyp refit is an exact no-op).
    #[test]
    fn refit_matches_fresh_fit() {
        let mut rng = Pcg64::seed(13);
        let (n, d, s, m) = (24, 2, 5, 3);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.05);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = random_partition(n, m, &mut rng);
        let model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();

        let hyp2 = SeArd::isotropic(d, 1.3, 1.4, 0.02);
        let refit = model.refit(&hyp2, &NativeBackend);
        let fresh = ServedModel::fit(&hyp2, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();
        let q: Vec<f64> = rng.normals(4 * d);
        let (m_r, v_r) = refit.predict_batch(&NativeBackend, 1, &q, 4, 4);
        let (m_f, v_f) = fresh.predict_batch(&NativeBackend, 1, &q, 4, 4);
        crate::testkit::assert_all_close(&m_r, &m_f, 1e-12, 1e-12);
        crate::testkit::assert_all_close(&v_r, &v_f, 1e-12, 1e-12);

        let same = model.refit(&hyp, &NativeBackend);
        let (m_0, _) = model.predict_batch(&NativeBackend, 0, &q, 4, 4);
        let (m_s, _) = same.predict_batch(&NativeBackend, 0, &q, 4, 4);
        assert_eq!(m_0, m_s);
    }

    /// Losing a machine conserves every data row, shrinks the cluster
    /// by one, and leaves predictions **bitwise** identical to a fresh
    /// fit on the merged (round-robin rebalanced) partition.
    #[test]
    fn lose_machine_rebalances_and_matches_fresh_fit() {
        let mut rng = Pcg64::seed(41);
        let (n, d, s, m) = (24, 2, 5, 3);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.05);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = random_partition(n, m, &mut rng);
        let mut model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                         &NativeBackend).unwrap();
        let before: usize =
            model.blocks.iter().map(|(x, _, _)| x.rows).sum();
        model.lose_machine(1, &NativeBackend).unwrap();
        assert_eq!(model.machines(), m - 1);
        let after: usize =
            model.blocks.iter().map(|(x, _, _)| x.rows).sum();
        assert_eq!(after, before, "rows must be conserved");

        // the merged partition lose_machine produces: block 1's rows
        // round-robined onto survivors [0, 2] in order
        let mut merged = vec![blocks[0].clone(), blocks[2].clone()];
        for (i, &g) in blocks[1].iter().enumerate() {
            merged[i % 2].push(g);
        }
        let fresh = ServedModel::fit(&hyp, &xd, &y, &xs, &merged,
                                     &NativeBackend).unwrap();
        let q: Vec<f64> = rng.normals(4 * d);
        let lctx = LinalgCtx::serial();
        for mm in 0..m - 1 {
            let (m_l, v_l) =
                model.predict_batch(&NativeBackend, mm, &q, 4, 4);
            let (m_f, v_f) =
                fresh.predict_batch(&NativeBackend, mm, &q, 4, 4);
            assert_eq!(m_l, m_f, "mean drifted on machine {mm}");
            assert_eq!(v_l, v_f, "var drifted on machine {mm}");
            // staged fast path rebuilt too
            let mut s1 = ServeScratch::new();
            let mut s2 = ServeScratch::new();
            let (fm_l, fv_l) =
                model.predict_batch_fast(mm, &q, 4, 4, &lctx, &mut s1);
            let (fm_f, fv_f) =
                fresh.predict_batch_fast(mm, &q, 4, 4, &lctx, &mut s2);
            assert_eq!(fm_l, fm_f, "fast mean drifted on machine {mm}");
            assert_eq!(fv_l, fv_f, "fast var drifted on machine {mm}");
        }
        // routing covers only surviving machines
        assert!(model.router.route(&q[..d]) < m - 1);
    }

    /// Out-of-range machine ids are typed errors; losing the last
    /// machine is `MachinesLost`, not a panic.
    #[test]
    fn lose_machine_rejects_bad_requests() {
        let (mut model, _, _) = fitted(6, 2);
        assert!(matches!(model.lose_machine(5, &NativeBackend),
                         Err(ApiError::InvalidSpec(_))));
        model.lose_machine(0, &NativeBackend).unwrap();
        assert_eq!(model.machines(), 1);
        let err = model.lose_machine(0, &NativeBackend).unwrap_err();
        assert!(matches!(err, ApiError::MachinesLost { machines: 1, .. }));
    }

    #[test]
    fn batch_larger_amortizes_calls() {
        let (model, _, _) = fitted(3, 2);
        let mut rng = Pcg64::seed(4);
        let requests: Vec<PredictRequest> = (0..32)
            .map(|i| PredictRequest {
                id: i as u64,
                x: rng.normals(2),
                arrival_s: 0.0,
            })
            .collect();
        let mut small = DynamicBatcher::new(model.machines(), 2, 1, 1.0);
        let r_small = model.serve(&NativeBackend, &requests, &mut small);
        let mut big = DynamicBatcher::new(model.machines(), 2, 16, 1.0);
        let r_big = model.serve(&NativeBackend, &requests, &mut big);
        assert!(r_big.batches < r_small.batches);
    }

    #[test]
    fn routing_prefers_local_machine() {
        // two machines with separated data; query near machine 1's blob
        let mut rng = Pcg64::seed(5);
        let (n, d, s, _m) = (16, 2, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.05);
        let mut xd = Mat::zeros(n, d);
        for i in 0..n {
            xd[(i, 0)] = if i < n / 2 { -8.0 } else { 8.0 };
            xd[(i, 1)] = rng.normal() * 0.1;
        }
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = vec![(0..n / 2).collect::<Vec<_>>(),
                          (n / 2..n).collect()];
        let model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                     &NativeBackend).unwrap();
        assert_eq!(model.router.route(&[-7.5, 0.0]), 0);
        assert_eq!(model.router.route(&[8.5, 0.0]), 1);
    }
}
