//! # The `pgpr` facade — one door to every GP method
//!
//! The paper's Theorems 1–3 say pPITC, pPIC and the pICF-based GP are
//! *equivalent* to their centralized counterparts; this module makes the
//! code say it too. Every method — the exact FGP baseline, the three
//! centralized approximations, the three distributed protocols, and the
//! §5.2 online mode — is constructed by one [`GpBuilder`] and driven
//! through one [`Regressor`] trait, with method choice a runtime
//! [`Method`] value instead of a compile-time type.
//!
//! * [`GpBuilder`] owns partitioning, support-set selection, executor
//!   (thread pool) plumbing and backend wiring.
//! * [`FitSpec`] / [`PredictSpec`] absorb the per-method quirks that
//!   used to diverge across call sites: PIC's test partition, ICF's
//!   rank, the serving path's pad-to-AOT-shape batches.
//! * [`ApiError`] turns shape mismatches, empty data/partitions and
//!   non-SPD covariances into typed errors instead of panics deep in
//!   [`crate::linalg`].
//!
//! The pre-facade inherent constructors (`FullGp::fit`,
//! `PitcGp::fit_ctx`, the `parallel::*::run` free functions, …) remain
//! public as the low-level layer — the equivalence-test oracles that
//! pin the facade's numerics — but the server, CLI and sweep harness
//! all go through here.
//!
//! ```
//! use pgpr::api::{Gp, Method};
//! use pgpr::kernel::SeArd;
//! use pgpr::linalg::Mat;
//!
//! let hyp = SeArd::isotropic(1, 0.7, 1.0, 0.05);
//! let xd = Mat::from_vec(12, 1, (0..12).map(|i| i as f64 * 0.3).collect());
//! let y: Vec<f64> = (0..12).map(|i| (i as f64 * 0.3).cos()).collect();
//! let xu = Mat::from_vec(3, 1, vec![0.4, 1.9, 3.1]);
//!
//! // same code path, any method
//! for method in [Method::Fgp, Method::Pitc, Method::PPitc] {
//!     let gp = Gp::builder()
//!         .method(method)
//!         .hyp(hyp.clone())
//!         .data(xd.clone(), y.clone())
//!         .machines(3)
//!         .support_size(6)
//!         .fit()
//!         .unwrap();
//!     let pred = gp.predict(&xu).unwrap();
//!     assert_eq!(pred.len(), 3, "{}", method.name());
//! }
//! ```

pub mod builder;
pub mod error;
pub mod method;
pub mod models;
pub mod spec;

pub use builder::GpBuilder;
pub use error::{ApiError, Result};
pub use method::Method;
pub use models::{FgpModel, IcfModel, OnlineSession, PIcfModel, PPicModel,
                 PPitcModel, PicModel, PitcModel};
pub use spec::{FitSpec, PartitionSpec, PredictOutput, PredictSpec,
               SupportSpec};

use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::store::Checkpoint;

/// The one interface every GP regression method implements.
///
/// Object-safe (minus the `Sized`-bound constructor), so a fitted model
/// is usable as `Box<dyn Regressor>` — which is exactly what [`Gp`]
/// holds. Theorems 1–3 guarantee that for a fixed spec, a parallel
/// method and its centralized counterpart produce identical predictions
/// through this interface (asserted in `tests/integration_api.rs`).
pub trait Regressor: Send + Sync {
    /// Fit this method from a (possibly unresolved) [`FitSpec`].
    fn fit(spec: &FitSpec) -> Result<Self>
    where
        Self: Sized;

    /// Method-specific prediction. Implementations may assume
    /// [`PredictSpec::pad_to`] is `None` — padding is handled once in
    /// the provided [`Regressor::predict_full`], which is what callers
    /// should use.
    fn predict_unpadded(&self, spec: &PredictSpec) -> Result<PredictOutput>;

    /// Predict with full output (simulated-cluster metrics included for
    /// the distributed methods). Handles [`PredictSpec::pad_to`] (AOT
    /// batch shapes) uniformly for every method by repeating the first
    /// row and truncating the outputs — per-row predictions are
    /// independent given the fitted summaries, so padding never changes
    /// the retained rows.
    fn predict_full(&self, spec: &PredictSpec) -> Result<PredictOutput> {
        match spec.pad_to {
            None => self.predict_unpadded(spec),
            Some(pad) => {
                if spec.u_blocks.is_some() {
                    return Err(ApiError::invalid(
                        "pad_to and u_blocks are mutually exclusive"));
                }
                let rows = spec.xu.rows;
                if rows == 0 {
                    return Err(ApiError::EmptyData);
                }
                if rows > pad {
                    return Err(ApiError::ShapeMismatch {
                        what: "xu rows vs pad_to",
                        expected: pad,
                        got: rows,
                    });
                }
                let mut data = Vec::with_capacity(pad * spec.xu.cols);
                for r in 0..rows {
                    data.extend_from_slice(spec.xu.row(r));
                }
                for _ in rows..pad {
                    data.extend_from_slice(spec.xu.row(0));
                }
                let padded = Mat::from_vec(pad, spec.xu.cols, data);
                let mut out =
                    self.predict_unpadded(&PredictSpec::new(padded))?;
                out.prediction.mean.truncate(rows);
                out.prediction.var.truncate(rows);
                Ok(out)
            }
        }
    }

    /// Predict means and variances only.
    fn predict(&self, spec: &PredictSpec) -> Result<Prediction> {
        Ok(self.predict_full(spec)?.prediction)
    }

    /// Serve-path prediction through fit-staged predictive operators:
    /// the query-independent pieces of the method's predictive
    /// equations (weight vector, variance operator — see
    /// [`crate::gp::predictor`]) are precomputed once (lazily, on the
    /// first call) and every batch is then one feature GEMM + one GEMV
    /// + one fused quadratic-form pass. No cluster simulation, no
    /// metrics, native math only. Agrees with the seed solve-based
    /// [`Regressor::predict`] path to ≤1e-12 (pinned per method in
    /// `tests/integration_serve_fast.rs`); methods without an override
    /// fall back to it exactly. PIC-family models route test rows by
    /// nearest local-data centroid, like the default `predict` path.
    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        self.predict(&PredictSpec::new(xu.clone()))
    }

    /// Re-fit under new hyperparameters while keeping the original
    /// support set, partition and executor (the serving hot-swap path
    /// for trained hypers).
    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>>;

    /// Snapshot this model's durable state as a [`Checkpoint`]
    /// (versioned, checksummed, deterministic — see [`crate::store`]).
    /// Every in-crate method implements this; the default exists only
    /// for external `Regressor` implementations that opt out.
    fn checkpoint(&self) -> Result<Checkpoint> {
        Err(ApiError::Unsupported("checkpoint"))
    }

    /// Atomically persist this model to `path` (temp file + fsync +
    /// rename); returns the byte count written. Reload through
    /// [`Gp::load`] / [`GpBuilder::from_checkpoint`].
    fn save(&self, path: &str) -> Result<u64> {
        Ok(self.checkpoint()?.write_file(path)?)
    }

    /// Number of (simulated) machines holding the data.
    fn machines(&self) -> usize;

    /// Which method this model is.
    fn method(&self) -> Method;
}

/// A fitted model of *some* method — the facade's main handle.
///
/// Construct with [`Gp::builder`]; see [`GpBuilder`] for the full
/// recipe surface and examples.
pub struct Gp {
    inner: Box<dyn Regressor>,
}

impl Gp {
    /// Start a model recipe.
    #[must_use]
    pub fn builder() -> GpBuilder {
        GpBuilder::new()
    }

    /// Fit the method named by `spec.method`.
    pub fn fit(spec: &FitSpec) -> Result<Gp> {
        let inner: Box<dyn Regressor> = match spec.method {
            Method::Fgp => Box::new(FgpModel::fit(spec)?),
            Method::Pitc => Box::new(PitcModel::fit(spec)?),
            Method::Pic => Box::new(PicModel::fit(spec)?),
            Method::Icf => Box::new(IcfModel::fit(spec)?),
            Method::PPitc => Box::new(PPitcModel::fit(spec)?),
            Method::PPic => Box::new(PPicModel::fit(spec)?),
            Method::PIcf => Box::new(PIcfModel::fit(spec)?),
            Method::Online => Box::new(OnlineSession::fit(spec)?),
        };
        Ok(Gp { inner })
    }

    /// Predict `xu` with default work distribution.
    pub fn predict(&self, xu: &Mat) -> Result<Prediction> {
        self.predict_spec(&PredictSpec::new(xu.clone()))
    }

    /// Predict with an explicit [`PredictSpec`].
    pub fn predict_spec(&self, spec: &PredictSpec) -> Result<Prediction> {
        Ok(self.predict_full(spec)?.prediction)
    }

    /// Predict with full output — see [`Regressor::predict_full`]
    /// (padding to AOT shapes included).
    pub fn predict_full(&self, spec: &PredictSpec) -> Result<PredictOutput> {
        if crate::obsv::enabled() {
            crate::obsv::counter_add_labeled("api.requests",
                                             self.inner.method().name(), 1);
        }
        self.inner.predict_full(spec)
    }

    /// Serve-path prediction through the staged predictive operators —
    /// see [`Regressor::predict_fast`].
    pub fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        if crate::obsv::enabled() {
            crate::obsv::counter_add_labeled("api.requests",
                                             self.inner.method().name(), 1);
        }
        self.inner.predict_fast(xu)
    }

    /// Re-fit under new hyperparameters (same support set, partition,
    /// executor) — see [`Regressor::refit`].
    pub fn refit(&self, hyp: &SeArd) -> Result<Gp> {
        Ok(Gp { inner: self.inner.refit(hyp)? })
    }

    /// Number of (simulated) machines holding the data.
    #[must_use]
    pub fn machines(&self) -> usize {
        self.inner.machines()
    }

    /// Which method this model is.
    #[must_use]
    pub fn method(&self) -> Method {
        self.inner.method()
    }

    /// Borrow the model through the trait (e.g. to store heterogeneous
    /// models together).
    #[must_use]
    pub fn as_regressor(&self) -> &dyn Regressor {
        self.inner.as_ref()
    }

    /// Snapshot the model's durable state — see [`Regressor::checkpoint`].
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        self.inner.checkpoint()
    }

    /// Atomically persist to `path`; returns bytes written — see
    /// [`Regressor::save`].
    pub fn save(&self, path: &str) -> Result<u64> {
        self.inner.save(path)
    }

    /// Rebuild a fitted model from a decoded [`Checkpoint`]. Batch
    /// checkpoints re-run the deterministic fit from their resolved
    /// ingredients; online checkpoints restore the stream state
    /// verbatim. Either way the rebuilt model predicts bitwise what the
    /// saved one did, and re-serializing it reproduces the checkpoint
    /// byte-for-byte (pinned in `tests/integration_store.rs`). A served
    /// checkpoint belongs to [`crate::server::ServedModel::load`] and
    /// is reported as a typed mismatch here.
    pub fn from_checkpoint(ckpt: Checkpoint) -> Result<Gp> {
        match ckpt {
            Checkpoint::Batch(b) => Gp::fit(&models::spec_of_batch(&b)),
            Checkpoint::Online(o) => Ok(Gp {
                inner: Box::new(OnlineSession::from_checkpoint(o)?),
            }),
            Checkpoint::Served(_) => {
                Err(ApiError::Store(crate::store::StoreError::MethodMismatch {
                    expected: "an api::Method model",
                    found: "served",
                }))
            }
        }
    }

    /// Read, validate and rebuild a model from a checkpoint file —
    /// corrupt input yields a typed [`ApiError::Store`], never a panic.
    pub fn load(path: &str) -> Result<Gp> {
        Gp::from_checkpoint(Checkpoint::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::gp::icf_gp::IcfGp;
    use crate::gp::pic::PicGp;
    use crate::gp::pitc::PitcGp;
    use crate::gp::FullGp;
    use crate::testkit::assert_all_close;
    use crate::util::Pcg64;

    fn problem(n: usize, u: usize, d: usize, seed: u64)
        -> (SeArd, Mat, Vec<f64>, Mat, Mat)
    {
        let mut rng = Pcg64::seed(seed);
        let hyp = SeArd::isotropic(d, 0.9, 1.0, 0.08);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(6, d, rng.normals(6 * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        (hyp, xd, y, xs, xu)
    }

    /// Facade predictions are the *same numbers* as the pre-facade
    /// direct calls (the in-crate half of the equivalence oracle; the
    /// cross-method protocol half lives in `tests/integration_api.rs`).
    #[test]
    fn facade_matches_direct_centralized_calls() {
        let (hyp, xd, y, xs, xu) = problem(24, 9, 2, 5);
        let mut rng = Pcg64::seed(11);
        for m in [1, 4, 8] {
            let d_blocks = random_partition(24, m, &mut rng);

            let fit = |method: Method| {
                Gp::builder()
                    .method(method)
                    .hyp(hyp.clone())
                    .data(xd.clone(), y.clone())
                    .machines(m)
                    .support(xs.clone())
                    .partition(d_blocks.clone())
                    .rank(12)
                    .fit()
                    .unwrap()
            };

            let got = fit(Method::Fgp).predict(&xu).unwrap();
            let want = FullGp::fit(&hyp, &xd, &y).predict(&xu);
            assert_eq!(got.mean, want.mean, "FGP M={m}");
            assert_eq!(got.var, want.var, "FGP M={m}");

            let got = fit(Method::Pitc).predict(&xu).unwrap();
            let want =
                PitcGp::fit(&hyp, &xd, &y, &xs, &d_blocks).predict(&xu);
            assert_eq!(got.mean, want.mean, "PITC M={m}");
            assert_eq!(got.var, want.var, "PITC M={m}");

            let got = fit(Method::Icf).predict(&xu).unwrap();
            let want =
                IcfGp::fit(&hyp, &xd, &y, 12, &d_blocks).predict(&xu);
            assert_eq!(got.mean, want.mean, "ICF M={m}");
            assert_eq!(got.var, want.var, "ICF M={m}");

            // PIC conditions on the test partition: pin it explicitly
            let ub = random_partition(8, m, &mut rng);
            let xu8 = Mat::from_vec(8, 2, xu.data[..16].to_vec());
            let got = fit(Method::Pic)
                .predict_spec(&PredictSpec::new(xu8.clone())
                    .with_blocks(ub.clone()))
                .unwrap();
            let want = PicGp::fit(&hyp, &xd, &y, &xs, &d_blocks)
                .predict(&xu8, &ub);
            assert_eq!(got.mean, want.mean, "PIC M={m}");
            assert_eq!(got.var, want.var, "PIC M={m}");
        }
    }

    /// Refit through the facade == fresh fit with the new hypers on the
    /// same pinned spec (the serving hot-swap contract, per method).
    #[test]
    fn refit_equals_fresh_fit_on_same_spec() {
        let (hyp, xd, y, xs, xu) = problem(20, 6, 2, 7);
        let d_blocks = random_partition(20, 4, &mut Pcg64::seed(2));
        let b = Gp::builder()
            .method(Method::PPic)
            .hyp(hyp.clone())
            .data(xd.clone(), y.clone())
            .machines(4)
            .support(xs.clone())
            .partition(d_blocks.clone());
        let gp = b.fit().unwrap();
        let hyp2 = SeArd::isotropic(2, 1.4, 1.2, 0.03);
        let refit = gp.refit(&hyp2).unwrap();
        assert_eq!(refit.method(), Method::PPic);
        assert_eq!(refit.machines(), 4);
        let p1 = refit.predict(&xu).unwrap();
        let fresh = b.hyp(hyp2).fit().unwrap().predict(&xu).unwrap();
        assert_eq!(p1.mean, fresh.mean);
        assert_eq!(p1.var, fresh.var);
        // and the hypers actually took effect
        let p0 = gp.predict(&xu).unwrap();
        assert!(p0.mean != p1.mean);
    }

    /// The typed error layer fires before any heavy math.
    #[test]
    fn validation_errors() {
        let (hyp, xd, y, xs, _xu) = problem(12, 4, 2, 9);
        let base = || {
            Gp::builder()
                .hyp(hyp.clone())
                .data(xd.clone(), y.clone())
        };

        // missing pieces
        assert_eq!(Gp::builder().hyp(hyp.clone()).fit().err().unwrap(),
                   ApiError::MissingField("data"));
        assert!(matches!(
            base().method(Method::Pitc).machines(3).fit().err().unwrap(),
            ApiError::MissingField(_)));
        assert!(matches!(
            base().method(Method::PIcf).machines(3).partition(
                vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9, 10, 11]])
                .fit().err().unwrap(),
            ApiError::MissingField(_)));

        // bad shapes / partitions
        assert!(matches!(
            base().method(Method::Pitc).machines(5).support(xs.clone())
                .fit().err().unwrap(),
            ApiError::InvalidSpec(_)));
        assert!(matches!(
            base().method(Method::Pitc).machines(2).support(xs.clone())
                .partition(vec![vec![0, 1], vec![2, 3]]).fit().err().unwrap(),
            ApiError::InvalidPartition { .. }));
        let gp = base().method(Method::Pitc).machines(2)
            .support(xs.clone()).fit().unwrap();
        let bad = Mat::from_vec(2, 3, vec![0.0; 6]);
        assert!(matches!(gp.predict(&bad).unwrap_err(),
                         ApiError::ShapeMismatch { .. }));

        // machines is inferred from an explicit partition
        let gp = base().method(Method::Pitc).support(xs.clone())
            .partition(vec![(0..6).collect(), (6..12).collect()])
            .fit().unwrap();
        assert_eq!(gp.machines(), 2);
    }

    /// pad_to repeats rows then truncates — identical retained rows.
    #[test]
    fn pad_to_is_transparent() {
        let (hyp, xd, y, xs, xu) = problem(16, 3, 2, 13);
        let gp = Gp::builder()
            .method(Method::PPitc)
            .hyp(hyp)
            .data(xd, y)
            .machines(2)
            .support(xs)
            .fit()
            .unwrap();
        let plain = gp.predict(&xu).unwrap();
        let padded = gp
            .predict_spec(&PredictSpec::new(xu.clone()).with_pad_to(8))
            .unwrap();
        assert_eq!(padded.len(), 3);
        assert_eq!(plain.mean, padded.mean);
        assert_eq!(plain.var, padded.var);
        assert!(matches!(
            gp.predict_spec(&PredictSpec::new(xu).with_pad_to(2))
                .unwrap_err(),
            ApiError::ShapeMismatch { .. }));
    }

    /// The online session equals batch pPIC on the same single-batch
    /// partition (§5.2 with one absorb), and streams further batches.
    #[test]
    fn online_session_first_batch_equals_ppic() {
        let n = 16;
        let mut rng = Pcg64::seed(31);
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, 2, rng.normals(n * 2));
        // zero-mean y so the online prior mean (first batch) matches the
        // batch run's empirical mean exactly
        let mut y = rng.normals(n);
        let mu = y.iter().sum::<f64>() / n as f64;
        for v in y.iter_mut() {
            *v -= mu;
        }
        let xs = Mat::from_vec(4, 2, rng.normals(8));
        let xu = Mat::from_vec(6, 2, rng.normals(12));
        let d_blocks = random_partition(n, 2, &mut rng);
        let u_blocks = random_partition(6, 2, &mut rng);

        let b = Gp::builder()
            .hyp(hyp.clone())
            .data(xd.clone(), y.clone())
            .machines(2)
            .support(xs.clone())
            .partition(d_blocks.clone());
        let mut sess = b.online().unwrap();
        assert_eq!(sess.batches(), 1);
        let ps = PredictSpec::new(xu.clone()).with_blocks(u_blocks.clone());
        let got = sess.predict(&ps).unwrap();

        let want = b.method(Method::PPic).fit().unwrap()
            .predict_spec(&ps).unwrap();
        assert_all_close(&got.mean, &want.mean, 1e-10, 1e-10);
        assert_all_close(&got.var, &want.var, 1e-10, 1e-10);

        // stream one more batch
        let batch: Vec<(Mat, Vec<f64>)> = (0..2)
            .map(|_| (Mat::from_vec(3, 2, rng.normals(6)), rng.normals(3)))
            .collect();
        sess.absorb(&batch).unwrap();
        assert_eq!(sess.batches(), 2);
        let p2 = sess.predict(&PredictSpec::new(xu)).unwrap();
        assert_eq!(p2.len(), 6);
        assert!(p2.var.iter().all(|&v| v.is_finite()));
        // refit is explicitly unsupported for streams
        assert_eq!(sess.refit(&hyp).err(),
                   Some(ApiError::Unsupported("refit of an online session")));
    }
}
