//! Declarative fit/predict specifications — the facade's wire format.
//!
//! A [`FitSpec`] is everything needed to construct any model behind the
//! [`crate::api::Regressor`] trait; a [`PredictSpec`] carries a test
//! matrix plus the per-method quirks (PIC's test partition, the AOT
//! pad-to shape) that used to leak into every call site.

use std::sync::Arc;

use super::error::{ApiError, Result};
use super::method::Method;
use crate::cluster::{FaultPlan, ParallelExecutor, RunMetrics};
use crate::data::partition::random_partition;
use crate::gp::support::support_from_pool;
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::runtime::Backend;
use crate::util::Pcg64;

/// How the support set S is chosen.
#[derive(Clone, Debug)]
pub enum SupportSpec {
    /// Not set (valid only for methods with [`Method::needs_support`]
    /// false).
    Unset,
    /// Use these rows verbatim.
    Points(Mat),
    /// Differential-entropy greedy selection of `size` rows from a
    /// seeded random candidate pool of the training inputs (the
    /// Section-6 recipe).
    Entropy { size: usize },
}

/// How the Definition-1 data partition is chosen.
#[derive(Clone, Debug)]
pub enum PartitionSpec {
    /// Even random partition (seeded; requires `machines | n`).
    Random,
    /// Use these blocks verbatim (validated: disjoint cover of `0..n`).
    Blocks(Vec<Vec<usize>>),
}

/// A complete, validated model recipe. Build one with
/// [`crate::api::GpBuilder`]; [`FitSpec::resolved`] turns selection
/// policies ([`SupportSpec::Entropy`], [`PartitionSpec::Random`]) into
/// concrete values so a refit reuses the exact same S and blocks.
#[derive(Clone)]
pub struct FitSpec {
    pub method: Method,
    pub hyp: SeArd,
    pub xd: Mat,
    pub y: Vec<f64>,
    pub machines: usize,
    pub support: SupportSpec,
    pub partition: PartitionSpec,
    /// ICF rank R (required by [`Method::needs_rank`] methods).
    pub rank: Option<usize>,
    /// Host worker threads (0/1 = serial).
    pub threads: usize,
    pub seed: u64,
    pub backend: Arc<dyn Backend>,
    /// Optional pre-built executor; overrides `threads` so many models
    /// can share one thread pool (the sweep-harness pattern).
    pub exec: Option<ParallelExecutor>,
    /// Optional fault-injection plan: cluster methods then run their
    /// fault-aware protocol variants (retry, rebalance, typed
    /// [`ApiError::MachinesLost`]) instead of the direct path.
    pub faults: Option<FaultPlan>,
    /// Opt into the mixed-precision (f32-storage / f64-accumulate)
    /// serve path: [`crate::api::GpBuilder::serve`] then stages
    /// demoted operators alongside the f64 ones and serves through
    /// them, within
    /// [`crate::gp::predictor::F32_SERVE_REL_BUDGET`] of the f64
    /// path. Ignored by the non-serving fit terminals.
    pub mixed_precision: bool,
}

impl std::fmt::Debug for FitSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FitSpec")
            .field("method", &self.method)
            .field("n", &self.xd.rows)
            .field("d", &self.xd.cols)
            .field("machines", &self.machines)
            .field("rank", &self.rank)
            .field("threads", &self.threads)
            .field("seed", &self.seed)
            .field("backend", &self.backend.name())
            .field("faults", &self.faults)
            .field("mixed_precision", &self.mixed_precision)
            .finish()
    }
}

impl FitSpec {
    /// The executor this spec runs node work (and master-side linalg)
    /// on: the shared override if set, else a fresh pool per
    /// [`FitSpec::threads`].
    #[must_use]
    pub fn executor(&self) -> ParallelExecutor {
        match &self.exec {
            Some(e) => e.clone(),
            None => ParallelExecutor::threads(self.threads),
        }
    }

    /// Validate the spec and materialize every selection policy:
    /// entropy support becomes [`SupportSpec::Points`], the random
    /// partition becomes [`PartitionSpec::Blocks`]. Idempotent — and
    /// the basis of [`crate::api::Regressor::refit`] reusing the exact
    /// support set and blocks of the original fit.
    pub fn resolved(&self) -> Result<FitSpec> {
        let n = self.xd.rows;
        if n == 0 || self.y.is_empty() {
            return Err(ApiError::EmptyData);
        }
        if self.y.len() != n {
            return Err(ApiError::ShapeMismatch {
                what: "y length vs xd rows",
                expected: n,
                got: self.y.len(),
            });
        }
        if self.machines == 0 {
            return Err(ApiError::invalid("machines must be >= 1"));
        }

        let support = match &self.support {
            SupportSpec::Unset => {
                if self.method.needs_support() {
                    return Err(ApiError::MissingField(
                        "support (set .support(xs) or .support_size(k))"));
                }
                SupportSpec::Unset
            }
            SupportSpec::Points(xs) => {
                if xs.rows == 0 {
                    return Err(ApiError::invalid("support set is empty"));
                }
                if xs.cols != self.xd.cols {
                    return Err(ApiError::ShapeMismatch {
                        what: "support cols vs input dim",
                        expected: self.xd.cols,
                        got: xs.cols,
                    });
                }
                SupportSpec::Points(xs.clone())
            }
            SupportSpec::Entropy { size } => {
                if *size == 0 {
                    return Err(ApiError::invalid("support size must be >= 1"));
                }
                if self.method.needs_support() {
                    let mut rng = Pcg64::new(self.seed, 0xA1);
                    SupportSpec::Points(support_from_pool(
                        &self.hyp, &self.xd, *size, &mut rng))
                } else {
                    // don't pay for a selection this method never reads
                    // (one base builder fanning out over methods)
                    SupportSpec::Unset
                }
            }
        };

        let partition = if self.method.needs_partition() {
            let blocks = match &self.partition {
                PartitionSpec::Random => {
                    if n % self.machines != 0 {
                        return Err(ApiError::invalid(format!(
                            "random partition needs machines | n \
                             ({} ∤ {n}); trim the data or pass explicit \
                             blocks", self.machines)));
                    }
                    let mut rng = Pcg64::new(self.seed, 0xA2);
                    random_partition(n, self.machines, &mut rng)
                }
                PartitionSpec::Blocks(b) => {
                    validate_partition(b, n, self.machines)?;
                    b.clone()
                }
            };
            PartitionSpec::Blocks(blocks)
        } else {
            self.partition.clone()
        };

        let rank = if self.method.needs_rank() {
            match self.rank {
                None => return Err(ApiError::MissingField("rank (set .rank(r))")),
                Some(0) => {
                    return Err(ApiError::invalid("rank must be >= 1"))
                }
                Some(r) => Some(r.min(n)),
            }
        } else {
            self.rank
        };

        Ok(FitSpec {
            support,
            partition,
            rank,
            ..self.clone()
        })
    }

    /// The resolved support matrix (panics if called before
    /// [`FitSpec::resolved`] on a support-needing method — facade
    /// internals only see resolved specs).
    pub(crate) fn support_points(&self) -> &Mat {
        match &self.support {
            SupportSpec::Points(xs) => xs,
            _ => panic!("spec not resolved: support"),
        }
    }

    /// The resolved Definition-1 blocks (same caveat as
    /// [`FitSpec::support_points`]).
    pub(crate) fn blocks(&self) -> &[Vec<usize>] {
        match &self.partition {
            PartitionSpec::Blocks(b) => b,
            _ => panic!("spec not resolved: partition"),
        }
    }
}

/// Check a Definition-1 partition: exactly `machines` non-empty,
/// disjoint blocks covering `0..n`.
pub(crate) fn validate_partition(
    blocks: &[Vec<usize>],
    n: usize,
    machines: usize,
) -> Result<()> {
    if blocks.len() != machines {
        return Err(ApiError::ShapeMismatch {
            what: "partition blocks vs machines",
            expected: machines,
            got: blocks.len(),
        });
    }
    let mut seen = vec![false; n];
    for (m, blk) in blocks.iter().enumerate() {
        if blk.is_empty() {
            return Err(ApiError::EmptyPartition { machine: m });
        }
        for &i in blk {
            if i >= n {
                return Err(ApiError::InvalidPartition {
                    reason: format!("machine {m} references row {i} >= {n}"),
                });
            }
            if seen[i] {
                return Err(ApiError::InvalidPartition {
                    reason: format!("row {i} assigned twice"),
                });
            }
            seen[i] = true;
        }
    }
    if let Some(miss) = seen.iter().position(|&s| !s) {
        return Err(ApiError::InvalidPartition {
            reason: format!("row {miss} unassigned"),
        });
    }
    Ok(())
}

/// Like [`validate_partition`] but for *test* partitions, where empty
/// blocks are legal (a machine may simply have no queries).
pub(crate) fn validate_test_partition(
    blocks: &[Vec<usize>],
    u: usize,
    machines: usize,
) -> Result<()> {
    if blocks.len() != machines {
        return Err(ApiError::ShapeMismatch {
            what: "u_blocks vs machines",
            expected: machines,
            got: blocks.len(),
        });
    }
    let mut seen = vec![false; u];
    for (m, blk) in blocks.iter().enumerate() {
        for &i in blk {
            if i >= u {
                return Err(ApiError::InvalidPartition {
                    reason: format!("machine {m} references test row {i} >= {u}"),
                });
            }
            if seen[i] {
                return Err(ApiError::InvalidPartition {
                    reason: format!("test row {i} assigned twice"),
                });
            }
            seen[i] = true;
        }
    }
    if let Some(miss) = seen.iter().position(|&s| !s) {
        return Err(ApiError::InvalidPartition {
            reason: format!("test row {miss} unassigned"),
        });
    }
    Ok(())
}

/// One prediction request against a fitted model.
///
/// * `u_blocks` — Definition-1 test partition. Only the PIC family
///   conditions on it numerically; methods whose per-row predictions
///   are partition-independent use it (or a default split) purely for
///   work distribution. When absent, PIC-family models route each test
///   row to the machine with the nearest local-data centroid (the
///   serving scheme of [`crate::server::Router`]).
/// * `pad_to` — pad the batch to a fixed AOT row count by repeating the
///   first row; extra outputs are discarded. Mutually exclusive with
///   `u_blocks`.
#[derive(Clone, Debug)]
pub struct PredictSpec {
    pub xu: Mat,
    pub u_blocks: Option<Vec<Vec<usize>>>,
    pub pad_to: Option<usize>,
}

impl PredictSpec {
    /// Predict these rows with default work distribution.
    #[must_use]
    pub fn new(xu: Mat) -> PredictSpec {
        PredictSpec { xu, u_blocks: None, pad_to: None }
    }

    /// Pin the Definition-1 test partition (required to reproduce a
    /// specific PIC/pPIC run exactly).
    #[must_use]
    pub fn with_blocks(mut self, u_blocks: Vec<Vec<usize>>) -> PredictSpec {
        self.u_blocks = Some(u_blocks);
        self
    }

    /// Pad the batch to an AOT shape (see [`PredictSpec`] docs).
    #[must_use]
    pub fn with_pad_to(mut self, pad_to: usize) -> PredictSpec {
        self.pad_to = Some(pad_to);
        self
    }
}

/// A prediction plus the simulated-cluster metrics, when the method ran
/// a distributed protocol (`None` for centralized methods).
#[derive(Clone, Debug)]
pub struct PredictOutput {
    pub prediction: Prediction,
    pub metrics: Option<RunMetrics>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_validation() {
        // valid
        assert!(validate_partition(&[vec![0, 2], vec![1, 3]], 4, 2).is_ok());
        // wrong machine count
        assert!(matches!(
            validate_partition(&[vec![0, 1, 2, 3]], 4, 2),
            Err(ApiError::ShapeMismatch { .. })
        ));
        // empty block
        assert!(matches!(
            validate_partition(&[vec![0, 1, 2, 3], vec![]], 4, 2),
            Err(ApiError::EmptyPartition { machine: 1 })
        ));
        // duplicate
        assert!(matches!(
            validate_partition(&[vec![0, 1], vec![1, 2]], 4, 2),
            Err(ApiError::InvalidPartition { .. })
        ));
        // missing row
        assert!(matches!(
            validate_partition(&[vec![0, 1], vec![2]], 4, 2),
            Err(ApiError::InvalidPartition { .. })
        ));
        // out of range
        assert!(matches!(
            validate_partition(&[vec![0, 1], vec![2, 9]], 4, 2),
            Err(ApiError::InvalidPartition { .. })
        ));
    }

    #[test]
    fn test_partition_allows_empty_blocks() {
        assert!(validate_test_partition(&[vec![0, 1, 2], vec![]], 3, 2).is_ok());
        assert!(matches!(
            validate_test_partition(&[vec![0, 1], vec![]], 3, 2),
            Err(ApiError::InvalidPartition { .. })
        ));
    }
}
