//! Typed error layer of the facade: shape mismatches, empty data or
//! partitions, and non-SPD covariances surface as [`ApiError`] values
//! instead of panics deep inside [`crate::linalg`].

use crate::linalg::cholesky::NotSpd;
use crate::store::StoreError;
use std::fmt;

/// `Result` specialized to the facade's error type.
pub type Result<T> = std::result::Result<T, ApiError>;

/// Everything the facade can reject.
///
/// Validation happens eagerly: [`crate::api::GpBuilder::fit`] checks
/// shapes, partitions and spec completeness *before* any O(n³) work, and
/// the FGP/PITC/PIC fit paths report Cholesky breakdowns as
/// [`ApiError::NotSpd`] rather than panicking. (ICF's pivoted
/// factorization cannot fail SPD at fit; its R×R solves at predict
/// time, and the distributed protocols' in-cluster factorizations,
/// keep the pre-facade panic behavior.)
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// No training data (`y` empty) — previously a silently-served
    /// zero-mean model.
    EmptyData,
    /// Two shapes that must agree do not.
    ShapeMismatch {
        /// What was being checked (e.g. `"y vs xd rows"`).
        what: &'static str,
        expected: usize,
        got: usize,
    },
    /// A machine's Definition-1 block is empty.
    EmptyPartition {
        /// Machine index with no data.
        machine: usize,
    },
    /// A partition is malformed (out-of-range, duplicate, or missing
    /// row indices).
    InvalidPartition {
        reason: String,
    },
    /// A covariance matrix was not symmetric positive definite.
    NotSpd {
        /// Which matrix failed (e.g. `"Σ_DD"`).
        what: &'static str,
        /// Failing pivot index and value from the Cholesky.
        pivot: usize,
        value: f64,
    },
    /// A fault-injected run lost every machine mid-protocol (see
    /// [`crate::cluster::MachinesLost`]).
    MachinesLost {
        /// Protocol phase during which the last machine died.
        phase: String,
        /// Cluster size (all of them are gone).
        machines: usize,
    },
    /// A required spec field was never set.
    MissingField(&'static str),
    /// The spec is self-inconsistent (bad sizes, conflicting options).
    InvalidSpec(String),
    /// The operation is not defined for this method.
    Unsupported(&'static str),
    /// Saving or loading a checkpoint failed (see
    /// [`crate::store::StoreError`] — corrupt input surfaces here as a
    /// typed value, never a panic).
    Store(StoreError),
}

impl ApiError {
    /// Wrap a linalg [`NotSpd`] with the name of the failing matrix.
    pub fn not_spd(what: &'static str, e: &NotSpd) -> ApiError {
        ApiError::NotSpd { what, pivot: e.pivot, value: e.value }
    }

    /// Shorthand for [`ApiError::InvalidSpec`].
    pub fn invalid(reason: impl Into<String>) -> ApiError {
        ApiError::InvalidSpec(reason.into())
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::EmptyData => write!(f, "empty training data"),
            ApiError::ShapeMismatch { what, expected, got } => {
                write!(f, "shape mismatch ({what}): expected {expected}, \
                           got {got}")
            }
            ApiError::EmptyPartition { machine } => {
                write!(f, "machine {machine} has an empty data block")
            }
            ApiError::InvalidPartition { reason } => {
                write!(f, "invalid partition: {reason}")
            }
            ApiError::NotSpd { what, pivot, value } => {
                write!(f, "{what} not SPD: pivot {pivot} = {value:.3e}")
            }
            ApiError::MachinesLost { phase, machines } => {
                write!(f, "all {machines} machines lost during phase \
                           '{phase}'")
            }
            ApiError::MissingField(name) => {
                write!(f, "spec field not set: {name}")
            }
            ApiError::InvalidSpec(reason) => write!(f, "invalid spec: {reason}"),
            ApiError::Unsupported(op) => {
                write!(f, "operation not supported by this method: {op}")
            }
            ApiError::Store(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<crate::cluster::MachinesLost> for ApiError {
    fn from(e: crate::cluster::MachinesLost) -> ApiError {
        ApiError::MachinesLost { phase: e.phase, machines: e.machines }
    }
}

impl From<StoreError> for ApiError {
    fn from(e: StoreError) -> ApiError {
        ApiError::Store(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = ApiError::ShapeMismatch { what: "y vs xd", expected: 4, got: 3 };
        assert!(e.to_string().contains("expected 4"));
        let e = ApiError::not_spd("Σ_DD", &NotSpd { pivot: 2, value: -1.0 });
        assert!(e.to_string().contains("Σ_DD"));
        assert!(ApiError::EmptyData.to_string().contains("empty"));
        assert!(ApiError::MissingField("support").to_string().contains("support"));
    }

    #[test]
    fn machines_lost_converts_from_cluster_error() {
        let e: ApiError =
            crate::cluster::MachinesLost::at("predict", 4).into();
        assert_eq!(e, ApiError::MachinesLost {
            phase: "predict".into(),
            machines: 4,
        });
        assert!(e.to_string().contains("predict"));
        assert!(e.to_string().contains('4'));
    }
}
