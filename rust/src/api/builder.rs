//! [`GpBuilder`] — the one construction path for every model, serving
//! state, and training run behind the facade.

use std::sync::Arc;

use super::error::{ApiError, Result};
use super::method::Method;
use super::models::OnlineSession;
use super::spec::{FitSpec, PartitionSpec, SupportSpec};
use super::{Gp, Regressor as _};
use crate::cluster::{FaultPlan, ParallelExecutor};
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::parallel::ClusterSpec;
use crate::runtime::{Backend, NativeBackend};
use crate::server::ServedModel;
use crate::train::{train_pitc, try_train_pitc, AdamConfig, TrainResult};

/// Fluent recipe for a GP model: pick a [`Method`] at runtime, hand over
/// data, and let the builder own partitioning, support selection and
/// executor plumbing (everything the old per-model 6-positional-arg
/// `fit` calls made each call site repeat).
///
/// ```
/// use pgpr::api::{Gp, Method};
/// use pgpr::kernel::SeArd;
/// use pgpr::linalg::Mat;
///
/// let hyp = SeArd::isotropic(1, 0.8, 1.0, 0.05);
/// let xd = Mat::from_vec(8, 1, (0..8).map(|i| i as f64 * 0.4).collect());
/// let y: Vec<f64> = (0..8).map(|i| (i as f64 * 0.4).sin()).collect();
///
/// // pPIC on 2 simulated machines with an entropy-selected support set
/// let gp = Gp::builder()
///     .method(Method::PPic)
///     .hyp(hyp)
///     .data(xd, y)
///     .machines(2)
///     .support_size(4)
///     .fit()
///     .unwrap();
/// assert_eq!(gp.method(), Method::PPic);
/// assert_eq!(gp.machines(), 2);
///
/// let xu = Mat::from_vec(2, 1, vec![0.5, 1.5]);
/// let pred = gp.predict(&xu).unwrap();
/// assert_eq!(pred.len(), 2);
/// assert!(pred.var.iter().all(|&v| v > 0.0));
/// ```
///
/// Invalid specs come back as typed [`ApiError`]s instead of panics:
///
/// ```
/// use pgpr::api::{ApiError, Gp, Method};
/// use pgpr::kernel::SeArd;
/// use pgpr::linalg::Mat;
///
/// let err = Gp::builder()
///     .method(Method::Pitc)
///     .hyp(SeArd::isotropic(1, 1.0, 1.0, 0.1))
///     .data(Mat::zeros(0, 1), vec![])
///     .fit()
///     .err()
///     .unwrap();
/// assert_eq!(err, ApiError::EmptyData);
/// ```
///
/// Builders are `Clone` (data buffers are copied, the backend and any
/// shared executor by `Arc`), so one base recipe can fan out over
/// methods: `base.clone().method(Method::PIcf).fit()`.
#[derive(Clone)]
pub struct GpBuilder {
    method: Method,
    hyp: Option<SeArd>,
    xd: Option<Mat>,
    y: Option<Vec<f64>>,
    machines: Option<usize>,
    support: SupportSpec,
    partition: PartitionSpec,
    rank: Option<usize>,
    threads: usize,
    seed: u64,
    backend: Arc<dyn Backend>,
    exec: Option<ParallelExecutor>,
    faults: Option<FaultPlan>,
    mixed_precision: bool,
}

impl Default for GpBuilder {
    fn default() -> GpBuilder {
        GpBuilder {
            method: Method::Fgp,
            hyp: None,
            xd: None,
            y: None,
            machines: None,
            support: SupportSpec::Unset,
            partition: PartitionSpec::Random,
            rank: None,
            threads: 0,
            seed: 1,
            backend: Arc::new(NativeBackend),
            exec: None,
            faults: None,
            mixed_precision: false,
        }
    }
}

impl GpBuilder {
    /// Fresh builder with the defaults: exact FGP, one machine, serial
    /// execution, native backend, seed 1.
    #[must_use]
    pub fn new() -> GpBuilder {
        GpBuilder::default()
    }

    /// Which regression method to fit (default [`Method::Fgp`]).
    #[must_use]
    pub fn method(mut self, method: Method) -> GpBuilder {
        self.method = method;
        self
    }

    /// Kernel hyperparameters (required).
    #[must_use]
    pub fn hyp(mut self, hyp: SeArd) -> GpBuilder {
        self.hyp = Some(hyp);
        self
    }

    /// Training inputs and outputs (required).
    #[must_use]
    pub fn data(mut self, xd: Mat, y: Vec<f64>) -> GpBuilder {
        self.xd = Some(xd);
        self.y = Some(y);
        self
    }

    /// Number of simulated machines M. Defaults to the block count of
    /// an explicit [`GpBuilder::partition`] (so a partition alone fully
    /// determines M), else 1.
    #[must_use]
    pub fn machines(mut self, machines: usize) -> GpBuilder {
        self.machines = Some(machines);
        self
    }

    /// Use these support inputs verbatim.
    #[must_use]
    pub fn support(mut self, xs: Mat) -> GpBuilder {
        self.support = SupportSpec::Points(xs);
        self
    }

    /// Select `size` support inputs by greedy differential-entropy
    /// scoring over a seeded candidate pool (the Section-6 recipe).
    #[must_use]
    pub fn support_size(mut self, size: usize) -> GpBuilder {
        self.support = SupportSpec::Entropy { size };
        self
    }

    /// Use this Definition-1 partition verbatim (default: seeded random
    /// even partition).
    #[must_use]
    pub fn partition(mut self, d_blocks: Vec<Vec<usize>>) -> GpBuilder {
        self.partition = PartitionSpec::Blocks(d_blocks);
        self
    }

    /// ICF rank R (required by the ICF family).
    #[must_use]
    pub fn rank(mut self, rank: usize) -> GpBuilder {
        self.rank = Some(rank);
        self
    }

    /// Host worker threads executing node work and master-side linalg
    /// (0/1 = serial; predictions are executor-independent).
    #[must_use]
    pub fn threads(mut self, threads: usize) -> GpBuilder {
        self.threads = threads;
        self
    }

    /// Seed for every stochastic choice (candidate pool, random
    /// partition).
    #[must_use]
    pub fn seed(mut self, seed: u64) -> GpBuilder {
        self.seed = seed;
        self
    }

    /// Block-math backend (default [`NativeBackend`]; pass the PJRT
    /// backend to serve from AOT artifacts).
    #[must_use]
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> GpBuilder {
        self.backend = backend;
        self
    }

    /// Share a pre-built executor (thread pool) across several fits —
    /// overrides [`GpBuilder::threads`]. The sweep harness uses this so
    /// all methods of one experiment reuse one pool.
    #[must_use]
    pub fn executor(mut self, exec: ParallelExecutor) -> GpBuilder {
        self.exec = Some(exec);
        self
    }

    /// Inject a deterministic fault plan into every cluster run made
    /// from this builder (predict protocols and training). Cluster
    /// methods then retry dropped messages, rebalance dead machines'
    /// blocks onto survivors, and report a typed
    /// [`ApiError::MachinesLost`] only when nobody survives.
    #[must_use]
    pub fn fault_plan(mut self, plan: FaultPlan) -> GpBuilder {
        self.faults = Some(plan);
        self
    }

    /// Opt the serve terminal into the mixed-precision fast path:
    /// [`GpBuilder::serve`] then also stages f32-storage /
    /// f64-accumulate operators and serves through them, trading a
    /// bounded relative error
    /// ([`crate::gp::predictor::F32_SERVE_REL_BUDGET`], asserted
    /// in-tree and re-measured by BENCH_serve) for roughly halved
    /// streaming traffic on the memory-bound predict path. Off by
    /// default; ignored by the non-serving terminals.
    #[must_use]
    pub fn mixed_precision(mut self, on: bool) -> GpBuilder {
        self.mixed_precision = on;
        self
    }

    // ------------------------------------------------------- getters

    /// The method this builder will fit.
    #[must_use]
    pub fn method_choice(&self) -> Method {
        self.method
    }

    /// The machine count this builder will use (explicit, or inferred
    /// from an explicit partition).
    #[must_use]
    pub fn machine_count(&self) -> usize {
        match self.machines {
            Some(m) => m,
            None => match &self.partition {
                PartitionSpec::Blocks(b) => b.len(),
                PartitionSpec::Random => 1,
            },
        }
    }

    /// The host thread count this builder will use.
    #[must_use]
    pub fn thread_count(&self) -> usize {
        self.threads
    }

    // ----------------------------------------------------- terminals

    /// Assemble the raw [`FitSpec`] (unresolved; fit paths resolve it).
    pub fn spec(&self) -> Result<FitSpec> {
        let hyp = self.hyp.clone().ok_or(ApiError::MissingField("hyp"))?;
        let (xd, y) = match (&self.xd, &self.y) {
            (Some(xd), Some(y)) => (xd.clone(), y.clone()),
            _ => return Err(ApiError::MissingField("data")),
        };
        Ok(FitSpec {
            method: self.method,
            hyp,
            xd,
            y,
            machines: self.machine_count(),
            support: self.support.clone(),
            partition: self.partition.clone(),
            rank: self.rank,
            threads: self.threads,
            seed: self.seed,
            backend: Arc::clone(&self.backend),
            exec: self.exec.clone(),
            faults: self.faults.clone(),
            mixed_precision: self.mixed_precision,
        })
    }

    /// Validate the spec and fit the chosen method.
    pub fn fit(&self) -> Result<Gp> {
        Gp::fit(&self.spec()?)
    }

    /// Restore a fitted model from a checkpoint file written by
    /// [`crate::api::Regressor::save`] (ignores the builder's own recipe — the
    /// checkpoint carries the full resolved spec). Corrupt or
    /// mismatched files come back as [`ApiError::Store`], never a
    /// panic.
    pub fn from_checkpoint(path: &str) -> Result<Gp> {
        Gp::load(path)
    }

    /// Fit an unboxed streaming session ([`Method::Online`] implied) so
    /// the caller keeps access to [`OnlineSession::absorb`].
    pub fn online(&self) -> Result<OnlineSession> {
        let mut spec = self.spec()?;
        spec.method = Method::Online;
        OnlineSession::fit(&spec)
    }

    /// Fit pPIC summaries packaged for request serving (router +
    /// batcher-ready [`ServedModel`]). Rejects empty data — the
    /// zero-mean-model footgun the untyped path allowed.
    pub fn serve(&self) -> Result<ServedModel> {
        let mut spec = self.spec()?;
        spec.method = Method::PPic;
        let spec = spec.resolved()?;
        let model = ServedModel::fit(&spec.hyp, &spec.xd, &spec.y,
                                     spec.support_points(), spec.blocks(),
                                     spec.backend.as_ref())?;
        Ok(if spec.mixed_precision {
            model.with_mixed_precision()
        } else {
            model
        })
    }

    /// Distributed PITC marginal-likelihood training
    /// ([`crate::train::dist::train_pitc`]) on this spec's data, support
    /// set and partition. Feed the result back through
    /// [`Gp::refit`] or a fresh build.
    pub fn train(&self, cfg: &AdamConfig) -> Result<TrainResult> {
        let mut spec = self.spec()?;
        spec.method = Method::Pitc;
        let spec = spec.resolved()?;
        let cluster = ClusterSpec {
            machines: spec.machines,
            net: crate::cluster::NetworkModel::gigabit(),
            exec: spec.executor(),
            faults: spec.faults.clone(),
        };
        if cluster.faults.is_some() {
            return try_train_pitc(&spec.hyp, &spec.xd, &spec.y,
                                  spec.support_points(), spec.blocks(),
                                  &cluster, cfg)
                .map_err(ApiError::from);
        }
        Ok(train_pitc(&spec.hyp, &spec.xd, &spec.y, spec.support_points(),
                      spec.blocks(), &cluster, cfg))
    }
}
