//! [`Regressor`] implementations: the four centralized models and the
//! cluster-backed wrappers around pPITC / pPIC / pICF / [`OnlineGp`].
//!
//! Every model is fitted from a resolved [`FitSpec`] and keeps that
//! spec, so [`Regressor::refit`] re-fits under new hyperparameters with
//! the *exact* support set, partition and executor of the original fit
//! (the [`crate::server::ServedModel::refit`] contract, generalized).

use std::sync::{Arc, Mutex, OnceLock};

use super::error::{ApiError, Result};
use super::method::Method;
use super::spec::{validate_test_partition, FitSpec, PartitionSpec,
                  PredictOutput, PredictSpec, SupportSpec};
use super::Regressor;
use crate::cluster::{Cluster, NetworkModel, ParallelExecutor};
use crate::gp::icf_gp::IcfGp;
use crate::gp::pic::PicGp;
use crate::gp::pitc::PitcGp;
use crate::gp::predictor::{icf_operator, PredictOperator};
use crate::gp::{FullGp, Prediction};
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};
use crate::parallel::online::OnlineGp;
use crate::parallel::{picf, ppic, ppitc, ClusterSpec};
use crate::runtime::NativeBackend;
use crate::server::Router;
use crate::store::{BatchCheckpoint, Checkpoint, OnlineCheckpoint,
                   StoreError};

/// Shape-check a test matrix against the training dimensionality.
fn check_xu_mat(d: usize, xu: &Mat) -> Result<()> {
    if xu.cols != d {
        return Err(ApiError::ShapeMismatch {
            what: "xu cols vs input dim",
            expected: d,
            got: xu.cols,
        });
    }
    Ok(())
}

/// Shape-check a predict spec against the training dimensionality.
fn check_xu(d: usize, ps: &PredictSpec) -> Result<()> {
    check_xu_mat(d, &ps.xu)
}

/// Contiguous even-ish split of `0..u` into `m` blocks (sizes differ by
/// at most one) — the default work distribution for methods whose
/// per-row predictions don't depend on the test partition.
fn contiguous_blocks(u: usize, m: usize) -> Vec<Vec<usize>> {
    let base = u / m;
    let rem = u % m;
    let mut out = Vec::with_capacity(m);
    let mut next = 0;
    for k in 0..m {
        let len = base + usize::from(k < rem);
        out.push((next..next + len).collect());
        next += len;
    }
    out
}

/// Route each test row to the machine with the nearest local-data
/// centroid (the serving scheme) — the default test partition for the
/// PIC family, whose local term feeds on co-location.
fn routed_blocks(router: &Router, xu: &Mat) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); router.machines()];
    for (i, m) in router.route_all(xu).into_iter().enumerate() {
        out[m].push(i);
    }
    out
}

/// The fast-predict recipe for operator sets without a centralized
/// model to delegate to ([`OnlineSession`]'s streamed state): route
/// rows to machines, run each machine's staged operator on its slice,
/// scatter back to input order.
fn routed_fast_predict(
    ops: &[PredictOperator],
    router: &Router,
    lctx: &LinalgCtx,
    xu: &Mat,
) -> Prediction {
    let u_blocks = routed_blocks(router, xu);
    let preds: Vec<Prediction> = u_blocks
        .iter()
        .enumerate()
        .map(|(m, blk)| ops[m].predict_ctx(lctx, &xu.select_rows(blk)))
        .collect();
    Prediction::scatter(&preds, &u_blocks, xu.rows)
}

/// Resolve the test partition: explicit blocks are validated; otherwise
/// route by centroid (when a router is available) or split contiguously.
fn resolve_u_blocks(
    ps: &PredictSpec,
    machines: usize,
    router: Option<&Router>,
) -> Result<Vec<Vec<usize>>> {
    match &ps.u_blocks {
        Some(blocks) => {
            validate_test_partition(blocks, ps.xu.rows, machines)?;
            Ok(blocks.clone())
        }
        None => Ok(match router {
            Some(r) => routed_blocks(r, &ps.xu),
            None => contiguous_blocks(ps.xu.rows, machines),
        }),
    }
}

/// Resolve + pool-share the spec: models keep the resolved spec with
/// `exec` pinned so refits and repeated predicts reuse one thread pool.
fn prepared(spec: &FitSpec) -> Result<(FitSpec, ParallelExecutor)> {
    let mut spec = spec.resolved()?;
    let exec = spec.executor();
    spec.exec = Some(exec.clone());
    Ok((spec, exec))
}

fn cluster_of(spec: &FitSpec, exec: &ParallelExecutor) -> ClusterSpec {
    ClusterSpec {
        machines: spec.machines,
        net: NetworkModel::gigabit(),
        exec: exec.clone(),
        faults: spec.faults.clone(),
    }
}

fn refit_of<T: Regressor + 'static>(spec: &FitSpec, hyp: &SeArd)
    -> Result<Box<dyn Regressor>>
{
    let mut s = spec.clone();
    s.hyp = hyp.clone();
    Ok(Box::new(T::fit(&s)?))
}

/// Checkpoint a batch model: the *resolved fit ingredients* go to disk
/// (hyperparameters, data, materialized support/partition, rank,
/// threads, seed, precision mode), not the fitted factors — fitting
/// from a resolved spec is bitwise-reproducible, so rerunning the
/// deterministic fit on load reproduces the model exactly while the
/// file format stays independent of internal factor layouts.
fn batch_checkpoint(spec: &FitSpec, method: Method) -> Checkpoint {
    Checkpoint::Batch(BatchCheckpoint {
        method,
        hyp: spec.hyp.clone(),
        xd: spec.xd.clone(),
        y: spec.y.clone(),
        machines: spec.machines,
        support: match &spec.support {
            SupportSpec::Points(xs) => Some(xs.clone()),
            _ => None,
        },
        partition: match &spec.partition {
            PartitionSpec::Blocks(b) => Some(b.clone()),
            PartitionSpec::Random => None,
        },
        rank: spec.rank,
        threads: spec.threads,
        seed: spec.seed,
        mixed_precision: spec.mixed_precision,
    })
}

/// Rebuild the fit spec a [`BatchCheckpoint`] describes (native
/// backend, no fault plan — persistence captures the model, not the
/// chaos harness around it).
pub(crate) fn spec_of_batch(ck: &BatchCheckpoint) -> FitSpec {
    FitSpec {
        method: ck.method,
        hyp: ck.hyp.clone(),
        xd: ck.xd.clone(),
        y: ck.y.clone(),
        machines: ck.machines,
        support: match &ck.support {
            Some(xs) => SupportSpec::Points(xs.clone()),
            None => SupportSpec::Unset,
        },
        partition: match &ck.partition {
            Some(b) => PartitionSpec::Blocks(b.clone()),
            None => PartitionSpec::Random,
        },
        rank: ck.rank,
        threads: ck.threads,
        seed: ck.seed,
        backend: Arc::new(NativeBackend),
        exec: None,
        faults: None,
        mixed_precision: ck.mixed_precision,
    }
}

// ------------------------------------------------------- centralized

/// Exact full GP behind the facade.
pub struct FgpModel {
    spec: FitSpec,
    gp: FullGp,
    exec: ParallelExecutor,
}

impl Regressor for FgpModel {
    fn fit(spec: &FitSpec) -> Result<FgpModel> {
        let (spec, exec) = prepared(spec)?;
        let gp = FullGp::try_fit_ctx(&exec.linalg_ctx(), &spec.hyp,
                                     &spec.xd, &spec.y)
            .map_err(|e| ApiError::not_spd("Σ_DD", &e))?;
        Ok(FgpModel { spec, gp, exec })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let p = self.gp.predict_ctx(&self.exec.linalg_ctx(), &ps.xu);
        Ok(PredictOutput { prediction: p, metrics: None })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        Ok(self.gp.predict_fast_ctx(&self.exec.linalg_ctx(), xu))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<FgpModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::Fgp))
    }

    fn machines(&self) -> usize {
        1
    }

    fn method(&self) -> Method {
        Method::Fgp
    }
}

/// Centralized PITC behind the facade.
pub struct PitcModel {
    spec: FitSpec,
    gp: PitcGp,
    exec: ParallelExecutor,
}

impl Regressor for PitcModel {
    fn fit(spec: &FitSpec) -> Result<PitcModel> {
        let (spec, exec) = prepared(spec)?;
        let gp = PitcGp::try_fit_ctx(&exec.linalg_ctx(), &spec.hyp,
                                     &spec.xd, &spec.y,
                                     spec.support_points(), spec.blocks())
            .map_err(|e| ApiError::not_spd("PITC covariance", &e))?;
        Ok(PitcModel { spec, gp, exec })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let p = self.gp.predict_ctx(&self.exec.linalg_ctx(), &ps.xu);
        Ok(PredictOutput { prediction: p, metrics: None })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        Ok(self.gp.predict_fast_ctx(&self.exec.linalg_ctx(), xu))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<PitcModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::Pitc))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::Pitc
    }
}

/// Centralized PIC behind the facade: block predictions tied to the
/// test partition (explicit via [`PredictSpec::with_blocks`], else
/// routed by nearest local-data centroid).
pub struct PicModel {
    spec: FitSpec,
    gp: PicGp,
    router: Router,
    exec: ParallelExecutor,
}

impl Regressor for PicModel {
    fn fit(spec: &FitSpec) -> Result<PicModel> {
        let (spec, exec) = prepared(spec)?;
        let gp = PicGp::try_fit_ctx(&exec.linalg_ctx(), &spec.hyp,
                                    &spec.xd, &spec.y,
                                    spec.support_points(), spec.blocks())
            .map_err(|e| ApiError::not_spd("PIC covariance", &e))?;
        let xms: Vec<Mat> =
            spec.blocks().iter().map(|b| spec.xd.select_rows(b)).collect();
        let refs: Vec<&Mat> = xms.iter().collect();
        let router = Router::from_blocks(&spec.hyp, &refs);
        Ok(PicModel { spec, gp, router, exec })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let u_blocks =
            resolve_u_blocks(ps, self.spec.machines, Some(&self.router))?;
        let p = self.gp.predict_ctx(&self.exec.linalg_ctx(), &ps.xu,
                                    &u_blocks);
        Ok(PredictOutput { prediction: p, metrics: None })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        let u_blocks = routed_blocks(&self.router, xu);
        Ok(self.gp.predict_fast_ctx(&self.exec.linalg_ctx(), xu,
                                    &u_blocks))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<PicModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::Pic))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::Pic
    }
}

/// Centralized ICF-based GP behind the facade.
///
/// ICF's pivoted factorization stops early instead of failing on a
/// non-SPD Gram matrix, so (unlike FGP/PITC/PIC) fit has no `NotSpd`
/// path; the R×R Φ solve at predict time keeps the legacy panic on
/// degenerate hyperparameters.
pub struct IcfModel {
    spec: FitSpec,
    gp: IcfGp,
    exec: ParallelExecutor,
}

impl Regressor for IcfModel {
    fn fit(spec: &FitSpec) -> Result<IcfModel> {
        let (spec, exec) = prepared(spec)?;
        let rank = spec.rank.expect("resolved spec has rank");
        let gp = IcfGp::fit_ctx(&exec.linalg_ctx(), &spec.hyp, &spec.xd,
                                &spec.y, rank, spec.blocks());
        Ok(IcfModel { spec, gp, exec })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let p = self.gp.predict_ctx(&self.exec.linalg_ctx(), &ps.xu);
        Ok(PredictOutput { prediction: p, metrics: None })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        Ok(self.gp.predict_fast_ctx(&self.exec.linalg_ctx(), xu))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<IcfModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::Icf))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::Icf
    }
}

// -------------------------------------------------------- distributed

/// pPITC behind the facade. `fit` stages the distributed state (the
/// protocol's Step 1 "data already distributed" assumption); every
/// `predict` executes Steps 2–4 over the simulated cluster and returns
/// the run's [`crate::cluster::RunMetrics`]. [`Regressor::predict_fast`]
/// instead serves from the staged centralized model (built on first
/// use — the same Steps 1–3 math by Theorem 1, rebuilt by `refit`),
/// skipping the cluster simulation entirely.
pub struct PPitcModel {
    spec: FitSpec,
    cluster: ClusterSpec,
    staged: OnceLock<PitcGp>,
}

impl PPitcModel {
    /// The staged serve-path model (first use builds it; a refit
    /// constructs a fresh facade model, restaging under the new
    /// hypers). Theorem 1 makes [`PitcGp`] the exact centralized form
    /// of the protocol, so delegating keeps the staging recipe in one
    /// place (`gp/pitc.rs`).
    fn staged_gp(&self) -> &PitcGp {
        self.staged.get_or_init(|| {
            PitcGp::fit_ctx(&self.cluster.exec.linalg_ctx(),
                            &self.spec.hyp, &self.spec.xd, &self.spec.y,
                            self.spec.support_points(), self.spec.blocks())
        })
    }
}

impl Regressor for PPitcModel {
    fn fit(spec: &FitSpec) -> Result<PPitcModel> {
        let (spec, exec) = prepared(spec)?;
        let cluster = cluster_of(&spec, &exec);
        Ok(PPitcModel { spec, cluster, staged: OnceLock::new() })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let u_blocks = resolve_u_blocks(ps, self.spec.machines, None)?;
        let out = if self.cluster.faults.is_some() {
            ppitc::try_run(&self.spec.hyp, &self.spec.xd, &self.spec.y,
                           self.spec.support_points(), &ps.xu,
                           self.spec.blocks(), &u_blocks,
                           self.spec.backend.as_ref(), &self.cluster)
                .map_err(ApiError::from)?
                .output
        } else {
            ppitc::run(&self.spec.hyp, &self.spec.xd, &self.spec.y,
                       self.spec.support_points(), &ps.xu,
                       self.spec.blocks(), &u_blocks,
                       self.spec.backend.as_ref(), &self.cluster)
        };
        Ok(PredictOutput {
            prediction: out.prediction,
            metrics: Some(out.metrics),
        })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        Ok(self.staged_gp()
            .predict_fast_ctx(&self.cluster.exec.linalg_ctx(), xu))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<PPitcModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::PPitc))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::PPitc
    }
}

/// pPIC behind the facade (fixed Definition-1 partition; the protocol's
/// clustering scheme stays available through [`crate::parallel::ppic`]).
/// [`Regressor::predict_fast`] serves from the staged centralized
/// model's per-machine Definition-5 operators (built on first use —
/// Theorem 2 makes [`PicGp`] the protocol's exact centralized form —
/// rebuilt by `refit`), routing test rows by nearest local-data
/// centroid like the default predict path.
pub struct PPicModel {
    spec: FitSpec,
    cluster: ClusterSpec,
    router: Router,
    staged: OnceLock<PicGp>,
}

impl PPicModel {
    fn staged_gp(&self) -> &PicGp {
        self.staged.get_or_init(|| {
            PicGp::fit_ctx(&self.cluster.exec.linalg_ctx(),
                           &self.spec.hyp, &self.spec.xd, &self.spec.y,
                           self.spec.support_points(), self.spec.blocks())
        })
    }
}

impl Regressor for PPicModel {
    fn fit(spec: &FitSpec) -> Result<PPicModel> {
        let (spec, exec) = prepared(spec)?;
        let cluster = cluster_of(&spec, &exec);
        let xms: Vec<Mat> =
            spec.blocks().iter().map(|b| spec.xd.select_rows(b)).collect();
        let refs: Vec<&Mat> = xms.iter().collect();
        let router = Router::from_blocks(&spec.hyp, &refs);
        Ok(PPicModel { spec, cluster, router, staged: OnceLock::new() })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let u_blocks =
            resolve_u_blocks(ps, self.spec.machines, Some(&self.router))?;
        let out = if self.cluster.faults.is_some() {
            ppic::try_run_with_partition(
                &self.spec.hyp, &self.spec.xd, &self.spec.y,
                self.spec.support_points(), &ps.xu, self.spec.blocks(),
                &u_blocks, self.spec.backend.as_ref(), &self.cluster)
                .map_err(ApiError::from)?
                .output
        } else {
            ppic::run_with_partition(
                &self.spec.hyp, &self.spec.xd, &self.spec.y,
                self.spec.support_points(), &ps.xu, self.spec.blocks(),
                &u_blocks, self.spec.backend.as_ref(), &self.cluster)
        };
        Ok(PredictOutput {
            prediction: out.prediction,
            metrics: Some(out.metrics),
        })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        let u_blocks = routed_blocks(&self.router, xu);
        Ok(self.staged_gp().predict_fast_ctx(
            &self.cluster.exec.linalg_ctx(), xu, &u_blocks))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<PPicModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::PPic))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::PPic
    }
}

/// pICF-based GP behind the facade. Step 5 has every machine scan all
/// of U, so `u_blocks` carries no information here and is ignored.
/// [`Regressor::predict_fast`] serves from a staged low-rank operator
/// built from the *same* row-based parallel ICF factor the protocol
/// computes (so the two paths share the factor exactly), collapsing
/// Definitions 7–9 into one GEMV + a rank-R correction.
pub struct PIcfModel {
    spec: FitSpec,
    cluster: ClusterSpec,
    staged: OnceLock<PredictOperator>,
}

impl PIcfModel {
    fn staged_op(&self) -> &PredictOperator {
        self.staged.get_or_init(|| {
            let lctx = self.cluster.exec.linalg_ctx();
            let rank = self.spec.rank.expect("resolved spec has rank");
            let blocks = self.spec.blocks();
            // Step 2 on an inert cluster: identical slabs to the
            // protocol run, no metrics side effects.
            let mut cluster =
                Cluster::new(self.spec.machines, NetworkModel::instant());
            let slabs = picf::parallel_icf(&self.spec.hyp, &self.spec.xd,
                                           blocks, rank, &mut cluster);
            let y = &self.spec.y;
            let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
            let data: Vec<(Mat, Vec<f64>)> = blocks
                .iter()
                .map(|blk| {
                    let xm = self.spec.xd.select_rows(blk);
                    let ym: Vec<f64> =
                        blk.iter().map(|&i| y[i] - y_mean).collect();
                    (xm, ym)
                })
                .collect();
            let refs: Vec<(&Mat, &[f64], &Mat)> = data
                .iter()
                .zip(slabs.iter())
                .map(|((xm, ym), f_m)| (xm, ym.as_slice(), f_m))
                .collect();
            icf_operator(&lctx, &self.spec.hyp, &refs, y_mean)
        })
    }
}

impl Regressor for PIcfModel {
    fn fit(spec: &FitSpec) -> Result<PIcfModel> {
        let (spec, exec) = prepared(spec)?;
        let cluster = cluster_of(&spec, &exec);
        Ok(PIcfModel { spec, cluster, staged: OnceLock::new() })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let rank = self.spec.rank.expect("resolved spec has rank");
        let out = if self.cluster.faults.is_some() {
            picf::try_run(&self.spec.hyp, &self.spec.xd, &self.spec.y,
                          &ps.xu, self.spec.blocks(), rank,
                          self.spec.backend.as_ref(), &self.cluster)
                .map_err(ApiError::from)?
                .output
        } else {
            picf::run(&self.spec.hyp, &self.spec.xd, &self.spec.y,
                      &ps.xu, self.spec.blocks(), rank,
                      self.spec.backend.as_ref(), &self.cluster)
        };
        Ok(PredictOutput {
            prediction: out.prediction,
            metrics: Some(out.metrics),
        })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        Ok(self.staged_op()
            .predict_ctx(&self.cluster.exec.linalg_ctx(), xu))
    }

    fn refit(&self, hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        refit_of::<PIcfModel>(&self.spec, hyp)
    }

    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(batch_checkpoint(&self.spec, Method::PIcf))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::PIcf
    }
}

// ------------------------------------------------------------- online

/// Streaming §5.2 session behind the facade: `fit` absorbs the spec's
/// data as the first batch, [`OnlineSession::absorb`] streams more in,
/// and predictions are pPIC-flavored (each machine's local term is its
/// latest block). Obtain one with [`crate::api::GpBuilder::online`], or
/// drive it boxed through the [`Regressor`] trait like any other method.
pub struct OnlineSession {
    spec: FitSpec,
    gp: OnlineGp,
    latest_inputs: Vec<Mat>,
    /// Cached nearest-centroid router over `latest_inputs`; rebuilt only
    /// when an absorb changes the machines' latest blocks.
    router: Router,
    /// Staged per-machine serve-path operators over the *current*
    /// summaries; invalidated by every absorb, rebuilt on the next
    /// [`Regressor::predict_fast`].
    staged: StagedOnlineOps,
}

/// The online session's restageable operator cache: absorb drops it,
/// the next fast predict rebuilds it (shared so the lock is not held
/// across the prediction itself).
type StagedOnlineOps = Mutex<Option<Arc<Vec<PredictOperator>>>>;

impl OnlineSession {
    /// Absorb one batch (`blocks[m]` = machine m's new inputs/outputs).
    /// Returns the simulated makespan of the absorb round.
    pub fn absorb(&mut self, blocks: &[(Mat, Vec<f64>)]) -> Result<f64> {
        if blocks.len() != self.spec.machines {
            return Err(ApiError::ShapeMismatch {
                what: "batch blocks vs machines",
                expected: self.spec.machines,
                got: blocks.len(),
            });
        }
        for (m, (xm, ym)) in blocks.iter().enumerate() {
            if xm.rows == 0 {
                return Err(ApiError::EmptyPartition { machine: m });
            }
            if xm.rows != ym.len() {
                return Err(ApiError::ShapeMismatch {
                    what: "batch y length vs rows",
                    expected: xm.rows,
                    got: ym.len(),
                });
            }
            if xm.cols != self.spec.xd.cols {
                return Err(ApiError::ShapeMismatch {
                    what: "batch cols vs input dim",
                    expected: self.spec.xd.cols,
                    got: xm.cols,
                });
            }
        }
        for (m, (xm, _)) in blocks.iter().enumerate() {
            self.latest_inputs[m] = xm.clone();
        }
        self.router = router_over(&self.spec.hyp, &self.latest_inputs);
        // the summaries are about to change: drop the staged operators
        *self.staged.lock().unwrap() = None;
        Ok(self.gp.absorb(blocks))
    }

    /// Batches absorbed so far.
    #[must_use]
    pub fn batches(&self) -> usize {
        self.gp.batches
    }

    /// Cumulative simulated seconds spent absorbing.
    #[must_use]
    pub fn absorb_makespan(&self) -> f64 {
        self.gp.absorb_makespan
    }

    /// Rebuild a session from a decoded [`OnlineCheckpoint`]: the fit
    /// spec is reconstructed from the stored ingredients, the
    /// [`OnlineGp`] stream state is restored verbatim (its support
    /// context recomputed with the same execution context `absorb`
    /// uses), and the router is rebuilt over the restored latest
    /// blocks. Absorbing the remaining batches afterwards is
    /// bitwise-identical to a process that never stopped; structural
    /// inconsistencies in a crafted checkpoint surface as typed
    /// [`ApiError::Store`] values, never a panic.
    pub fn from_checkpoint(ck: OnlineCheckpoint) -> Result<OnlineSession> {
        let corrupt = |reason: String| {
            ApiError::Store(StoreError::Corrupt { section: "latest", reason })
        };
        if ck.y_mean.is_none() || ck.global.is_none() || ck.l_g.is_none() {
            return Err(ApiError::Store(StoreError::Corrupt {
                section: "stream",
                reason: "session checkpoint has no absorbed state".into(),
            }));
        }
        let d = ck.xd.cols;
        if ck.support.cols != d {
            return Err(ApiError::Store(StoreError::Corrupt {
                section: "support",
                reason: format!(
                    "support cols {} != input dim {d}",
                    ck.support.cols
                ),
            }));
        }
        let mut latest_inputs = Vec::with_capacity(ck.latest.len());
        for (m, slot) in ck.latest.iter().enumerate() {
            let Some((xm, ym, _)) = slot else {
                return Err(corrupt(format!("machine {m} has no block")));
            };
            if xm.cols != d {
                return Err(corrupt(format!(
                    "machine {m} block cols {} != input dim {d}",
                    xm.cols
                )));
            }
            if xm.rows == 0 || xm.rows != ym.len() {
                return Err(corrupt(format!(
                    "machine {m} block has {} rows but {} targets",
                    xm.rows,
                    ym.len()
                )));
            }
            latest_inputs.push(xm.clone());
        }
        let (spec, exec) = prepared(&spec_of_batch(&BatchCheckpoint {
            method: Method::Online,
            hyp: ck.hyp.clone(),
            xd: ck.xd.clone(),
            y: ck.y.clone(),
            machines: ck.machines,
            support: Some(ck.support.clone()),
            partition: Some(ck.partition.clone()),
            rank: None,
            threads: ck.threads,
            seed: ck.seed,
            mixed_precision: ck.mixed_precision,
        }))?;
        let cluster = cluster_of(&spec, &exec);
        let gp = OnlineGp::restore(
            &spec.hyp,
            &ck.support,
            Arc::clone(&spec.backend),
            cluster,
            ck.y_mean,
            ck.global,
            ck.l_g,
            ck.latest,
            ck.batches,
        )
        .map_err(|e| ApiError::not_spd("Σ_SS", &e))?;
        let router = router_over(&spec.hyp, &latest_inputs);
        Ok(OnlineSession {
            spec,
            gp,
            latest_inputs,
            router,
            staged: Mutex::new(None),
        })
    }
}

/// Nearest-centroid router over a set of machine blocks.
fn router_over(hyp: &SeArd, blocks: &[Mat]) -> Router {
    let refs: Vec<&Mat> = blocks.iter().collect();
    Router::from_blocks(hyp, &refs)
}

impl Regressor for OnlineSession {
    fn fit(spec: &FitSpec) -> Result<OnlineSession> {
        let (spec, exec) = prepared(spec)?;
        let cluster = cluster_of(&spec, &exec);
        let mut gp = OnlineGp::new(&spec.hyp, spec.support_points(),
                                   Arc::clone(&spec.backend), cluster);
        let blocks: Vec<(Mat, Vec<f64>)> = spec
            .blocks()
            .iter()
            .map(|blk| {
                let xm = spec.xd.select_rows(blk);
                let ym: Vec<f64> = blk.iter().map(|&i| spec.y[i]).collect();
                (xm, ym)
            })
            .collect();
        gp.absorb(&blocks);
        let latest_inputs: Vec<Mat> =
            blocks.into_iter().map(|(xm, _)| xm).collect();
        let router = router_over(&spec.hyp, &latest_inputs);
        Ok(OnlineSession {
            spec,
            gp,
            latest_inputs,
            router,
            staged: Mutex::new(None),
        })
    }

    fn predict_unpadded(&self, ps: &PredictSpec) -> Result<PredictOutput> {
        check_xu(self.spec.xd.cols, ps)?;
        let u_blocks =
            resolve_u_blocks(ps, self.spec.machines, Some(&self.router))?;
        let out = self.gp.predict_ppic(&ps.xu, &u_blocks);
        Ok(PredictOutput {
            prediction: out.prediction,
            metrics: Some(out.metrics),
        })
    }

    fn predict_fast(&self, xu: &Mat) -> Result<Prediction> {
        check_xu_mat(self.spec.xd.cols, xu)?;
        let lctx = self.spec.executor().linalg_ctx();
        let ops = {
            let mut guard = self.staged.lock().unwrap();
            if guard.is_none() {
                *guard = Some(Arc::new(self.gp.machine_operators(&lctx)));
            }
            Arc::clone(guard.as_ref().unwrap())
        };
        Ok(routed_fast_predict(&ops, &self.router, &lctx, xu))
    }

    /// An online session accumulates streamed state that a refit cannot
    /// reconstruct — rebuild via the builder instead.
    fn refit(&self, _hyp: &SeArd) -> Result<Box<dyn Regressor>> {
        Err(ApiError::Unsupported("refit of an online session"))
    }

    /// Mid-stream snapshot: fit ingredients + the assimilated summaries
    /// and every machine's latest block. Restore with
    /// [`OnlineSession::from_checkpoint`] and keep absorbing — the
    /// stream continues bitwise as if the process never stopped.
    fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(Checkpoint::Online(OnlineCheckpoint {
            hyp: self.spec.hyp.clone(),
            xd: self.spec.xd.clone(),
            y: self.spec.y.clone(),
            machines: self.spec.machines,
            support: self.spec.support_points().clone(),
            partition: self.spec.blocks().to_vec(),
            threads: self.spec.threads,
            seed: self.spec.seed,
            mixed_precision: self.spec.mixed_precision,
            y_mean: self.gp.stream_y_mean(),
            global: self.gp.stream_global().cloned(),
            l_g: self.gp.stream_l_g().cloned(),
            latest: self.gp.stream_latest().to_vec(),
            batches: self.gp.batches,
        }))
    }

    fn machines(&self) -> usize {
        self.spec.machines
    }

    fn method(&self) -> Method {
        Method::Online
    }
}
