//! Runtime method selection: every GP regression method in the crate as
//! one enum, so the server, CLI, benches and tests pick an algorithm
//! with a value instead of a type.

/// The regression methods behind the facade: the exact baseline, the
/// three centralized low-rank approximations (Sections 2–4), their three
/// distributed reformulations (Theorems 1–3), and the §5.2 online
/// assimilation mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// pPITC — Section 3, Steps 1–4 over the cluster.
    PPitc,
    /// pPIC — Definition 5 over the cluster.
    PPic,
    /// pICF-based GP — Section 4, Steps 1–6 over the cluster.
    PIcf,
    /// Centralized PITC (eqs. 9–11).
    Pitc,
    /// Centralized PIC (eqs. 15–18).
    Pic,
    /// Centralized ICF-based GP (eqs. 28–29).
    Icf,
    /// Exact full GP (eqs. 1–2) — the accuracy anchor.
    Fgp,
    /// Online/incremental pPIC (§5.2): fit absorbs the data as the
    /// first batch; more batches stream in through
    /// [`crate::api::OnlineSession::absorb`].
    Online,
}

impl Method {
    /// Display name matching the paper's terminology.
    #[must_use]
    pub fn name(self) -> &'static str {
        match self {
            Method::PPitc => "pPITC",
            Method::PPic => "pPIC",
            Method::PIcf => "pICF",
            Method::Pitc => "PITC",
            Method::Pic => "PIC",
            Method::Icf => "ICF",
            Method::Fgp => "FGP",
            Method::Online => "online",
        }
    }

    /// Parse a CLI-style method name (case-insensitive).
    #[must_use]
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "ppitc" => Some(Method::PPitc),
            "ppic" => Some(Method::PPic),
            "picf" => Some(Method::PIcf),
            "pitc" => Some(Method::Pitc),
            "pic" => Some(Method::Pic),
            "icf" => Some(Method::Icf),
            "fgp" => Some(Method::Fgp),
            "online" => Some(Method::Online),
            _ => None,
        }
    }

    /// The seven batch methods of Section 6 (the experiment default;
    /// excludes [`Method::Online`], which is a streaming mode).
    pub const ALL: [Method; 7] = [
        Method::PPitc, Method::PPic, Method::PIcf,
        Method::Pitc, Method::Pic, Method::Icf, Method::Fgp,
    ];

    /// The three distributed protocols.
    pub const PARALLEL: [Method; 3] =
        [Method::PPitc, Method::PPic, Method::PIcf];

    /// True for the cluster-backed methods (including online).
    #[must_use]
    pub fn is_parallel(self) -> bool {
        matches!(self,
                 Method::PPitc | Method::PPic | Method::PIcf | Method::Online)
    }

    /// True when the method conditions on a support set S.
    #[must_use]
    pub fn needs_support(self) -> bool {
        matches!(self,
                 Method::Pitc | Method::Pic | Method::PPitc | Method::PPic
                     | Method::Online)
    }

    /// True when the method needs an ICF rank R.
    #[must_use]
    pub fn needs_rank(self) -> bool {
        matches!(self, Method::Icf | Method::PIcf)
    }

    /// True when the method needs a Definition-1 data partition.
    #[must_use]
    pub fn needs_partition(self) -> bool {
        self != Method::Fgp
    }

    /// A parallel method's centralized counterpart (Theorems 1–3).
    #[must_use]
    pub fn centralized_counterpart(self) -> Option<Method> {
        match self {
            Method::PPitc => Some(Method::Pitc),
            Method::PPic | Method::Online => Some(Method::Pic),
            Method::PIcf => Some(Method::Icf),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m), "{:?}", m);
        }
        assert_eq!(Method::parse("online"), Some(Method::Online));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn flags_are_consistent() {
        for m in Method::PARALLEL {
            assert!(m.is_parallel());
            assert!(m.centralized_counterpart().is_some());
        }
        assert!(!Method::Fgp.needs_partition());
        assert!(Method::Icf.needs_rank() && Method::PIcf.needs_rank());
        assert!(Method::Online.needs_support());
        assert!(!Method::Fgp.needs_support() && !Method::Icf.needs_support());
    }
}
