//! The paper's Section 6.1 performance metrics: RMSE, MNLP, incurred
//! time, and speedup.

/// Root mean square error: sqrt(|U|⁻¹ Σ (y - μ)²).
pub fn rmse(y_true: &[f64], mean: &[f64]) -> f64 {
    assert_eq!(y_true.len(), mean.len());
    assert!(!y_true.is_empty());
    let s: f64 = y_true
        .iter()
        .zip(mean.iter())
        .map(|(y, m)| (y - m) * (y - m))
        .sum();
    (s / y_true.len() as f64).sqrt()
}

/// Mean negative log probability:
/// 0.5·|U|⁻¹ Σ ((y-μ)²/σ² + log(2πσ²)).
///
/// Negative *variances* (possible for pICF with too-small rank R — the
/// paper's Remark 2 after Theorem 3) make the log undefined; following
/// the paper's plots (which show "negative MNLP" pathologies), we clamp
/// σ² at a tiny positive floor and let the metric blow up rather than
/// NaN, so the pathology is visible in the curves.
pub fn mnlp(y_true: &[f64], mean: &[f64], var: &[f64]) -> f64 {
    assert_eq!(y_true.len(), mean.len());
    assert_eq!(y_true.len(), var.len());
    assert!(!y_true.is_empty());
    let two_pi = 2.0 * std::f64::consts::PI;
    let s: f64 = (0..y_true.len())
        .map(|i| {
            let v = var[i].max(1e-300);
            let d = y_true[i] - mean[i];
            d * d / v + (two_pi * v).ln()
        })
        .sum();
    0.5 * s / y_true.len() as f64
}

/// Fraction of predictive variances that are non-positive (the pICF
/// pathology indicator).
pub fn frac_nonpositive_var(var: &[f64]) -> f64 {
    if var.is_empty() {
        return 0.0;
    }
    var.iter().filter(|&&v| v <= 0.0).count() as f64 / var.len() as f64
}

/// Speedup of a parallel run over its centralized counterpart
/// (Section 6.1(d)); ideal speedup is the machine count M.
pub fn speedup(centralized_secs: f64, parallel_secs: f64) -> f64 {
    assert!(parallel_secs > 0.0);
    centralized_secs / parallel_secs
}

/// Efficiency = speedup / M ∈ (0, 1] against ideal.
pub fn efficiency(centralized_secs: f64, parallel_secs: f64, m: usize) -> f64 {
    speedup(centralized_secs, parallel_secs) / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn rmse_known_values() {
        assert_close(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0, 0.0, 1e-15);
        assert_close(rmse(&[0.0, 0.0], &[3.0, 4.0]),
                     (12.5f64).sqrt(), 1e-12, 0.0);
    }

    #[test]
    fn mnlp_perfect_prediction_depends_on_variance() {
        // exact mean: MNLP = 0.5·log(2πσ²); smaller σ is better
        let tight = mnlp(&[1.0], &[1.0], &[0.01]);
        let loose = mnlp(&[1.0], &[1.0], &[1.0]);
        assert!(tight < loose);
        assert_close(loose, 0.5 * (2.0 * std::f64::consts::PI).ln(), 1e-12, 0.0);
    }

    #[test]
    fn mnlp_penalizes_overconfidence() {
        // wrong mean with tiny variance must be much worse than with
        // honest variance
        let overconfident = mnlp(&[0.0], &[3.0], &[1e-4]);
        let honest = mnlp(&[0.0], &[3.0], &[9.0]);
        assert!(overconfident > honest);
    }

    #[test]
    fn mnlp_survives_nonpositive_variance() {
        let v = mnlp(&[0.0], &[0.0], &[-1.0]);
        assert!(v.is_finite() || v == f64::INFINITY);
        assert_eq!(frac_nonpositive_var(&[-1.0, 0.5, 0.0]), 2.0 / 3.0);
    }

    #[test]
    fn speedup_and_efficiency() {
        assert_close(speedup(10.0, 2.0), 5.0, 1e-15, 0.0);
        assert_close(efficiency(10.0, 2.0, 10), 0.5, 1e-15, 0.0);
    }

    #[test]
    #[should_panic]
    fn rmse_length_mismatch() {
        rmse(&[1.0], &[1.0, 2.0]);
    }
}
