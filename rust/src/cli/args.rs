//! Minimal `--key value` / `--flag` argument parser.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed flags: `--key value` pairs and bare `--flag`s.
#[derive(Debug, Clone, Default)]
pub struct Args {
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            if key.is_empty() {
                bail!("bare '--' not supported");
            }
            // `--key=value` or `--key value` or bare flag
            if let Some((k, v)) = key.split_once('=') {
                out.kv.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.kv.insert(key.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                out.flags.push(key.to_string());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.kv.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad number '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    /// Comma-separated list value.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default()
    }
}

// ---------------------------------------------------------------------
// Loose process-argv scanning for bench mains.
//
// The `cargo bench` harness passes its own flags (`--bench`) through to
// bench mains, so they cannot use the strict `Args::parse` (which
// rejects unknown positionals); instead they scan argv loosely for the
// two conventions every bench main shares: `--key=value` (equals form
// only) and "first non-dash argument is the output path". These
// scanners are that convention in one place — the telemetry PR
// copy-pasted both across three bench mains.
// ---------------------------------------------------------------------

/// Scan an argv iterator for `--key=value` (equals form only); first
/// match wins. Pure core of [`process_eq`], testable without touching
/// the real process args.
pub fn scan_eq<I>(argv: I, key: &str) -> Option<String>
where
    I: IntoIterator<Item = String>,
{
    let prefix = format!("--{key}=");
    argv.into_iter()
        .find_map(|a| a.strip_prefix(prefix.as_str()).map(String::from))
}

/// [`scan_eq`] over this process's arguments (program name skipped).
pub fn process_eq(key: &str) -> Option<String> {
    scan_eq(std::env::args().skip(1), key)
}

/// Scan an argv iterator for the first argument that does not start
/// with `-` (the bench mains' "first real arg = output path"
/// convention, which skips cargo-bench's `--bench` flag), falling back
/// to `default`. Pure core of [`process_out_path`].
pub fn scan_out_path<I>(argv: I, default: &str) -> String
where
    I: IntoIterator<Item = String>,
{
    argv.into_iter()
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| default.to_string())
}

/// [`scan_out_path`] over this process's arguments.
pub fn process_out_path(default: &str) -> String {
    scan_out_path(std::env::args().skip(1), default)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&sv(&["--n", "100", "--learn", "--domain=sarcos"]))
            .unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 100);
        assert!(a.flag("learn"));
        assert_eq!(a.get("domain"), Some("sarcos"));
        assert_eq!(a.str_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["oops"])).is_err());
    }

    #[test]
    fn numeric_errors() {
        let a = Args::parse(&sv(&["--n", "abc"])).unwrap();
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.f64_or("n", 0.0).is_err());
    }

    #[test]
    fn list_values() {
        let a = Args::parse(&sv(&["--methods", "ppic, fgp,pitc"])).unwrap();
        assert_eq!(a.list("methods"), vec!["ppic", "fgp", "pitc"]);
        assert!(a.list("nothing").is_empty());
    }

    #[test]
    fn negative_number_as_value() {
        let a = Args::parse(&sv(&["--lr", "-0.5"])).unwrap();
        // "-0.5" doesn't start with --, so it's a value
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn scan_eq_equals_form_only() {
        // equals form is found, space form is (deliberately) not: the
        // bench mains' positional out-path scan must keep seeing the
        // value as a positional
        let argv = sv(&["--bench", "--telemetry-out=t.json", "out.json"]);
        assert_eq!(scan_eq(argv.clone(), "telemetry-out"),
                   Some("t.json".to_string()));
        assert_eq!(scan_eq(sv(&["--telemetry-out", "t.json"]),
                           "telemetry-out"),
                   None);
        // first match wins
        assert_eq!(scan_eq(sv(&["--k=a", "--k=b"]), "k"),
                   Some("a".to_string()));
        // a key that is a prefix of another must not match it
        assert_eq!(scan_eq(sv(&["--telemetry-out-extra=x"]),
                           "telemetry-out"),
                   None);
    }

    #[test]
    fn scan_out_path_skips_dash_args() {
        let argv = sv(&["--bench", "--telemetry-out=t.json", "out.json"]);
        assert_eq!(scan_out_path(argv, "dflt.json"), "out.json");
        assert_eq!(scan_out_path(sv(&["--bench"]), "dflt.json"),
                   "dflt.json");
        assert_eq!(scan_out_path(sv(&[]), "dflt.json"), "dflt.json");
    }
}
