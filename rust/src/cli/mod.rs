//! The `pgpr` command-line launcher.
//!
//! Subcommands:
//! * `info`      — version, artifact/profile status
//! * `predict`   — run selected methods on a workload, print metric table
//! * `sweep`     — regenerate a paper figure (fig1 | fig2 | fig3 | table1)
//! * `serve`     — real-time serving demo (router + batcher + backend)
//! * `learn`     — MLE hyperparameter learning on a workload subset
//! * `train`     — distributed PITC marginal-likelihood training
//! * `stats`     — record a mini fit+predict+serve pass, export telemetry
//! * `node`      — serve a model over TCP (predict/stats/healthz/admin)
//! * `save`      — fit a model and write its versioned checkpoint
//! * `load`      — verify a checkpoint: decode, restore, probe predict
//! * `loadgen`   — open-loop qps sweep against a node → BENCH_e2e.json
//! * `selftest`  — native vs PJRT backend agreement on the tiny profile
//!
//! Arg syntax: `--key value` or `--flag`; hand-rolled (no clap offline).

pub mod args;
pub mod commands;

pub use args::Args;

const USAGE: &str = "\
pgpr — Parallel Gaussian Process Regression (Chen et al. 2013 reproduction)

USAGE:
  pgpr <COMMAND> [--key value ...]

COMMANDS:
  info                               environment + artifact status
  predict   --domain aimpeak|sarcos --n 1000 --m 8 --s 64 --rank 64
            [--methods ppic,fgp,...] [--test 200] [--seed 1] [--learn]
            [--parallel-threads N]
  sweep     --figure fig1|fig2|fig3|table1 [--domain aimpeak|sarcos]
            [--scale small|paper] [--out results.json]
            [--parallel-threads N]
  serve     --profile tiny|aimpeak|sarcos [--requests 200] [--batch-wait-ms 2]
            [--backend pjrt|native] [--artifacts DIR] [--parallel-threads N]
            [--telemetry-out PATH]
  learn     --domain aimpeak|sarcos [--n 512] [--iters 40] [--seed 1]
  train     --dataset rff|aimpeak|sarcos [--n 2048] [--m 8] [--s 96]
            [--d 4] [--test 256] [--iters 30] [--lr 0.08] [--subset 256]
            [--seed 1] [--no-backtrack] [--parallel-threads N]
            [--telemetry-out PATH]
  stats     [--format json|prometheus] [--mode full|deterministic]
            [--n 128] [--m 4] [--s 16] [--seed 1] [--out PATH]
  node      [--listen 127.0.0.1:7070] [--n 512] [--m 4] [--s 32] [--d 2]
            [--seed 1] [--workers 8] [--queue-cap 256] [--max-inflight 512]
            [--max-batch 16] [--batch-wait-ms 2] [--deadline-ms 250]
            [--mixed-precision] [--telemetry-out PATH]
            [--checkpoint PATH] [--snapshot-every-s 30]
  save      --out PATH [--method served|ppic|pitc|...] [--n 512] [--m 4]
            [--s 32] [--d 2] [--seed 1] [--mixed-precision]
  load      --path PATH
  loadgen   [--target 127.0.0.1:7070] [--smoke] [--qps 500,1000,...]
            [--duration-s 5] [--conns 16] [--seed 1] [--out BENCH_e2e.json]
  selftest  [--artifacts DIR]

--parallel-threads N (N >= 2) executes the simulated machines' work
concurrently on N host threads (cluster::ParallelExecutor). Predictions
are identical to the serial run — Theorems 1-2 are executor-independent
— and reported wall_s drops toward the critical path. The modeled
makespan (time_s) is still measured per node, so core contention can
inflate it; keep N <= physical cores when time_s feeds paper figures,
or use the serial default for timing-faithful sweeps. 0/1 = serial.

ENV: PGPR_ARTIFACTS (artifacts dir), PGPR_LOG (error|warn|info|debug),
PGPR_TELEMETRY (1 default | 0 off — metrics registry + phase spans)
";

/// CLI entrypoint; returns the process exit code.
pub fn main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Dispatch on the subcommand (separated for testing).
pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => commands::info(&args),
        "predict" => commands::predict(&args),
        "sweep" => commands::sweep(&args),
        "serve" => commands::serve(&args),
        "learn" => commands::learn(&args),
        "train" => commands::train(&args),
        "stats" => commands::stats(&args),
        "node" => commands::node(&args),
        "save" => commands::save(&args),
        "load" => commands::load(&args),
        "loadgen" => commands::loadgen(&args),
        "selftest" => commands::selftest(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_args_prints_usage() {
        assert!(run(&[]).is_ok());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&["frobnicate".into()]).is_err());
    }

    #[test]
    fn help_ok() {
        assert!(run(&["help".into()]).is_ok());
    }

    #[test]
    fn info_runs() {
        assert!(run(&["info".into()]).is_ok());
    }

    /// End-to-end `pgpr stats`: the mini fit+predict+serve pass runs,
    /// and its JSON export parses with phase spans and per-method
    /// request counters present.
    #[test]
    fn stats_smoke_writes_parsable_snapshot() {
        let path = std::env::temp_dir().join("pgpr_stats_cli_test.json");
        let path_s = path.to_str().unwrap().to_string();
        let argv: Vec<String> = [
            "stats", "--n", "32", "--m", "2", "--s", "6", "--out", &path_s,
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(run(&argv).is_ok());
        let raw = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(&raw).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(),
                   "pgpr-telemetry/1");
        let counters = doc.get("counters").unwrap();
        for method in ["pPITC", "pPIC", "pICF"] {
            let key = format!("api.requests.{method}");
            assert!(counters.get(&key).is_some(), "missing {key}");
        }
        assert!(!doc.get("spans").unwrap().as_arr().unwrap().is_empty());
        let _ = std::fs::remove_file(&path);

        // prometheus render path
        let argv: Vec<String> =
            ["stats", "--n", "32", "--m", "2", "--s", "6", "--format",
             "prometheus", "--out", &path_s]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run(&argv).is_ok());
        let prom = std::fs::read_to_string(&path).unwrap();
        assert!(prom.contains("pgpr_cluster_runs"));
        let _ = std::fs::remove_file(&path);
    }

    /// End-to-end `pgpr save` → `pgpr load` for the served model and a
    /// batch method: the checkpoint writes, decodes, restores and
    /// answers a probe prediction.
    #[test]
    fn save_load_roundtrip_cli() {
        let path = std::env::temp_dir().join("pgpr_cli_ckpt_test.bin");
        let p = path.to_str().unwrap().to_string();
        let save: Vec<String> =
            ["save", "--out", &p, "--n", "32", "--m", "2", "--s", "6"]
                .iter().map(|s| s.to_string()).collect();
        assert!(run(&save).is_ok());
        let load: Vec<String> =
            ["load", "--path", &p].iter().map(|s| s.to_string()).collect();
        assert!(run(&load).is_ok());
        let save_pitc: Vec<String> =
            ["save", "--out", &p, "--method", "pitc", "--n", "32", "--m",
             "2", "--s", "6"].iter().map(|s| s.to_string()).collect();
        assert!(run(&save_pitc).is_ok());
        assert!(run(&load).is_ok());
        // a garbage file is a typed error, not a panic
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(run(&load).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// End-to-end `pgpr train` on a tiny synthetic problem (the same
    /// shape the CI train smoke job runs).
    #[test]
    fn train_smoke_runs() {
        let argv: Vec<String> = [
            "train", "--n", "64", "--test", "16", "--m", "4", "--s", "12",
            "--d", "2", "--iters", "3", "--subset", "48",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(run(&argv).is_ok());
    }
}
