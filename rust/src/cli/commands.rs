//! CLI subcommand implementations.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::args::Args;
use crate::api::{Gp, Method};
use crate::bench_support::experiments::{
    run_methods, speedup_order, ExperimentConfig,
};
use crate::bench_support::figures::{self, Scale};
use crate::bench_support::table::{fmt3, Table};
use crate::bench_support::workloads::{prepare, Domain};
use crate::data::partition::cluster_partition;
use crate::gp::likelihood::{learn_hyperparameters, MleConfig};
use crate::gp::support::support_matrix;
use crate::runtime::{artifacts, ArtifactManifest, Backend, NativeBackend,
                     PjrtBackend};
use crate::server::{DynamicBatcher, PredictRequest};
use crate::util::Pcg64;

fn parse_domain(args: &Args) -> Result<Domain> {
    let name = args.str_or("domain", "aimpeak");
    Domain::parse(name).ok_or_else(|| anyhow!("unknown domain '{name}'"))
}

/// `pgpr info`
pub fn info(_args: &Args) -> Result<()> {
    println!("pgpr {}", crate::version());
    println!("paper: Chen et al., Parallel Gaussian Process Regression \
              with Low-Rank Covariance Matrix Approximations (UAI 2013)");
    let dir = artifacts::default_dir();
    match ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} profiles)", dir.display(),
                     m.profiles.len());
            for (name, p) in &m.profiles {
                println!("  {name}: d={} B={} S={} U={} R={} ({} graphs)",
                         p.d, p.block, p.support, p.pred_block, p.rank,
                         p.graphs.len());
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

/// `pgpr predict` — one experiment point, table to stdout.
pub fn predict(args: &Args) -> Result<()> {
    let domain = parse_domain(args)?;
    let n = args.usize_or("n", 1000)?;
    let m = args.usize_or("m", 8)?;
    let s = args.usize_or("s", 64)?;
    let rank = args.usize_or("rank", s)?;
    let test = args.usize_or("test", (n / 10).max(m))?;
    let seed = args.u64_or("seed", 1)?;
    let learn = args.flag("learn");
    let threads = args.usize_or("parallel-threads", 0)?;

    let methods: Vec<Method> = if args.get("methods").is_some() {
        args.list("methods")
            .iter()
            .map(|s| Method::parse(s).ok_or_else(|| anyhow!("bad method '{s}'")))
            .collect::<Result<_>>()?
    } else {
        Method::ALL.to_vec()
    };

    crate::info!("preparing {} workload: n={n} test={test}", domain.name());
    let w = prepare(domain, n, test, seed, learn);
    let cfg = ExperimentConfig {
        machines: m, support_size: s, rank, seed, threads,
    };
    let results = run_methods(&w, &cfg, &speedup_order(&methods),
                              Arc::new(NativeBackend));

    // time_s is the paper's modeled incurred time (simulated makespan
    // for the parallel methods); wall_s is the real host wall-clock,
    // which shrinks with --parallel-threads.
    let mut t = Table::new(
        &format!("{} |D|={n} M={m} |S|={s} R={rank} threads={}",
                 domain.name(), threads.max(1)),
        &["method", "RMSE", "MNLP", "time_s", "wall_s", "speedup",
          "bad_var%"],
    );
    for r in &results {
        t.row(vec![
            r.method.name().into(),
            fmt3(r.rmse),
            fmt3(r.mnlp),
            fmt3(r.time_s),
            fmt3(r.wall_s),
            r.speedup.map(fmt3).unwrap_or_else(|| "-".into()),
            fmt3(100.0 * r.bad_var),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `pgpr sweep` — regenerate a figure/table.
pub fn sweep(args: &Args) -> Result<()> {
    let figure = args.str_or("figure", "fig1");
    let scale = Scale::parse(args.str_or("scale", "small"))
        .ok_or_else(|| anyhow!("bad --scale"))?;
    let seed = args.u64_or("seed", 1)?;
    let threads = args.usize_or("parallel-threads", 0)?;
    let domains: Vec<Domain> = match args.get("domain") {
        Some(d) => vec![Domain::parse(d).ok_or_else(|| anyhow!("bad domain"))?],
        None => vec![Domain::Aimpeak, Domain::Sarcos],
    };
    let mut tables = Vec::new();
    for domain in domains {
        let t = match figure {
            "fig1" => figures::fig1(domain, scale, seed, threads),
            "fig2" => figures::fig2(domain, scale, seed, threads),
            "fig3" => figures::fig3(domain, scale, seed, threads),
            "table1" => figures::table1(domain, seed, threads),
            other => bail!("unknown figure '{other}'"),
        };
        println!("{}", t.render());
        tables.push(t);
    }
    if let Some(path) = args.get("out") {
        let json = crate::util::json::Json::Arr(
            tables.iter().map(|t| t.to_json()).collect());
        std::fs::write(path, json.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `pgpr serve` — serving demo over a profile's shapes.
pub fn serve(args: &Args) -> Result<()> {
    let profile = args.str_or("profile", "tiny");
    let n_requests = args.usize_or("requests", 200)?;
    let wait_ms = args.f64_or("batch-wait-ms", 2.0)?;
    // default to pjrt only when the feature (and thus a loadable
    // backend) is actually compiled in; the stub's load always errors
    let default_backend = if cfg!(feature = "pjrt") { "pjrt" } else { "native" };
    let backend_name = args.str_or("backend", default_backend);
    let seed = args.u64_or("seed", 1)?;
    let threads = args.usize_or("parallel-threads", 0)?;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);

    let manifest = ArtifactManifest::load(&dir)?;
    let spec = manifest.profile(profile)?.clone();
    let m = args.usize_or("m", 4)?;
    let n = spec.block * m;

    // synthetic workload at the profile's input dimensionality
    let mut rng = Pcg64::new(seed, 0x5E);
    let hyp = crate::kernel::SeArd::isotropic(spec.d, 1.0, 1.0, 0.05);
    let xd = crate::linalg::Mat::from_vec(n, spec.d, rng.normals(n * spec.d));
    let y = rng.normals(n);
    let xu_probe = crate::linalg::Mat::from_vec(m, spec.d,
                                                rng.normals(m * spec.d));
    let part = cluster_partition(&xd, &xu_probe, m, &mut rng);

    let backend: Arc<dyn Backend> = match backend_name {
        "native" => Arc::new(NativeBackend),
        "pjrt" => Arc::new(PjrtBackend::load(&manifest, profile)?),
        other => bail!("unknown backend '{other}'"),
    };

    crate::info!("fitting served model: profile={profile} n={n} m={m} \
                  backend={backend_name}");
    let xs = support_matrix(&hyp, &xd, spec.support);
    let model = Gp::builder()
        .hyp(hyp.clone())
        .data(xd, y)
        .machines(m)
        .support(xs)
        .partition(part.d_blocks)
        .backend(Arc::clone(&backend))
        .seed(seed)
        .serve()?;

    let requests: Vec<PredictRequest> = (0..n_requests)
        .map(|i| PredictRequest {
            id: i as u64,
            x: rng.normals(spec.d),
            arrival_s: i as f64 * 2e-4, // 5k req/s offered load
        })
        .collect();
    let mut batcher = DynamicBatcher::new(m, spec.d, spec.pred_block,
                                          wait_ms * 1e-3);
    let exec = crate::cluster::ParallelExecutor::threads(threads);
    // Native serving goes through the fit-staged predictive operators
    // (serve_fast); a PJRT deployment executes the AOT graphs per
    // batch through the backend-driven loop.
    let telemetry = telemetry_sink(args);
    let (path, report) = if backend_name == "native" {
        ("fast", model.serve_fast(&requests, &mut batcher, &exec))
    } else {
        ("aot", model.serve_with(backend.as_ref(), &requests, &mut batcher,
                                 &exec))
    };
    println!("serve[{}|{}|{} threads]: {}", backend.name(), path,
             exec.workers(), report.summary());
    if let Some(p) = telemetry {
        write_telemetry(&p)?;
    }
    Ok(())
}

/// `pgpr learn` — MLE hyperparameter learning.
pub fn learn(args: &Args) -> Result<()> {
    let domain = parse_domain(args)?;
    let n = args.usize_or("n", 512)?;
    let iters = args.usize_or("iters", 40)?;
    let seed = args.u64_or("seed", 1)?;
    let w = prepare(domain, n, n / 10, seed, false);
    let cfg = MleConfig {
        iters,
        subset: 192.min(w.train.len()),
        seed,
        ..Default::default()
    };
    let init = domain.default_hyp();
    let result = learn_hyperparameters(&init, &w.train.x, &w.train.y, &cfg);
    println!("NLML: {} -> {}",
             fmt3(result.nlml_trace[0]),
             fmt3(*result.nlml_trace.last().unwrap()));
    println!("log_ls  = {:?}",
             result.hyp.log_ls.iter().map(|v| fmt3(*v)).collect::<Vec<_>>());
    println!("log_sf2 = {}", fmt3(result.hyp.log_sf2));
    println!("log_sn2 = {}", fmt3(result.hyp.log_sn2));
    Ok(())
}

/// `pgpr train` — distributed PITC marginal-likelihood training
/// (rust/src/train): M machines each contribute O(|S|²) statistics per
/// Adam iteration, then the trained hypers are consumed by a PITC refit
/// whose held-out RMSE is compared against the exact-subset MLE
/// baseline (`pgpr learn`'s path) and the untrained init.
pub fn train(args: &Args) -> Result<()> {
    use crate::train::optim::AdamConfig;

    let dataset = args.str_or("dataset", "rff");
    let m = args.usize_or("m", 8)?;
    if m == 0 {
        bail!("--m must be >= 1");
    }
    let n_req = args.usize_or("n", 2048)?;
    let n_test = args.usize_or("test", (n_req / 8).max(64))?;
    let s = args.usize_or("s", 96)?;
    let d_in = args.usize_or("d", 4)?;
    let iters = args.usize_or("iters", 30)?;
    let lr = args.f64_or("lr", 0.08)?;
    let subset = args.usize_or("subset", 256)?;
    let seed = args.u64_or("seed", 1)?;
    let threads = args.usize_or("parallel-threads", 0)?;
    let backtrack = !args.flag("no-backtrack");

    // dataset + init hypers + fixed support set / Definition 1 partition
    // (shared with inference); the rff path is the canonical recovery
    // problem shared with train_bench and the integration suite
    let (train_ds, test_ds, init, xs, d_blocks) = if dataset == "rff" {
        if n_req / m == 0 {
            bail!("need at least {m} training points");
        }
        let r = crate::bench_support::workloads::rff_recovery(
            n_req, n_test, d_in, s, m, seed);
        (r.train, r.test, r.init, r.xs, r.d_blocks)
    } else {
        let domain = Domain::parse(dataset)
            .ok_or_else(|| anyhow!("unknown dataset '{dataset}'"))?;
        let w = prepare(domain, n_req, n_test, seed, false);
        let init = domain.default_hyp();
        let n = (w.train.len() / m) * m;
        if n == 0 {
            bail!("need at least {m} training points");
        }
        let idx_n: Vec<usize> = (0..n).collect();
        let train = w.train.select(&idx_n);
        let (xs, d_blocks) =
            crate::bench_support::workloads::train_support_and_partition(
                &init, &train, s, m, seed);
        (train, w.test, init, xs, d_blocks)
    };
    let n = train_ds.len();
    let s = xs.rows;

    let exec = crate::cluster::ParallelExecutor::threads(threads);
    let lctx = exec.linalg_ctx();
    let cfg = AdamConfig { iters, lr, backtrack, ..Default::default() };

    crate::info!("train: dataset={dataset} n={n} M={m} |S|={s} iters={iters} \
                  threads={}", exec.workers());
    let telemetry = telemetry_sink(args);
    let result = Gp::builder()
        .hyp(init.clone())
        .data(train_ds.x.clone(), train_ds.y.clone())
        .machines(m)
        .support(xs.clone())
        .partition(d_blocks.clone())
        .executor(exec)
        .seed(seed)
        .train(&cfg)?;
    if backtrack {
        // The smoke gate CI relies on. Monotonicity alone is vacuous
        // (minimize guarantees it by construction), so also require
        // genuine finite progress — catching both a stalled run (every
        // step rejected) and NaN values.
        for w in result.nlml_trace.windows(2) {
            if w[1].is_nan() || w[1] > w[0] + 1e-9 {
                bail!("NLML increased under backtracking: {} -> {}",
                      w[0], w[1]);
            }
        }
        // Strict progress is only demanded on the rff recovery problem,
        // whose init is deliberately far off (the CI smoke shape) —
        // curated real-domain inits can legitimately start converged.
        let first = result.nlml_trace[0];
        let last = *result.nlml_trace.last().unwrap();
        if dataset == "rff" && iters > 0 && (last.is_nan() || last >= first)
        {
            bail!("training made no NLML progress: {first} -> {last}");
        }
    }

    // exact-subset MLE baseline (the seed's training path)
    let mle_cfg = MleConfig {
        iters,
        subset: subset.min(n),
        seed,
        lr,
        ..Default::default()
    };
    let mle = learn_hyperparameters(&init, &train_ds.x, &train_ds.y, &mle_cfg);

    // refit PITC with each hyper set and compare held-out RMSE
    let heldout_rmse = |hyp: &crate::kernel::SeArd| -> f64 {
        crate::bench_support::workloads::pitc_heldout_rmse(
            &lctx, hyp, &train_ds, &test_ds, &xs, &d_blocks)
    };
    let rmse_init = heldout_rmse(&init);
    let rmse_dist = heldout_rmse(&result.hyp);
    let rmse_mle = heldout_rmse(&mle.hyp);

    println!("distributed PITC NLML: {} -> {}  ({} evals, {} rejected)",
             fmt3(result.nlml_trace[0]),
             fmt3(*result.nlml_trace.last().unwrap()),
             result.evals, result.rejected);
    println!("per-eval comm: {} bytes / {} messages; makespan {:.3}s; \
              wall {:.3}s",
             result.bytes_per_eval, result.messages_per_eval,
             result.makespan_s, result.wall_s);
    println!("log_ls  = {:?}",
             result.hyp.log_ls.iter().map(|v| fmt3(*v)).collect::<Vec<_>>());
    println!("log_sf2 = {}  log_sn2 = {}",
             fmt3(result.hyp.log_sf2), fmt3(result.hyp.log_sn2));
    let mut t = Table::new(
        &format!("held-out RMSE (PITC refit, |D|={n} M={m} |S|={s})"),
        &["hypers", "RMSE", "vs exact-subset"],
    );
    for (name, r) in [("init", rmse_init), ("distributed-PITC", rmse_dist),
                      ("exact-subset", rmse_mle)] {
        t.row(vec![name.into(), fmt3(r), format!("{:.3}x", r / rmse_mle)]);
    }
    println!("{}", t.render());
    if let Some(p) = telemetry {
        write_telemetry(&p)?;
    }
    Ok(())
}

/// The miniature fit + predict + serve pass `pgpr stats` records: one
/// facade fit and prediction per parallel protocol (pPITC, pPIC,
/// pICF), then a short serve_fast stream — enough to exercise every
/// instrumented layer (protocol spans, cluster phases and collectives,
/// per-method API counters, serve latency histograms, linalg dispatch
/// counters).
fn stats_demo(n: usize, m: usize, s: usize, seed: u64) -> Result<()> {
    let _root = crate::obsv::span("stats.demo")
        .with_u64("n", n as u64)
        .with_u64("machines", m as u64);
    let d = 2usize;
    let mut rng = Pcg64::seed(seed);
    let hyp = crate::kernel::SeArd::isotropic(d, 1.0, 1.0, 0.05);
    let xd = crate::linalg::Mat::from_vec(n, d, rng.normals(n * d));
    let y = rng.normals(n);
    let u = m * 4;
    let xu = crate::linalg::Mat::from_vec(u, d, rng.normals(u * d));
    let base = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(s)
        .seed(seed);
    for method in [Method::PPitc, Method::PPic, Method::PIcf] {
        let gp = base.clone().method(method).fit()?;
        let out = gp.predict_full(
            &crate::api::PredictSpec::new(xu.clone()))?;
        anyhow::ensure!(out.prediction.mean.len() == u,
                        "{} returned {} rows", method.name(),
                        out.prediction.mean.len());
    }
    let model = base.serve()?;
    let requests: Vec<PredictRequest> = (0..16 * m)
        .map(|i| PredictRequest {
            id: i as u64,
            x: rng.normals(d),
            arrival_s: i as f64 * 1e-4,
        })
        .collect();
    let mut batcher = DynamicBatcher::new(model.machines(), d, 4, 5e-4);
    let exec = crate::cluster::ParallelExecutor::serial();
    let report = model.serve_fast(&requests, &mut batcher, &exec);
    anyhow::ensure!(report.responses.len() == requests.len(),
                    "serve dropped responses");
    Ok(())
}

/// `pgpr stats` — record a miniature fit + predict + serve pass into a
/// fresh telemetry registry and export the snapshot (JSON by default;
/// `--format prometheus` for scrape text, `--mode deterministic` to
/// drop measured-time content, `--out PATH` to write a file).
pub fn stats(args: &Args) -> Result<()> {
    use crate::obsv::{Registry, SnapshotMode};
    let format = args.str_or("format", "json");
    let mode = match args.str_or("mode", "full") {
        "full" => SnapshotMode::Full,
        "deterministic" => SnapshotMode::Deterministic,
        other => bail!("unknown --mode '{other}' (full|deterministic)"),
    };
    let m = args.usize_or("m", 4)?.max(1);
    let n = (args.usize_or("n", 128)? / m).max(2) * m;
    let s = args.usize_or("s", 16)?;
    let seed = args.u64_or("seed", 1)?;

    // a fresh scoped registry: the snapshot holds exactly this run
    let reg = std::sync::Arc::new(Registry::new());
    {
        let _guard = reg.install();
        stats_demo(n, m, s, seed)?;
    }
    let snap = reg.snapshot(mode);
    let rendered = match format {
        "json" => snap.to_json().to_string_pretty() + "\n",
        "prometheus" => snap.to_prometheus(),
        other => bail!("unknown --format '{other}' (json|prometheus)"),
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &rendered)?;
            println!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// Honor a `--telemetry-out PATH` argument on long-running commands:
/// force recording on now (before the workload) and return the writer
/// to call after it.
fn telemetry_sink(args: &Args) -> Option<String> {
    let path = args.get("telemetry-out")?.to_string();
    crate::obsv::set_enabled(true);
    Some(path)
}

fn write_telemetry(path: &str) -> Result<()> {
    let snap = crate::obsv::snapshot(crate::obsv::SnapshotMode::Full);
    std::fs::write(path, snap.to_json().to_string_pretty() + "\n")?;
    println!("wrote telemetry snapshot {path}");
    Ok(())
}

/// `pgpr selftest` — native vs PJRT agreement on the tiny profile.
pub fn selftest(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);
    let manifest = ArtifactManifest::load(&dir)?;
    let pjrt = PjrtBackend::load(&manifest, "tiny")?;
    let p = pjrt.profile.clone();

    let mut rng = Pcg64::seed(17);
    let hyp = crate::kernel::SeArd::isotropic(p.d, 1.0, 1.0, 0.05);
    let xm = crate::linalg::Mat::from_vec(p.block, p.d,
                                          rng.normals(p.block * p.d));
    let xs = crate::linalg::Mat::from_vec(p.support, p.d,
                                          rng.normals(p.support * p.d));
    let xu = crate::linalg::Mat::from_vec(p.pred_block, p.d,
                                          rng.normals(p.pred_block * p.d));
    let ym = rng.normals(p.block);

    let native = NativeBackend;
    let l_n = native.local_summary(&hyp, &xm, &ym, &xs);
    let l_p = pjrt.local_summary(&hyp, &xm, &ym, &xs);
    let d1 = crate::testkit::max_abs_diff(&l_n.y_dot, &l_p.y_dot);

    let ctx = crate::gp::summaries::SupportContext::new(&hyp, &xs);
    let glob = crate::gp::summaries::global_summary(&ctx, &[&l_n]);
    let p_n = native.ppitc_predict(&hyp, &xu, &xs, &glob);
    let p_p = pjrt.ppitc_predict(&hyp, &xu, &xs, &glob);
    let d2 = crate::testkit::max_abs_diff(&p_n.mean, &p_p.mean);
    let q_n = native.ppic_predict(&hyp, &xu, &xs, &xm, &ym, &l_n, &glob);
    let q_p = pjrt.ppic_predict(&hyp, &xu, &xs, &xm, &ym, &l_p, &glob);
    let d3 = crate::testkit::max_abs_diff(&q_n.mean, &q_p.mean);

    println!("selftest (tiny profile, native vs pjrt):");
    println!("  local_summary ydot max|Δ| = {d1:.3e}");
    println!("  ppitc mean    max|Δ| = {d2:.3e}");
    println!("  ppic mean     max|Δ| = {d3:.3e}");
    if d1.max(d2).max(d3) > 1e-8 {
        bail!("backend disagreement exceeds 1e-8");
    }
    println!("  OK");
    Ok(())
}

/// Build the synthetic serving model behind `pgpr node` (same recipe
/// as the `stats` demo: isotropic SE on gaussian inputs, deterministic
/// in the seed — two processes with the same knobs serve
/// bitwise-identical models).
fn synthetic_model(
    n: usize,
    m: usize,
    s: usize,
    d: usize,
    seed: u64,
    mixed: bool,
) -> Result<crate::server::ServedModel> {
    let mut rng = Pcg64::seed(seed);
    let hyp = crate::kernel::SeArd::isotropic(d, 1.0, 1.0, 0.05);
    let xd = crate::linalg::Mat::from_vec(n, d, rng.normals(n * d));
    let y = rng.normals(n);
    let model = Gp::builder()
        .hyp(hyp)
        .data(xd, y)
        .machines(m)
        .support_size(s)
        .seed(seed)
        .mixed_precision(mixed)
        .serve()?;
    Ok(model)
}

/// `pgpr node` — serve a model over TCP; blocks until drained (POST
/// /v1/admin/shutdown, or kill the process).
pub fn node(args: &Args) -> Result<()> {
    use crate::net::{NodeConfig, NodeServer};
    let listen = args.str_or("listen", "127.0.0.1:7070");
    let m = args.usize_or("m", 4)?.max(1);
    let n = (args.usize_or("n", 512)? / m).max(2) * m;
    let s = args.usize_or("s", 32)?;
    let d = args.usize_or("d", 2)?.max(1);
    let seed = args.u64_or("seed", 1)?;
    let telemetry_out = args.get("telemetry-out").map(str::to_string);
    let checkpoint = args.get("checkpoint").map(str::to_string);
    let snapshot_every_s = args.f64_or("snapshot-every-s", 0.0)?;
    let dflt = NodeConfig::default();
    let cfg = NodeConfig {
        workers: args.usize_or("workers", dflt.workers)?.max(1),
        queue_cap: args.usize_or("queue-cap", dflt.queue_cap)?.max(1),
        max_inflight: args
            .usize_or("max-inflight", dflt.max_inflight)?
            .max(1),
        max_batch: args.usize_or("max-batch", dflt.max_batch)?.max(1),
        batch_wait_s: args
            .f64_or("batch-wait-ms", dflt.batch_wait_s * 1e3)?
            * 1e-3,
        deadline_s: args.f64_or("deadline-ms", dflt.deadline_s * 1e3)?
            * 1e-3,
        checkpoint_path: checkpoint.clone(),
        snapshot_every_s,
        ..dflt
    };
    // cold start: an existing checkpoint restores the model without a
    // refit (serving within the restore + staging time); otherwise fit
    // fresh and, when a --checkpoint path is given, seed it so the
    // first crash already has an image to come back to
    let model = match &checkpoint {
        Some(path) if std::path::Path::new(path).exists() => {
            let t0 = std::time::Instant::now();
            let model = crate::server::ServedModel::load(path)
                .map_err(|e| anyhow!("restore {path}: {e}"))?;
            println!("restored checkpoint {path} ({} machines, {:.3}s)",
                     model.machines(), t0.elapsed().as_secs_f64());
            model
        }
        _ => {
            let model = synthetic_model(n, m, s, d, seed,
                                        args.flag("mixed-precision"))?;
            if let Some(path) = &checkpoint {
                let bytes = model.save(path)
                    .map_err(|e| anyhow!("save {path}: {e}"))?;
                println!("wrote initial checkpoint {path} ({bytes} bytes)");
            }
            model
        }
    };
    let handle = NodeServer::start(model, listen, cfg)?;
    println!("pgpr node listening on {} (|D|={n}, m={m}, |S|={s}, d={d})",
             handle.addr());
    println!("  POST /v1/predict   GET /stats[?format=json]   \
              GET /healthz   POST /v1/admin/{{snapshot,reload,shutdown}}");
    let reg = handle.registry().clone();
    handle.join();
    if let Some(path) = telemetry_out {
        let snap = reg.snapshot(crate::obsv::SnapshotMode::Full);
        std::fs::write(&path, snap.to_json().to_string_pretty() + "\n")?;
        println!("wrote telemetry snapshot {path}");
    }
    println!("pgpr node drained");
    Ok(())
}

/// `pgpr save` — fit a model on the node's synthetic workload and
/// write its checkpoint: the staged serving model by default
/// (`--method served`), or any batch method. Online sessions
/// checkpoint mid-stream through the API instead.
pub fn save(args: &Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow!("--out PATH required"))?;
    let method_name = args.str_or("method", "served");
    let m = args.usize_or("m", 4)?.max(1);
    let n = (args.usize_or("n", 512)? / m).max(2) * m;
    let s = args.usize_or("s", 32)?;
    let d = args.usize_or("d", 2)?.max(1);
    let seed = args.u64_or("seed", 1)?;
    let bytes = if method_name == "served" {
        let model = synthetic_model(n, m, s, d, seed,
                                    args.flag("mixed-precision"))?;
        model.save(out)?
    } else {
        let method = Method::parse(method_name)
            .ok_or_else(|| anyhow!("unknown method '{method_name}'"))?;
        if method == Method::Online {
            bail!("online sessions checkpoint mid-stream through the \
                   API; `pgpr save` covers batch methods and 'served'");
        }
        let mut rng = Pcg64::seed(seed);
        let hyp = crate::kernel::SeArd::isotropic(d, 1.0, 1.0, 0.05);
        let xd = crate::linalg::Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let mut b = Gp::builder()
            .method(method)
            .hyp(hyp)
            .data(xd, y)
            .machines(m)
            .seed(seed);
        if method.needs_support() {
            b = b.support_size(s);
        }
        if method.needs_rank() {
            b = b.rank(s);
        }
        b.fit()?.save(out)?
    };
    println!("wrote {out} ({bytes} bytes, method {method_name})");
    Ok(())
}

/// `pgpr load` — verify a checkpoint: decode it (CRC + structural
/// checks), restore the model, and run one probe prediction.
pub fn load(args: &Args) -> Result<()> {
    let path = args
        .get("path")
        .ok_or_else(|| anyhow!("--path PATH required"))?;
    let ck = crate::store::Checkpoint::read_file(path)
        .map_err(|e| anyhow!("{e}"))?;
    let bytes = std::fs::metadata(path)?.len();
    println!("{path}: {} checkpoint, {bytes} bytes, format v{}, \
              version {:08x}",
             ck.method_name(), crate::store::FORMAT_VERSION,
             ck.version_hash());
    match ck {
        crate::store::Checkpoint::Served(sc) => {
            let t0 = std::time::Instant::now();
            let model = crate::server::ServedModel::from_checkpoint(sc)?;
            let d = model.xs.cols;
            let lctx = crate::linalg::LinalgCtx::serial();
            let mut scratch = crate::server::ServeScratch::new();
            let probe = vec![0.0; d];
            let (mean, var) = model.predict_batch_fast(0, &probe, 1, 1,
                                                       &lctx, &mut scratch);
            println!("restored serving model: {} machines, d={d}, \
                      {:.3}s; probe mean={:.6} var={:.6}",
                     model.machines(), t0.elapsed().as_secs_f64(),
                     mean[0], var[0]);
        }
        other => {
            let d = match &other {
                crate::store::Checkpoint::Batch(b) => b.xd.cols,
                crate::store::Checkpoint::Online(o) => o.xd.cols,
                crate::store::Checkpoint::Served(_) => unreachable!(),
            };
            let t0 = std::time::Instant::now();
            let gp = Gp::from_checkpoint(other)?;
            let xu = crate::linalg::Mat::from_vec(1, d, vec![0.0; d]);
            let pred = gp.predict(&xu)?;
            println!("restored {} model: {} machines, d={d}, {:.3}s; \
                      probe mean={:.6} var={:.6}",
                     gp.method().name(), gp.machines(),
                     t0.elapsed().as_secs_f64(), pred.mean[0],
                     pred.var[0]);
        }
    }
    Ok(())
}

/// `pgpr loadgen` — open-loop qps sweep against a running node →
/// `BENCH_e2e.json`.
pub fn loadgen(args: &Args) -> Result<()> {
    use crate::net::loadgen::{run_loadgen, LoadgenConfig};
    let target = args.str_or("target", "127.0.0.1:7070").to_string();
    let smoke = args.flag("smoke")
        || std::env::var("PGPR_E2E_SMOKE").as_deref() == Ok("1");
    let mut cfg = if smoke {
        LoadgenConfig::smoke(&target)
    } else {
        LoadgenConfig::full(&target)
    };
    if let Some(q) = args.get("qps") {
        cfg.qps_steps = q
            .split(',')
            .map(|v| {
                v.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("--qps: bad number '{v}'"))
            })
            .collect::<Result<Vec<f64>>>()?;
    }
    cfg.duration_s = args.f64_or("duration-s", cfg.duration_s)?;
    cfg.conns = args.usize_or("conns", cfg.conns)?.max(1);
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    let out = args.str_or("out", "BENCH_e2e.json");
    let report = run_loadgen(&cfg)?;
    println!("loadgen vs {} (m={}, queue_cap={}, max_batch={}):",
             target, report.machines, report.queue_cap,
             report.max_batch);
    println!("{:>11} {:>10} {:>8} {:>7} {:>7} {:>9} {:>9} {:>9}",
             "target_qps", "achieved", "ok", "429", "503", "p50_ms",
             "p99_ms", "p999_ms");
    for st in &report.steps {
        println!(
            "{:>11.0} {:>10.0} {:>8} {:>7} {:>7} {:>9.3} {:>9.3} {:>9.3}",
            st.target_qps, st.achieved_qps, st.ok, st.shed_429,
            st.shed_503, st.p50_s * 1e3, st.p99_s * 1e3,
            st.p999_s * 1e3
        );
    }
    report.write(out)?;
    println!("wrote {out}");
    Ok(())
}
