//! Adam with optional backtracking — the optimizer behind every
//! marginal-likelihood training path.
//!
//! Extracted from the seed's `gp/likelihood.rs` loop so the exact-subset
//! MLE ([`crate::gp::likelihood::learn_hyperparameters`]) and the
//! distributed PITC trainer ([`crate::train::dist`]) share one
//! implementation. The objective is a black box `θ → (value, ∇value)`;
//! for GP training θ is the log-hyperparameter vector
//! (`SeArd::to_vec` layout) and the value is an NLML.
//!
//! With `backtrack = true`, a proposed Adam step that *increases* the
//! objective (or evaluates to NaN) is retried with a halved learning
//! rate (up to `max_backtracks` times) and rejected outright if it
//! still increases — so the accepted-value trace is non-increasing and
//! finite by construction (the CI train smoke job asserts exactly
//! this). The reduced learning rate carries into subsequent iterations
//! but doubles back toward the configured rate on each accepted step,
//! so one rough region slows the walk without freezing the whole run.

/// Adam configuration. Defaults mirror the seed MLE loop
/// (lr 0.08, β₁ 0.9, β₂ 0.999, ε 1e-8, log-hyper clamp ±6).
#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub iters: usize,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Symmetric clamp applied to every coordinate after each step —
    /// keeps log-hyperparameters in a numerically sane range.
    pub log_bound: f64,
    /// Reject steps that increase the objective (halving lr first).
    pub backtrack: bool,
    /// Max lr halvings per iteration before the step is rejected.
    pub max_backtracks: usize,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            iters: 60,
            lr: 0.08,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            log_bound: 6.0,
            backtrack: false,
            max_backtracks: 4,
        }
    }
}

/// Result of [`minimize`].
#[derive(Debug, Clone)]
pub struct OptimResult {
    /// Final parameter vector.
    pub theta: Vec<f64>,
    /// Objective value at θ₀ followed by the value after each iteration
    /// (the *accepted* value when backtracking rejects a step) — length
    /// `iters + 1`. Non-increasing when `backtrack` is set.
    pub trace: Vec<f64>,
    /// Number of objective evaluations performed.
    pub evals: usize,
    /// Number of iterations whose step was rejected (backtracking only).
    pub rejected: usize,
}

/// Minimize `f` from `theta0` with Adam.
///
/// `f(θ)` returns `(value, gradient)`; the gradient must have `θ.len()`
/// entries. Without backtracking the iterate sequence is identical to
/// the seed's hand-rolled loop (one trailing evaluation is added so the
/// trace ends at the final θ).
pub fn minimize(
    cfg: &AdamConfig,
    theta0: &[f64],
    mut f: impl FnMut(&[f64]) -> (f64, Vec<f64>),
) -> OptimResult {
    let p = theta0.len();
    let mut theta = theta0.to_vec();
    let (mut m1, mut m2) = (vec![0.0; p], vec![0.0; p]);
    let mut lr = cfg.lr;
    let mut rejected = 0usize;

    let (mut value, mut grad) = f(&theta);
    assert_eq!(grad.len(), p, "gradient length mismatch");
    let mut evals = 1usize;
    let mut trace = Vec::with_capacity(cfg.iters + 1);
    trace.push(value);

    for t in 1..=cfg.iters {
        for i in 0..p {
            m1[i] = cfg.beta1 * m1[i] + (1.0 - cfg.beta1) * grad[i];
            m2[i] = cfg.beta2 * m2[i] + (1.0 - cfg.beta2) * grad[i] * grad[i];
        }
        let bias1 = 1.0 - cfg.beta1.powi(t as i32);
        let bias2 = 1.0 - cfg.beta2.powi(t as i32);
        let propose = |lr: f64, theta: &[f64], m1: &[f64], m2: &[f64]| {
            let mut cand = theta.to_vec();
            for i in 0..p {
                let mh = m1[i] / bias1;
                let vh = m2[i] / bias2;
                cand[i] -= lr * mh / (vh.sqrt() + cfg.eps);
                cand[i] = cand[i].clamp(-cfg.log_bound, cfg.log_bound);
            }
            cand
        };

        let mut cand = propose(lr, &theta, &m1, &m2);
        let (mut v_new, mut g_new) = f(&cand);
        evals += 1;
        if cfg.backtrack {
            // The explicit NaN arm matters: `v_new > value` is false for
            // NaN, and a NaN step must be backtracked/rejected, never
            // accepted.
            let worse = |v: f64| v.is_nan() || v > value;
            let mut tries = 0;
            while worse(v_new) && tries < cfg.max_backtracks {
                lr *= 0.5;
                cand = propose(lr, &theta, &m1, &m2);
                let (v, g) = f(&cand);
                v_new = v;
                g_new = g;
                evals += 1;
                tries += 1;
            }
            if worse(v_new) {
                // reject: keep θ (and the shrunken lr); grad unchanged,
                // so the moments keep decaying toward this direction.
                rejected += 1;
                trace.push(value);
                continue;
            }
        }
        theta = cand;
        value = v_new;
        grad = g_new;
        if cfg.backtrack {
            // recover toward the configured rate after an accepted step
            // so one rough region can't freeze the rest of the run at a
            // microscopic lr (halvings are per-encounter, not permanent)
            lr = (lr * 2.0).min(cfg.lr);
        }
        trace.push(value);
    }
    OptimResult { theta, trace, evals, rejected }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convex quadratic: Adam converges near the minimum (plain Adam
    /// with a fixed lr oscillates at ~lr scale, hence the loose bound).
    #[test]
    fn minimizes_quadratic() {
        let target = [1.5, -2.0, 0.25];
        let f = |theta: &[f64]| {
            let mut v = 0.0;
            let mut g = vec![0.0; 3];
            for i in 0..3 {
                let d = theta[i] - target[i];
                v += d * d;
                g[i] = 2.0 * d;
            }
            (v, g)
        };
        let cfg = AdamConfig { iters: 800, lr: 0.02, ..Default::default() };
        let r = minimize(&cfg, &[0.0; 3], f);
        for i in 0..3 {
            assert!((r.theta[i] - target[i]).abs() < 0.1,
                    "coord {i}: {} vs {}", r.theta[i], target[i]);
        }
        assert_eq!(r.trace.len(), 801);
        assert!(r.trace.last().unwrap() < &0.05);
        assert_eq!(r.evals, 801);
    }

    /// Backtracking makes the accepted trace non-increasing even on a
    /// nasty objective where plain Adam overshoots.
    #[test]
    fn backtracking_is_monotone() {
        // steep valley: |x|^1.5-ish with large lr forces overshoot
        let f = |theta: &[f64]| {
            let x = theta[0];
            (x * x * x * x - 0.3 * x, vec![4.0 * x * x * x - 0.3])
        };
        let cfg = AdamConfig {
            iters: 60,
            lr: 1.5,
            backtrack: true,
            ..Default::default()
        };
        let r = minimize(&cfg, &[2.0], f);
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace increased: {w:?}");
        }
        // and it still makes real progress from f(2) = 15.4
        assert!(*r.trace.last().unwrap() < 0.0, "no progress");
    }

    /// A NaN objective region is never stepped into: the NaN proposal is
    /// rejected (after backtracks) and the trace stays finite/monotone.
    #[test]
    fn backtracking_rejects_nan_steps() {
        // f is NaN for x < 0; a big lr would overshoot into it
        let f = |theta: &[f64]| {
            let x = theta[0];
            if x < 0.0 {
                (f64::NAN, vec![f64::NAN])
            } else {
                (x * x, vec![2.0 * x])
            }
        };
        let cfg = AdamConfig {
            iters: 20,
            lr: 4.0,
            backtrack: true,
            max_backtracks: 3,
            ..Default::default()
        };
        let r = minimize(&cfg, &[1.0], f);
        assert!(r.theta[0] >= 0.0, "stepped into the NaN region");
        for w in r.trace.windows(2) {
            assert!(w[1].is_finite() && w[1] <= w[0] + 1e-12, "{w:?}");
        }
        assert!(r.rejected > 0, "the lr-4 overshoot was never rejected");
    }

    /// Without backtracking the iterate sequence matches a hand-rolled
    /// seed-style Adam loop exactly.
    #[test]
    fn matches_seed_adam_loop() {
        let grad_at = |theta: &[f64]| {
            vec![theta[0].sin() + 0.3 * theta[0], theta[1] * 0.5 - 0.2]
        };
        let value_at = |theta: &[f64]| {
            -theta[0].cos() + 0.15 * theta[0] * theta[0]
                + 0.25 * theta[1] * theta[1] - 0.2 * theta[1]
        };
        let f = |theta: &[f64]| (value_at(theta), grad_at(theta));

        let cfg = AdamConfig { iters: 25, lr: 0.08, ..Default::default() };
        let r = minimize(&cfg, &[1.2, -0.7], f);

        // seed-style reference loop
        let mut theta = vec![1.2, -0.7];
        let (mut m1, mut m2) = (vec![0.0; 2], vec![0.0; 2]);
        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
        for t in 1..=25 {
            let g = grad_at(&theta);
            for i in 0..2 {
                m1[i] = b1 * m1[i] + (1.0 - b1) * g[i];
                m2[i] = b2 * m2[i] + (1.0 - b2) * g[i] * g[i];
                let mh = m1[i] / (1.0 - f64::powi(b1, t));
                let vh = m2[i] / (1.0 - f64::powi(b2, t));
                theta[i] -= 0.08 * mh / (vh.sqrt() + eps);
                theta[i] = theta[i].clamp(-6.0, 6.0);
            }
        }
        assert_eq!(r.theta, theta, "iterate sequences diverged");
    }

    #[test]
    fn respects_log_bound() {
        let f = |theta: &[f64]| (theta[0], vec![1.0]); // walk to -inf
        let cfg = AdamConfig {
            iters: 50,
            lr: 5.0,
            log_bound: 0.75,
            ..Default::default()
        };
        let r = minimize(&cfg, &[0.0], f);
        assert!(r.theta[0] >= -0.75 - 1e-12);
        assert!((r.theta[0] + 0.75).abs() < 1e-9, "should sit at the clamp");
    }

    #[test]
    fn zero_iters_returns_start() {
        let f = |theta: &[f64]| (theta[0] * theta[0], vec![2.0 * theta[0]]);
        let cfg = AdamConfig { iters: 0, ..Default::default() };
        let r = minimize(&cfg, &[3.0], f);
        assert_eq!(r.theta, vec![3.0]);
        assert_eq!(r.trace, vec![9.0]);
        assert_eq!(r.evals, 1);
    }
}
