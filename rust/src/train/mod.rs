//! Distributed low-rank marginal-likelihood **training** — the pipeline
//! stage the paper leaves centralized, made cluster-parallel.
//!
//! The prediction protocols (pPITC/pPIC, [`crate::parallel`]) distribute
//! *inference*; hyperparameter learning in the seed
//! ([`crate::gp::likelihood`]) remained an exact-GP NLML on a small
//! random subset. This module trains on **all** the data by maximizing
//! the *PITC* marginal likelihood — the low-rank model the predictions
//! actually use — with the work decomposed machine-by-machine on the
//! same cluster topology (and the same `Definition 1` partition) as
//! inference:
//!
//! * [`nlml`] — the closed-form PITC NLML `½yᵀC⁻¹y + ½log|C| + const`
//!   (`C = Σ_DS Σ_SS⁻¹ Σ_SD + blockdiag(Σ_mm − Q_mm)`) and its analytic
//!   gradient w.r.t. the log-hyperparameters, factored so machine m
//!   contributes only |S|×|S| + |S| statistics (value) and d+2 scalars
//!   (gradient) — O(|S|²) messages, matching the paper's communication
//!   analysis.
//! * [`dist`] — the two-round protocol over
//!   [`crate::cluster::ParallelExecutor`] (+ the Adam loop on top),
//!   exact w.r.t. the centralized evaluation to ≤1e-10 for any machine
//!   count: the training analogue of Theorem 1.
//! * [`optim`] — the shared Adam optimizer (extracted from the seed MLE
//!   loop) with optional backtracking that makes the NLML trace
//!   monotone.
//!
//! Trained hypers ([`SeArd`](crate::kernel::SeArd)) feed straight into
//! `PitcGp`/`PicGp`, the pPITC/pPIC protocols and
//! [`crate::server::ServedModel::refit`] — same jitter conventions
//! end-to-end. Entry points: `pgpr train` (CLI) and
//! [`dist::train_pitc`].
//!
//! A live deployment consumes a training run without downtime through
//! [`refit_for_swap`]: refit off the serving thread, then hand the
//! replacement to [`crate::server::ServedModel::swap_in`] (or checkpoint
//! it and `POST /v1/admin/reload` a running `pgpr node`).

pub mod dist;
pub mod nlml;
pub mod optim;

pub use dist::{
    nlml_and_grad_dist, nlml_and_grad_dist_ft, train_pitc,
    try_train_pitc, DistEval, TrainResult,
};
pub use nlml::{pitc_nlml_and_grad, LocalStats, TrainSupport};
pub use optim::{minimize, AdamConfig, OptimResult};

/// Turn a finished training run into a swap-ready serving model: refit
/// `live`'s summaries under the trained hyperparameters — same data
/// partition, same routing topology, mixed-precision staging preserved
/// — and return the replacement for
/// [`crate::server::ServedModel::swap_in`]. The refit runs on the
/// caller's thread, so a deployment trains + refits off the serving
/// loop and the swap itself is one pointer-sized move: in-flight
/// requests finish on the old model, later ones see only the new one.
#[must_use]
pub fn refit_for_swap(
    live: &crate::server::ServedModel,
    trained: &TrainResult,
    backend: &dyn crate::runtime::Backend,
) -> crate::server::ServedModel {
    live.refit(&trained.hyp, backend)
}
