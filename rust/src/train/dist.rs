//! Distributed PITC NLML/gradient evaluation over the simulated cluster,
//! and the full training loop on top of it.
//!
//! One NLML+gradient evaluation is a two-round protocol on the same
//! cluster topology the prediction protocols use:
//!
//! 1. **support** — the master builds the shared support state
//!    ([`TrainSupport`]) and ships `chol(Σ_SS)` + `K_SS` (machines could
//!    equivalently rebuild it from the cluster-wide S; one copy avoids M
//!    redundant factorizations on the host).
//! 2. **local_stats** — every machine condenses its block into
//!    [`LocalStats`] (O(|S|²) message) — fanned out on the
//!    [`crate::cluster::ParallelExecutor`] thread pool when configured.
//! 3. **assemble** — reduce to the master, assimilate to the NLML value
//!    and the O(|S|²) [`super::nlml::GradBroadcast`]; broadcast back.
//! 4. **local_grads** — every machine reduces its full gradient
//!    contribution to d+2 scalars; a final O(d) reduce finishes ∇NLML.
//!
//! Per-iteration communication is O(|S|²) per machine independent of
//! |D| — the paper's communication-complexity shape carried over to
//! training (cf. Dai et al., arXiv 1410.4984, which distributes exactly
//! this computation). The evaluation is **exact**: it equals
//! [`super::nlml::pitc_nlml_and_grad`] (same block math, same reduction
//! order) to
//! ≤1e-10 whatever M or the executor — asserted by
//! `tests/integration_train.rs`, the training analogue of Theorem 1.

use super::nlml::{
    local_grad_ctx, local_stats_ctx, master_assemble_ctx, LocalStats,
    TrainSupport,
};
use super::optim::{minimize, AdamConfig, OptimResult};
use crate::cluster::mpi::MASTER;
use crate::cluster::{Cluster, MachinesLost, RunMetrics};
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::parallel::{f64_bytes, ClusterSpec};
use crate::util::Stopwatch;

/// One distributed NLML + gradient evaluation.
#[derive(Debug, Clone)]
pub struct DistEval {
    pub value: f64,
    pub grad: Vec<f64>,
    pub metrics: RunMetrics,
}

/// Evaluate the PITC NLML and its gradient with the per-machine work
/// distributed across `spec`'s cluster. `y` must be centered by the
/// caller; `d_blocks` must have one block per machine.
pub fn nlml_and_grad_dist(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
) -> DistEval {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "train: d_blocks vs machines");
    assert_eq!(xd.rows, y.len(), "train: x/y length");
    let s = xs.rows;
    let p = hyp.dim() + 2;
    let _obsv_span = crate::obsv::span("train.eval")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let lctx = spec.exec.linalg_ctx();
    let mut cluster = spec.cluster();

    // Round 0: shared support state (chol(Σ_SS) + K_SS, 2·|S|² payload).
    let sup =
        cluster.compute_on(MASTER, || TrainSupport::new_ctx(&lctx, hyp, xs));
    cluster.bcast_from_master(f64_bytes(2 * s * s));
    cluster.phase("support");

    // Round 1: per-machine stats (thread-parallel under the executor).
    let round1 = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> = d_blocks[mid].iter().map(|&i| y[i]).collect();
        local_stats_ctx(&lctx, hyp, &xm, &ym, &sup)
    });
    cluster.phase("local_stats");

    // Reduce stats to the master, assimilate, broadcast gradient state.
    cluster.reduce_to_master(f64_bytes(s * s + s + 2));
    let master = cluster.compute_on(MASTER, || {
        let refs: Vec<&LocalStats> = round1.iter().map(|(st, _)| st).collect();
        master_assemble_ctx(&lctx, hyp, &sup, &refs, xd.rows)
    });
    cluster.bcast_from_master(f64_bytes(2 * s * s + 2 * s));
    cluster.phase("assemble");

    // Round 2: per-machine gradient scalars.
    let grads = cluster.compute_all(|mid| {
        local_grad_ctx(&lctx, hyp, &round1[mid].1, &sup, &master.bcast)
    });
    cluster.phase("local_grads");

    // Final O(d) reduce; master adds its own terms.
    cluster.reduce_to_master(f64_bytes(p));
    let mut grad = master.grad_master.clone();
    for gm in &grads {
        for (acc, v) in grad.iter_mut().zip(gm.iter()) {
            *acc += v;
        }
    }
    cluster.phase("grad_reduce");

    DistEval { value: master.value, grad, metrics: cluster.finish() }
}

/// Hand every block whose owner died to a survivor (round-robin),
/// charging the adopter one block fetch. Returns the moved block ids.
fn reassign_blocks(
    cluster: &mut Cluster,
    dead: &[usize],
    owners: &mut [usize],
    block_bytes: &[usize],
    phase: &str,
) -> Result<Vec<usize>, MachinesLost> {
    if dead.is_empty() {
        return Ok(Vec::new());
    }
    let survivors = cluster.alive_ids();
    if survivors.is_empty() {
        return Err(MachinesLost::at(phase, cluster.size()));
    }
    let mut moved = Vec::new();
    let mut next = 0usize;
    for (k, owner) in owners.iter_mut().enumerate() {
        if cluster.is_alive(*owner) {
            continue;
        }
        *owner = survivors[next % survivors.len()];
        next += 1;
        cluster.rebalance_fetch(*owner, block_bytes[k]);
        moved.push(k);
    }
    Ok(moved)
}

/// Fault-aware twin of [`nlml_and_grad_dist`]: the same block math in
/// the same reduction order, but every collective runs with bounded
/// retries and a machine that dies hands its *whole blocks* to
/// survivors. Per-block stats depend only on the block's data, so the
/// adopter recomputes them bitwise-identically, and because the
/// master's sums always run in block order the evaluation equals the
/// fault-free one **bitwise** whenever at least one machine survives.
pub fn nlml_and_grad_dist_ft(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
) -> Result<DistEval, MachinesLost> {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "train: d_blocks vs machines");
    assert_eq!(xd.rows, y.len(), "train: x/y length");
    let s = xs.rows;
    let p = hyp.dim() + 2;
    let _obsv_span = crate::obsv::span("train.eval")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let lctx = spec.exec.linalg_ctx();
    let mut cluster = spec.cluster();

    // block k's compute is charged to owners[k]; adoption rewires this
    // map without touching block contents
    let mut owners: Vec<usize> = (0..m).collect();
    let block_bytes: Vec<usize> = d_blocks
        .iter()
        .map(|b| f64_bytes(b.len() * (xd.cols + 1)))
        .collect();

    // Round 0: shared support state; receivers dying during the
    // broadcast only lose ownership (survivors already have the data).
    let dead = cluster.take_deaths("support");
    reassign_blocks(&mut cluster, &dead, &mut owners, &block_bytes,
                    "support")?;
    let root = cluster.master();
    let sup =
        cluster.compute_on(root, || TrainSupport::new_ctx(&lctx, hyp, xs));
    let failed = cluster.bcast_from_master(f64_bytes(2 * s * s));
    reassign_blocks(&mut cluster, &failed, &mut owners, &block_bytes,
                    "support")?;
    cluster.phase("support");

    // Round 1: per-block stats on their current owners.
    let dead = cluster.take_deaths("local_stats");
    reassign_blocks(&mut cluster, &dead, &mut owners, &block_bytes,
                    "local_stats")?;
    let mut round1 = cluster.compute_owned(&owners, |k| {
        let xm = xd.select_rows(&d_blocks[k]);
        let ym: Vec<f64> = d_blocks[k].iter().map(|&i| y[i]).collect();
        local_stats_ctx(&lctx, hyp, &xm, &ym, &sup)
    });
    cluster.phase("local_stats");

    // Reduce with retry: a dead sender's blocks move and the adopter
    // recomputes their O(|S|²) stats before the reduce re-runs.
    let dead = cluster.take_deaths("assemble");
    let mut pending = reassign_blocks(&mut cluster, &dead, &mut owners,
                                      &block_bytes, "assemble")?;
    loop {
        for &k in &pending {
            round1[k] = cluster.compute_on(owners[k], || {
                let xm = xd.select_rows(&d_blocks[k]);
                let ym: Vec<f64> =
                    d_blocks[k].iter().map(|&i| y[i]).collect();
                local_stats_ctx(&lctx, hyp, &xm, &ym, &sup)
            });
        }
        let failed = cluster.reduce_to_master(f64_bytes(s * s + s + 2));
        if failed.is_empty() {
            break;
        }
        pending = reassign_blocks(&mut cluster, &failed, &mut owners,
                                  &block_bytes, "assemble")?;
    }
    let root = cluster.master();
    let master = cluster.compute_on(root, || {
        let refs: Vec<&LocalStats> =
            round1.iter().map(|(st, _)| st).collect();
        master_assemble_ctx(&lctx, hyp, &sup, &refs, xd.rows)
    });
    let failed =
        cluster.bcast_from_master(f64_bytes(2 * s * s + 2 * s));
    reassign_blocks(&mut cluster, &failed, &mut owners, &block_bytes,
                    "assemble")?;
    cluster.phase("assemble");

    // Round 2: per-block gradient scalars.
    let dead = cluster.take_deaths("local_grads");
    reassign_blocks(&mut cluster, &dead, &mut owners, &block_bytes,
                    "local_grads")?;
    let mut grads = cluster.compute_owned(&owners, |k| {
        local_grad_ctx(&lctx, hyp, &round1[k].1, &sup, &master.bcast)
    });
    cluster.phase("local_grads");

    // Final reduce, same retry shape as the stats reduce.
    let dead = cluster.take_deaths("grad_reduce");
    let mut pending = reassign_blocks(&mut cluster, &dead, &mut owners,
                                      &block_bytes, "grad_reduce")?;
    loop {
        for &k in &pending {
            grads[k] = cluster.compute_on(owners[k], || {
                local_grad_ctx(&lctx, hyp, &round1[k].1, &sup,
                               &master.bcast)
            });
        }
        let failed = cluster.reduce_to_master(f64_bytes(p));
        if failed.is_empty() {
            break;
        }
        pending = reassign_blocks(&mut cluster, &failed, &mut owners,
                                  &block_bytes, "grad_reduce")?;
    }
    let mut grad = master.grad_master.clone();
    for gm in &grads {
        for (acc, v) in grad.iter_mut().zip(gm.iter()) {
            *acc += v;
        }
    }
    cluster.phase("grad_reduce");

    Ok(DistEval { value: master.value, grad, metrics: cluster.finish() })
}

/// Result of a distributed training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// Trained hyperparameters.
    pub hyp: SeArd,
    /// Empirical train-output mean subtracted before the NLML (callers
    /// un-center predictions with it, as the prediction paths do).
    pub y_mean: f64,
    /// NLML at the init followed by each iteration's accepted value.
    pub nlml_trace: Vec<f64>,
    /// Objective evaluations performed / backtracking rejections.
    pub evals: usize,
    pub rejected: usize,
    /// Modeled communication per NLML evaluation (constant across evals).
    pub bytes_per_eval: usize,
    pub messages_per_eval: usize,
    /// Summed simulated makespan over all evaluations.
    pub makespan_s: f64,
    /// Real host wall-clock for the whole run.
    pub wall_s: f64,
}

/// Train PITC hyperparameters by Adam on the distributed NLML, starting
/// from `init`. `y` is raw (centered internally); the support set and
/// partition stay fixed across iterations (standard fixed-inducing-set
/// hyperparameter optimization).
pub fn train_pitc(
    init: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
    cfg: &AdamConfig,
) -> TrainResult {
    let wall = Stopwatch::new();
    let n = y.len();
    let y_mean = y.iter().sum::<f64>() / n.max(1) as f64;
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let mut bytes_per_eval = 0usize;
    let mut messages_per_eval = 0usize;
    let mut makespan_s = 0.0;
    let result: OptimResult = minimize(cfg, &init.to_vec(), |theta| {
        let hyp = SeArd::from_vec(theta);
        let ev = nlml_and_grad_dist(&hyp, xd, &yc, xs, d_blocks, spec);
        bytes_per_eval = ev.metrics.bytes_sent;
        messages_per_eval = ev.metrics.messages;
        makespan_s += ev.metrics.makespan;
        (ev.value, ev.grad)
    });
    TrainResult {
        hyp: SeArd::from_vec(&result.theta),
        y_mean,
        nlml_trace: result.trace,
        evals: result.evals,
        rejected: result.rejected,
        bytes_per_eval,
        messages_per_eval,
        makespan_s,
        wall_s: wall.elapsed(),
    }
}

/// Fault-aware twin of [`train_pitc`]: every NLML evaluation goes
/// through [`nlml_and_grad_dist_ft`] (each evaluation replays the
/// spec's fault plan on a fresh simulated cluster). Returns a typed
/// error if an evaluation ever loses all machines.
pub fn try_train_pitc(
    init: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
    cfg: &AdamConfig,
) -> Result<TrainResult, MachinesLost> {
    let wall = Stopwatch::new();
    let n = y.len();
    let y_mean = y.iter().sum::<f64>() / n.max(1) as f64;
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    let p = init.dim() + 2;

    let mut bytes_per_eval = 0usize;
    let mut messages_per_eval = 0usize;
    let mut makespan_s = 0.0;
    let mut lost: Option<MachinesLost> = None;
    let result: OptimResult = minimize(cfg, &init.to_vec(), |theta| {
        if lost.is_some() {
            // cluster already gone: freeze the optimizer state
            return (f64::INFINITY, vec![0.0; p]);
        }
        let hyp = SeArd::from_vec(theta);
        match nlml_and_grad_dist_ft(&hyp, xd, &yc, xs, d_blocks, spec) {
            Ok(ev) => {
                bytes_per_eval = ev.metrics.bytes_sent;
                messages_per_eval = ev.metrics.messages;
                makespan_s += ev.metrics.makespan;
                (ev.value, ev.grad)
            }
            Err(e) => {
                lost = Some(e);
                (f64::INFINITY, vec![0.0; p])
            }
        }
    });
    if let Some(e) = lost {
        return Err(e);
    }
    Ok(TrainResult {
        hyp: SeArd::from_vec(&result.theta),
        y_mean,
        nlml_trace: result.trace,
        evals: result.evals,
        rejected: result.rejected,
        bytes_per_eval,
        messages_per_eval,
        makespan_s,
        wall_s: wall.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FaultPlan;
    use crate::data::partition::random_partition;
    use crate::testkit::assert_all_close;
    use crate::train::nlml::pitc_nlml_and_grad;
    use crate::util::Pcg64;

    struct Problem {
        hyp: SeArd,
        xd: Mat,
        y: Vec<f64>,
        xs: Mat,
        blocks: Vec<Vec<usize>>,
    }

    fn problem(m: usize, per: usize, seed: u64) -> Problem {
        let d = 2;
        let n = m * per;
        let s = 5;
        let mut rng = Pcg64::seed(seed);
        let hyp = SeArd::isotropic(d, 0.9, 1.1, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let mut y = rng.normals(n);
        let mean = y.iter().sum::<f64>() / n as f64;
        for v in y.iter_mut() {
            *v -= mean;
        }
        let blocks = random_partition(n, m, &mut rng);
        Problem { hyp, xd, y, xs, blocks }
    }

    /// Distributed evaluation equals the centralized reference, serial
    /// and thread-parallel.
    #[test]
    fn distributed_equals_centralized() {
        for m in [1usize, 2, 4] {
            let p = problem(m, 5, 40 + m as u64);
            let (want_v, want_g) = pitc_nlml_and_grad(&p.hyp, &p.xd, &p.y,
                                                      &p.xs, &p.blocks);
            for spec in [ClusterSpec::new(m), ClusterSpec::with_threads(m, 3)]
            {
                let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                            &p.blocks, &spec);
                assert!((ev.value - want_v).abs()
                            <= 1e-10 * want_v.abs().max(1.0),
                        "m={m} value {} vs {}", ev.value, want_v);
                assert_all_close(&ev.grad, &want_g, 1e-10, 1e-10);
            }
        }
    }

    /// Phase structure and the O(|S|²) traffic model.
    #[test]
    fn metrics_shape() {
        let m = 4;
        let p = problem(m, 4, 7);
        let s = p.xs.rows;
        let np = p.hyp.dim() + 2;
        let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs, &p.blocks,
                                    &ClusterSpec::new(m));
        let names: Vec<&str> =
            ev.metrics.phases.iter().map(|ph| ph.name.as_str()).collect();
        assert_eq!(names, vec!["support", "local_stats", "assemble",
                               "local_grads", "grad_reduce"]);
        let per_machine =
            2 * s * s + (s * s + s + 2) + (2 * s * s + 2 * s) + np;
        assert_eq!(ev.metrics.bytes_sent, 8 * per_machine * (m - 1));
        assert!(ev.metrics.makespan > 0.0);
    }

    /// Stragglers and successfully-retried drops never change the
    /// numbers: the fault-aware evaluation is bitwise the plain one,
    /// traffic is unchanged, only time + fault counters move.
    #[test]
    fn stragglers_and_retries_bitwise_identical() {
        let m = 4;
        let p = problem(m, 5, 51);
        let base = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                      &p.blocks, &ClusterSpec::new(m));

        let spec = ClusterSpec::new(m).with_faults(
            FaultPlan::seeded(9).with_stragglers(0.5, 1e-3));
        let ev = nlml_and_grad_dist_ft(&p.hyp, &p.xd, &p.y, &p.xs,
                                       &p.blocks, &spec)
            .expect("stragglers never kill");
        assert_eq!(ev.value.to_bits(), base.value.to_bits());
        for (a, b) in ev.grad.iter().zip(base.grad.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ev.metrics.bytes_sent, base.metrics.bytes_sent);
        assert_eq!(ev.metrics.messages, base.metrics.messages);
        assert!(ev.metrics.faults.straggle_events > 0);
        assert_eq!(ev.metrics.faults.deaths, 0);
        assert!(ev.metrics.makespan > base.metrics.makespan);

        let spec = ClusterSpec::new(m).with_faults(
            FaultPlan::seeded(3)
                .with_drops(0.4, 20)
                .with_timeout(1e-4, 2.0));
        let ev = nlml_and_grad_dist_ft(&p.hyp, &p.xd, &p.y, &p.xs,
                                       &p.blocks, &spec)
            .expect("bounded retries should succeed");
        assert_eq!(ev.value.to_bits(), base.value.to_bits());
        for (a, b) in ev.grad.iter().zip(base.grad.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ev.metrics.bytes_sent, base.metrics.bytes_sent);
        assert_eq!(ev.metrics.messages, base.metrics.messages);
        assert!(ev.metrics.faults.retries > 0);
        assert!(ev.metrics.faults.timeouts > 0);
        assert_eq!(ev.metrics.faults.deaths, 0);
    }

    /// Killing a machine at any training phase rebalances its blocks
    /// onto survivors and still evaluates bitwise-identically (the
    /// whole-block adoption property); losing every machine is a typed
    /// error, never a panic.
    #[test]
    fn death_rebalances_and_stays_bitwise() {
        let m = 4;
        let p = problem(m, 5, 52);
        let base = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                      &p.blocks, &ClusterSpec::new(m));
        for phase in ["support", "local_stats", "assemble",
                      "local_grads", "grad_reduce"] {
            let spec = ClusterSpec::new(m)
                .with_faults(FaultPlan::none().kill(2, phase));
            let ev = nlml_and_grad_dist_ft(&p.hyp, &p.xd, &p.y, &p.xs,
                                           &p.blocks, &spec)
                .unwrap_or_else(|e| panic!("{phase}: {e}"));
            assert_eq!(ev.value.to_bits(), base.value.to_bits(),
                       "{phase}");
            for (a, b) in ev.grad.iter().zip(base.grad.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{phase}");
            }
            assert_eq!(ev.metrics.faults.deaths, 1, "{phase}");
            assert!(ev.metrics.faults.rebalances >= 1, "{phase}");
        }
        let mut plan = FaultPlan::none();
        for mm in 0..m {
            plan = plan.kill(mm, "local_stats");
        }
        let err = nlml_and_grad_dist_ft(
            &p.hyp, &p.xd, &p.y, &p.xs, &p.blocks,
            &ClusterSpec::new(m).with_faults(plan))
            .unwrap_err();
        assert_eq!(err.machines, m);
        assert_eq!(err.phase, "local_stats");
    }

    /// Fault-aware training under a straggler plan follows the exact
    /// same optimization trajectory as the plain trainer.
    #[test]
    fn ft_training_matches_plain_trajectory() {
        let m = 3;
        let p = problem(m, 4, 53);
        let init = SeArd::isotropic(2, 1.5, 0.8, 0.3);
        let cfg = AdamConfig { iters: 6, ..Default::default() };
        let plain = train_pitc(&init, &p.xd, &p.y, &p.xs, &p.blocks,
                               &ClusterSpec::new(m), &cfg);
        let spec = ClusterSpec::new(m).with_faults(
            FaultPlan::seeded(5).with_stragglers(0.4, 5e-4));
        let ft = try_train_pitc(&init, &p.xd, &p.y, &p.xs, &p.blocks,
                                &spec, &cfg)
            .expect("stragglers never kill");
        assert_eq!(ft.nlml_trace.len(), plain.nlml_trace.len());
        for (a, b) in ft.nlml_trace.iter().zip(plain.nlml_trace.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ft.hyp.to_vec().iter().zip(plain.hyp.to_vec()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ft.bytes_per_eval, plain.bytes_per_eval);
    }

    /// Training decreases the NLML; with backtracking the trace is
    /// monotone by construction.
    #[test]
    fn training_decreases_nlml() {
        let m = 3;
        let d = 1;
        let mut rng = Pcg64::seed(19);
        let truth = SeArd::isotropic(d, 0.6, 1.2, 0.05);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 48;
        let xd = Mat::from_vec(n, d,
                               (0..n).map(|_| rng.uniform_in(-3.0, 3.0))
                                   .collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(xd.row(i)) + 0.2 * rng.normal())
            .collect();
        let xs = Mat::from_vec(8, d, rng.normals(8));
        let blocks = random_partition(n, m, &mut rng);
        let init = SeArd::isotropic(d, 2.0, 0.5, 0.5);
        let cfg = AdamConfig { iters: 30, backtrack: true,
                               ..Default::default() };
        let r = train_pitc(&init, &xd, &y, &xs, &blocks,
                           &ClusterSpec::new(m), &cfg);
        for w in r.nlml_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace increased: {w:?}");
        }
        let first = r.nlml_trace[0];
        let last = *r.nlml_trace.last().unwrap();
        assert!(last < first - 1.0, "no progress: {first} -> {last}");
        assert!(r.bytes_per_eval > 0);
        assert!(r.evals >= cfg.iters + 1);
        assert!(r.wall_s > 0.0);
    }
}
