//! Distributed PITC NLML/gradient evaluation over the simulated cluster,
//! and the full training loop on top of it.
//!
//! One NLML+gradient evaluation is a two-round protocol on the same
//! cluster topology the prediction protocols use:
//!
//! 1. **support** — the master builds the shared support state
//!    ([`TrainSupport`]) and ships `chol(Σ_SS)` + `K_SS` (machines could
//!    equivalently rebuild it from the cluster-wide S; one copy avoids M
//!    redundant factorizations on the host).
//! 2. **local_stats** — every machine condenses its block into
//!    [`LocalStats`] (O(|S|²) message) — fanned out on the
//!    [`crate::cluster::ParallelExecutor`] thread pool when configured.
//! 3. **assemble** — reduce to the master, assimilate to the NLML value
//!    and the O(|S|²) [`super::nlml::GradBroadcast`]; broadcast back.
//! 4. **local_grads** — every machine reduces its full gradient
//!    contribution to d+2 scalars; a final O(d) reduce finishes ∇NLML.
//!
//! Per-iteration communication is O(|S|²) per machine independent of
//! |D| — the paper's communication-complexity shape carried over to
//! training (cf. Dai et al., arXiv 1410.4984, which distributes exactly
//! this computation). The evaluation is **exact**: it equals
//! [`super::nlml::pitc_nlml_and_grad`] (same block math, same reduction
//! order) to
//! ≤1e-10 whatever M or the executor — asserted by
//! `tests/integration_train.rs`, the training analogue of Theorem 1.

use super::nlml::{
    local_grad_ctx, local_stats_ctx, master_assemble_ctx, LocalStats,
    TrainSupport,
};
use super::optim::{minimize, AdamConfig, OptimResult};
use crate::cluster::mpi::MASTER;
use crate::cluster::RunMetrics;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::parallel::{f64_bytes, ClusterSpec};
use crate::util::Stopwatch;

/// One distributed NLML + gradient evaluation.
#[derive(Debug, Clone)]
pub struct DistEval {
    pub value: f64,
    pub grad: Vec<f64>,
    pub metrics: RunMetrics,
}

/// Evaluate the PITC NLML and its gradient with the per-machine work
/// distributed across `spec`'s cluster. `y` must be centered by the
/// caller; `d_blocks` must have one block per machine.
pub fn nlml_and_grad_dist(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
) -> DistEval {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "train: d_blocks vs machines");
    assert_eq!(xd.rows, y.len(), "train: x/y length");
    let s = xs.rows;
    let p = hyp.dim() + 2;
    let lctx = spec.exec.linalg_ctx();
    let mut cluster = spec.cluster();

    // Round 0: shared support state (chol(Σ_SS) + K_SS, 2·|S|² payload).
    let sup =
        cluster.compute_on(MASTER, || TrainSupport::new_ctx(&lctx, hyp, xs));
    cluster.bcast_from_master(f64_bytes(2 * s * s));
    cluster.phase("support");

    // Round 1: per-machine stats (thread-parallel under the executor).
    let round1 = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> = d_blocks[mid].iter().map(|&i| y[i]).collect();
        local_stats_ctx(&lctx, hyp, &xm, &ym, &sup)
    });
    cluster.phase("local_stats");

    // Reduce stats to the master, assimilate, broadcast gradient state.
    cluster.reduce_to_master(f64_bytes(s * s + s + 2));
    let master = cluster.compute_on(MASTER, || {
        let refs: Vec<&LocalStats> = round1.iter().map(|(st, _)| st).collect();
        master_assemble_ctx(&lctx, hyp, &sup, &refs, xd.rows)
    });
    cluster.bcast_from_master(f64_bytes(2 * s * s + 2 * s));
    cluster.phase("assemble");

    // Round 2: per-machine gradient scalars.
    let grads = cluster.compute_all(|mid| {
        local_grad_ctx(&lctx, hyp, &round1[mid].1, &sup, &master.bcast)
    });
    cluster.phase("local_grads");

    // Final O(d) reduce; master adds its own terms.
    cluster.reduce_to_master(f64_bytes(p));
    let mut grad = master.grad_master.clone();
    for gm in &grads {
        for (acc, v) in grad.iter_mut().zip(gm.iter()) {
            *acc += v;
        }
    }
    cluster.phase("grad_reduce");

    DistEval { value: master.value, grad, metrics: cluster.finish() }
}

/// Result of a distributed training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// Trained hyperparameters.
    pub hyp: SeArd,
    /// Empirical train-output mean subtracted before the NLML (callers
    /// un-center predictions with it, as the prediction paths do).
    pub y_mean: f64,
    /// NLML at the init followed by each iteration's accepted value.
    pub nlml_trace: Vec<f64>,
    /// Objective evaluations performed / backtracking rejections.
    pub evals: usize,
    pub rejected: usize,
    /// Modeled communication per NLML evaluation (constant across evals).
    pub bytes_per_eval: usize,
    pub messages_per_eval: usize,
    /// Summed simulated makespan over all evaluations.
    pub makespan_s: f64,
    /// Real host wall-clock for the whole run.
    pub wall_s: f64,
}

/// Train PITC hyperparameters by Adam on the distributed NLML, starting
/// from `init`. `y` is raw (centered internally); the support set and
/// partition stay fixed across iterations (standard fixed-inducing-set
/// hyperparameter optimization).
pub fn train_pitc(
    init: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
    spec: &ClusterSpec,
    cfg: &AdamConfig,
) -> TrainResult {
    let wall = Stopwatch::new();
    let n = y.len();
    let y_mean = y.iter().sum::<f64>() / n.max(1) as f64;
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let mut bytes_per_eval = 0usize;
    let mut messages_per_eval = 0usize;
    let mut makespan_s = 0.0;
    let result: OptimResult = minimize(cfg, &init.to_vec(), |theta| {
        let hyp = SeArd::from_vec(theta);
        let ev = nlml_and_grad_dist(&hyp, xd, &yc, xs, d_blocks, spec);
        bytes_per_eval = ev.metrics.bytes_sent;
        messages_per_eval = ev.metrics.messages;
        makespan_s += ev.metrics.makespan;
        (ev.value, ev.grad)
    });
    TrainResult {
        hyp: SeArd::from_vec(&result.theta),
        y_mean,
        nlml_trace: result.trace,
        evals: result.evals,
        rejected: result.rejected,
        bytes_per_eval,
        messages_per_eval,
        makespan_s,
        wall_s: wall.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::testkit::assert_all_close;
    use crate::train::nlml::pitc_nlml_and_grad;
    use crate::util::Pcg64;

    struct Problem {
        hyp: SeArd,
        xd: Mat,
        y: Vec<f64>,
        xs: Mat,
        blocks: Vec<Vec<usize>>,
    }

    fn problem(m: usize, per: usize, seed: u64) -> Problem {
        let d = 2;
        let n = m * per;
        let s = 5;
        let mut rng = Pcg64::seed(seed);
        let hyp = SeArd::isotropic(d, 0.9, 1.1, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let mut y = rng.normals(n);
        let mean = y.iter().sum::<f64>() / n as f64;
        for v in y.iter_mut() {
            *v -= mean;
        }
        let blocks = random_partition(n, m, &mut rng);
        Problem { hyp, xd, y, xs, blocks }
    }

    /// Distributed evaluation equals the centralized reference, serial
    /// and thread-parallel.
    #[test]
    fn distributed_equals_centralized() {
        for m in [1usize, 2, 4] {
            let p = problem(m, 5, 40 + m as u64);
            let (want_v, want_g) = pitc_nlml_and_grad(&p.hyp, &p.xd, &p.y,
                                                      &p.xs, &p.blocks);
            for spec in [ClusterSpec::new(m), ClusterSpec::with_threads(m, 3)]
            {
                let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs,
                                            &p.blocks, &spec);
                assert!((ev.value - want_v).abs()
                            <= 1e-10 * want_v.abs().max(1.0),
                        "m={m} value {} vs {}", ev.value, want_v);
                assert_all_close(&ev.grad, &want_g, 1e-10, 1e-10);
            }
        }
    }

    /// Phase structure and the O(|S|²) traffic model.
    #[test]
    fn metrics_shape() {
        let m = 4;
        let p = problem(m, 4, 7);
        let s = p.xs.rows;
        let np = p.hyp.dim() + 2;
        let ev = nlml_and_grad_dist(&p.hyp, &p.xd, &p.y, &p.xs, &p.blocks,
                                    &ClusterSpec::new(m));
        let names: Vec<&str> =
            ev.metrics.phases.iter().map(|ph| ph.name.as_str()).collect();
        assert_eq!(names, vec!["support", "local_stats", "assemble",
                               "local_grads", "grad_reduce"]);
        let per_machine =
            2 * s * s + (s * s + s + 2) + (2 * s * s + 2 * s) + np;
        assert_eq!(ev.metrics.bytes_sent, 8 * per_machine * (m - 1));
        assert!(ev.metrics.makespan > 0.0);
    }

    /// Training decreases the NLML; with backtracking the trace is
    /// monotone by construction.
    #[test]
    fn training_decreases_nlml() {
        let m = 3;
        let d = 1;
        let mut rng = Pcg64::seed(19);
        let truth = SeArd::isotropic(d, 0.6, 1.2, 0.05);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 48;
        let xd = Mat::from_vec(n, d,
                               (0..n).map(|_| rng.uniform_in(-3.0, 3.0))
                                   .collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(xd.row(i)) + 0.2 * rng.normal())
            .collect();
        let xs = Mat::from_vec(8, d, rng.normals(8));
        let blocks = random_partition(n, m, &mut rng);
        let init = SeArd::isotropic(d, 2.0, 0.5, 0.5);
        let cfg = AdamConfig { iters: 30, backtrack: true,
                               ..Default::default() };
        let r = train_pitc(&init, &xd, &y, &xs, &blocks,
                           &ClusterSpec::new(m), &cfg);
        for w in r.nlml_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace increased: {w:?}");
        }
        let first = r.nlml_trace[0];
        let last = *r.nlml_trace.last().unwrap();
        assert!(last < first - 1.0, "no progress: {first} -> {last}");
        assert!(r.bytes_per_eval > 0);
        assert!(r.evals >= cfg.iters + 1);
        assert!(r.wall_s > 0.0);
    }
}
