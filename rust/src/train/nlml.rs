//! Closed-form PITC negative log marginal likelihood and its analytic
//! gradient, decomposed into per-machine statistics of support-set size.
//!
//! # The training objective
//!
//! Under the PITC model the training outputs are jointly Gaussian with
//! covariance
//!
//! ```text
//! C = Q + Λ,   Q = Σ_DS Σ_SS⁻¹ Σ_SD,   Λ = blockdiag(Σ_DmDm − Q_mm)
//! ```
//!
//! (exactly the covariance whose predictive conditionals the pPITC
//! protocol computes — see `gp/pitc.rs::pitc_direct_oracle`), and
//!
//! ```text
//! NLML(θ) = ½ yᵀC⁻¹y + ½ log|C| + n/2·log 2π
//! ```
//!
//! for centered `y`. Jitter conventions match the prediction path
//! bit-for-bit: `Σ_SS = K_SS + sn2·I + jitter·I` and each machine's
//! `Λ_m` is what `gp::summaries::local_summary` factorizes, so a hyper
//! vector trained here is consumed unchanged by `PitcGp` / pPITC / pPIC
//! and the serving pipeline.
//!
//! # Why it distributes (the training analogue of Theorem 1)
//!
//! By the Woodbury identity, with `A = Σ_SS + Σ_m K_Sm Λ_m⁻¹ K_mS`:
//!
//! ```text
//! yᵀC⁻¹y = Σ_m y_mᵀΛ_m⁻¹y_m − bᵀA⁻¹b,      b = Σ_m K_Sm Λ_m⁻¹ y_m
//! log|C| = log|A| − log|Σ_SS| + Σ_m log|Λ_m|
//! ```
//!
//! so machine m contributes only `(B_m, b_m, q_m, ld_m)` — an |S|×|S|
//! matrix, an |S|-vector and two scalars ([`LocalStats`], the training
//! analogue of Definition 2's local summary, same O(|S|²) message). The
//! gradient distributes the same way: after one O(|S|²) broadcast of
//! master state ([`GradBroadcast`]), each machine reduces its entire
//! d+2-dimensional gradient contribution to scalars ([`local_grad_ctx`]),
//! with per-hyper work done by the expansion trick
//! ([`SeArd::grad_dots`]) — no per-hyperparameter dK matrix is ever
//! materialized. Distributed and centralized evaluations are the *same
//! block math in the same order* — `train/dist.rs` asserts ≤1e-10
//! agreement, mirroring Theorem 1.
//!
//! Formulas were cross-validated against a dense-C oracle and central
//! finite differences (≤1e-9 relative) before transcription; the unit
//! tests below re-establish both properties in-tree.

use crate::kernel::SeArd;
use crate::linalg::cholesky::logdet_from_chol;
use crate::linalg::{
    cho_solve_mat_ctx, cho_solve_vec, cholesky_blocked, dot, gemm, gemm_nt,
    gemm_tn, matvec, matvec_t, solve_lower_mat_ctx, solve_upper_t_mat_ctx,
    LinalgCtx, Mat,
};

/// Support-set state shared by every machine during training (the paper
/// assumes S is known cluster-wide). Built once per NLML evaluation.
#[derive(Debug, Clone)]
pub struct TrainSupport {
    pub xs: Mat,
    /// Noise-free K_SS (reused by the gradient expansion trick).
    pub k0_ss: Mat,
    /// Σ_SS = K_SS + sn2·I + jitter·I — the same matrix
    /// `gp::summaries::SupportContext` factorizes for prediction.
    pub s_mat: Mat,
    /// chol(Σ_SS)
    pub l_s: Mat,
    /// log|Σ_SS|
    pub logdet_s: f64,
}

impl TrainSupport {
    pub fn new(hyp: &SeArd, xs: &Mat) -> TrainSupport {
        TrainSupport::new_ctx(&LinalgCtx::serial(), hyp, xs)
    }

    /// [`TrainSupport::new`] with explicit linalg execution context.
    pub fn new_ctx(lctx: &LinalgCtx, hyp: &SeArd, xs: &Mat) -> TrainSupport {
        let k0_ss = hyp.gram_ctx(lctx, xs, xs);
        let mut s_mat = k0_ss.clone();
        s_mat.add_diag(hyp.sn2() + hyp.jitter());
        let l_s = cholesky_blocked(lctx, &s_mat).expect("train: Σ_SS not SPD");
        let logdet_s = logdet_from_chol(&l_s);
        TrainSupport { xs: xs.clone(), k0_ss, s_mat, l_s, logdet_s }
    }

    pub fn size(&self) -> usize {
        self.xs.rows
    }
}

/// Machine m's round-1 training statistics — everything the master needs
/// for the NLML value. The O(|S|²) message of the training protocol.
#[derive(Debug, Clone)]
pub struct LocalStats {
    /// `b_m = K_Sm Λ_m⁻¹ y_m` (|S|)
    pub b: Vec<f64>,
    /// `B_m = K_Sm Λ_m⁻¹ K_mS` (|S|×|S|)
    pub t: Mat,
    /// `y_mᵀ Λ_m⁻¹ y_m`
    pub quad: f64,
    /// `log|Λ_m|`
    pub logdet: f64,
}

impl LocalStats {
    /// f64 payload count of the machine→master message.
    pub fn message_f64s(&self) -> usize {
        self.b.len() + self.t.data.len() + 2
    }
}

/// Machine m's retained local state between the stats and gradient
/// rounds (never communicated — it stays on the machine, like the data
/// block itself).
#[derive(Debug, Clone)]
pub struct LocalState {
    pub xm: Mat,
    /// Noise-free cross block K_mS (B×|S|).
    pub k_ms: Mat,
    /// Noise-free same-set block K_mm (B×B).
    pub k0_mm: Mat,
    /// Λ_m⁻¹ (B×B).
    pub lam_inv: Mat,
    /// W_m = Λ_m⁻¹ K_mS (B×|S|).
    pub w: Mat,
    /// L_S⁻¹ K_Sm (|S|×B) — the forward half of the Σ_SS⁻¹K_Sm solve,
    /// retained from round 1 so round 2 only runs the backward half.
    pub w0: Mat,
    /// Λ_m⁻¹ y_m (B).
    pub lam_inv_y: Vec<f64>,
}

/// Round 1 on machine m: factorize Λ_m and condense the block into
/// [`LocalStats`]. `ym` must be centered by the caller.
pub fn local_stats(
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    sup: &TrainSupport,
) -> (LocalStats, LocalState) {
    local_stats_ctx(&LinalgCtx::serial(), hyp, xm, ym, sup)
}

/// [`local_stats`] with explicit linalg execution context (pooled runs
/// are bitwise-identical to serial — engine guarantee).
pub fn local_stats_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    sup: &TrainSupport,
) -> (LocalStats, LocalState) {
    let b_rows = xm.rows;
    assert_eq!(ym.len(), b_rows, "train: ym length");
    let k_ms = hyp.cov_cross_ctx(lctx, xm, &sup.xs); // (B, S)
    let k0_mm = hyp.gram_ctx(lctx, xm, xm); // (B, B)
    // Λ_m = Σ_mm − K_mS Σ_SS⁻¹ K_Sm via the half-solve, exactly as
    // local_summary builds its Σ_mm|S
    let w0 = solve_lower_mat_ctx(lctx, &sup.l_s, &k_ms.transpose()); // (S, B)
    let q_mm = gemm_tn(lctx, &w0, &w0); // (B, B)
    let mut lam = k0_mm.clone();
    lam.add_diag(hyp.sn2() + hyp.jitter());
    lam.sub_assign(&q_mm);
    let l_m = cholesky_blocked(lctx, &lam).expect("train: Λ_m not SPD");
    let lam_inv = cho_solve_mat_ctx(lctx, &l_m, &Mat::identity(b_rows));
    let w = cho_solve_mat_ctx(lctx, &l_m, &k_ms); // (B, S)
    let lam_inv_y = cho_solve_vec(&l_m, ym);
    let stats = LocalStats {
        b: matvec_t(&k_ms, &lam_inv_y),
        t: gemm_tn(lctx, &k_ms, &w),
        quad: dot(ym, &lam_inv_y),
        logdet: logdet_from_chol(&l_m),
    };
    let state = LocalState {
        xm: xm.clone(),
        k_ms,
        k0_mm,
        lam_inv,
        w,
        w0,
        lam_inv_y,
    };
    (stats, state)
}

/// What the master broadcasts back for the gradient round — O(|S|²).
#[derive(Debug, Clone)]
pub struct GradBroadcast {
    /// chol(A), A = Σ_SS + Σ_m B_m.
    pub l_a: Mat,
    /// v = A⁻¹ b.
    pub v: Vec<f64>,
    /// M = Σ_SS⁻¹ (Σ B_m) A⁻¹.
    pub m_mat: Mat,
    /// ĝ = Σ_SS⁻¹ K_SD α (computed master-side as Σ_SS⁻¹(b − Tv)).
    pub g_hat: Vec<f64>,
}

impl GradBroadcast {
    /// f64 payload count of the master→machines broadcast.
    pub fn message_f64s(&self) -> usize {
        self.l_a.data.len() + self.v.len() + self.m_mat.data.len()
            + self.g_hat.len()
    }
}

/// Master state after assimilating round-1 stats: the NLML value, the
/// broadcast package for round 2, and the master-only gradient terms.
#[derive(Debug, Clone)]
pub struct MasterState {
    pub value: f64,
    pub bcast: GradBroadcast,
    /// Gradient terms computable only at the master
    /// (½·dot(N + ĝĝᵀ, ∂Σ_SS), N = Σ_SS⁻¹ T A⁻¹ T Σ_SS⁻¹).
    pub grad_master: Vec<f64>,
}

/// Assimilate round-1 stats (in machine order — the fixed reduction
/// order that makes distributed ≡ centralized exact). `n` is the total
/// training size (for the ½·n·log 2π constant).
pub fn master_assemble(
    hyp: &SeArd,
    sup: &TrainSupport,
    stats: &[&LocalStats],
    n: usize,
) -> MasterState {
    master_assemble_ctx(&LinalgCtx::serial(), hyp, sup, stats, n)
}

/// [`master_assemble`] with explicit linalg execution context.
pub fn master_assemble_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    sup: &TrainSupport,
    stats: &[&LocalStats],
    n: usize,
) -> MasterState {
    assert!(!stats.is_empty(), "train: no machines");
    let s = sup.size();
    let mut t_sum = Mat::zeros(s, s);
    let mut b_sum = vec![0.0; s];
    let mut quad = 0.0;
    let mut ld = 0.0;
    for st in stats {
        assert_eq!(st.b.len(), s, "train: stats size");
        t_sum.add_assign(&st.t);
        for (acc, v) in b_sum.iter_mut().zip(st.b.iter()) {
            *acc += v;
        }
        quad += st.quad;
        ld += st.logdet;
    }
    let mut a = sup.s_mat.clone();
    a.add_assign(&t_sum);
    let l_a = cholesky_blocked(lctx, &a).expect("train: A not SPD");
    let v = cho_solve_vec(&l_a, &b_sum);
    let logdet = logdet_from_chol(&l_a) - sup.logdet_s + ld;
    let value = 0.5 * (quad - dot(&b_sum, &v))
        + 0.5 * logdet
        + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // ĝ = Σ_SS⁻¹ K_SD α collapses to Σ_SS⁻¹ (b − T v) at the master.
    let tv = matvec(&t_sum, &v);
    let g: Vec<f64> = b_sum.iter().zip(tv.iter()).map(|(a, b)| a - b).collect();
    let g_hat = cho_solve_vec(&sup.l_s, &g);
    let sinv_t = cho_solve_mat_ctx(lctx, &sup.l_s, &t_sum); // Σ_SS⁻¹ T
    let t_sinv = sinv_t.transpose(); // T Σ_SS⁻¹ (T, Σ_SS symmetric)
    // M = Σ_SS⁻¹ T A⁻¹ = (A⁻¹ T Σ_SS⁻¹)ᵀ
    let m_mat = cho_solve_mat_ctx(lctx, &l_a, &t_sinv).transpose();
    let n_mat = gemm(lctx, &m_mat, &t_sinv); // N = Σ⁻¹TA⁻¹TΣ⁻¹ (symmetric)

    // master-only gradient: ½·dot(N + ĝĝᵀ, ∂Σ_SS/∂θ_p) per hyper
    let mut coef = n_mat;
    for i in 0..s {
        for j in 0..s {
            coef[(i, j)] += g_hat[i] * g_hat[j];
        }
    }
    let mut grad_master =
        hyp.grad_dots(&coef, &sup.k0_ss, &sup.xs, &sup.xs, true);
    for gp in grad_master.iter_mut() {
        *gp *= 0.5;
    }
    MasterState {
        value,
        bcast: GradBroadcast { l_a, v, m_mat, g_hat },
        grad_master,
    }
}

/// Round 2 on machine m: the machine's full gradient contribution, one
/// scalar per log-hyperparameter. All inputs are either machine-local
/// ([`LocalState`], the shared support) or the O(|S|²) broadcast.
pub fn local_grad(
    hyp: &SeArd,
    state: &LocalState,
    sup: &TrainSupport,
    bc: &GradBroadcast,
) -> Vec<f64> {
    local_grad_ctx(&LinalgCtx::serial(), hyp, state, sup, bc)
}

/// [`local_grad`] with explicit linalg execution context.
pub fn local_grad_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    state: &LocalState,
    sup: &TrainSupport,
    bc: &GradBroadcast,
) -> Vec<f64> {
    let b_rows = state.xm.rows;
    let s = sup.size();
    let p = hyp.dim() + 2;
    // α_m = Λ_m⁻¹ y_m − W_m v  (the machine's slice of C⁻¹y)
    let wv = matvec(&state.w, &bc.v);
    let alpha: Vec<f64> = state
        .lam_inv_y
        .iter()
        .zip(wv.iter())
        .map(|(a, b)| a - b)
        .collect();
    // c_m = Σ_SS⁻¹ K_Sm α_m
    let ksa = matvec_t(&state.k_ms, &alpha);
    let c = cho_solve_vec(&sup.l_s, &ksa);
    // R_m = W_m A⁻¹ W_mᵀ via the half-solve (symmetric PSD)
    let x1 = solve_lower_mat_ctx(lctx, &bc.l_a, &state.w.transpose()); // (S,B)
    let r_m = gemm_tn(lctx, &x1, &x1); // (B, B)
    // Y_m = Σ_SS⁻¹ K_Sm — finish the solve whose forward half (w0) round
    // 1 already did; bitwise-identical to a fresh cho_solve (which is
    // exactly this two-solve composition).
    let y_mat = solve_upper_t_mat_ctx(lctx, &sup.l_s, &state.w0);
    let z1 = gemm_nt(lctx, &state.w, &bc.m_mat); // W Mᵀ (B, S)
    let z2 = gemm_nt(lctx, &r_m, &y_mat); // R Yᵀ (B, S)
    let tmp = gemm(lctx, &y_mat, &r_m); // (S, B)
    let v_m = gemm_nt(lctx, &tmp, &y_mat); // Y R Yᵀ (S, S)

    // Coefficient matrices: grad contribution =
    //   ½·[dot(E, ∂Σ_mm) + dot(F, ∂K_mS) + dot(H, ∂Σ_SS)]
    let mut e = state.lam_inv.clone(); // E = Λ⁻¹ − R − ααᵀ
    e.sub_assign(&r_m);
    for i in 0..b_rows {
        for j in 0..b_rows {
            e[(i, j)] -= alpha[i] * alpha[j];
        }
    }
    let mut f = Mat::zeros(b_rows, s); // F = 2(−Z1 + Z2 − αĝᵀ + αcᵀ)
    for i in 0..b_rows {
        for j in 0..s {
            f[(i, j)] = 2.0
                * (z2[(i, j)] - z1[(i, j)]
                    + alpha[i] * (c[j] - bc.g_hat[j]));
        }
    }
    let mut h = v_m; // H = −V − ccᵀ
    h.scale(-1.0);
    for i in 0..s {
        for j in 0..s {
            h[(i, j)] -= c[i] * c[j];
        }
    }

    let ge = hyp.grad_dots(&e, &state.k0_mm, &state.xm, &state.xm, true);
    let gf = hyp.grad_dots(&f, &state.k_ms, &state.xm, &sup.xs, false);
    let gh = hyp.grad_dots(&h, &sup.k0_ss, &sup.xs, &sup.xs, true);
    (0..p).map(|k| 0.5 * (ge[k] + gf[k] + gh[k])).collect()
}

/// Centralized (single-machine) PITC NLML + gradient: the same block
/// math as the distributed path, executed serially in machine order.
/// `y` must be centered by the caller. This is the reference
/// `train/dist.rs` is asserted equal to (≤1e-10) — the training
/// analogue of Theorem 1.
pub fn pitc_nlml_and_grad(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
) -> (f64, Vec<f64>) {
    pitc_nlml_and_grad_ctx(&LinalgCtx::serial(), hyp, xd, y, xs, d_blocks)
}

/// [`pitc_nlml_and_grad`] with explicit linalg execution context.
pub fn pitc_nlml_and_grad_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
) -> (f64, Vec<f64>) {
    assert_eq!(xd.rows, y.len(), "train: x/y length");
    assert!(!d_blocks.is_empty(), "train: no blocks");
    let sup = TrainSupport::new_ctx(lctx, hyp, xs);
    let mut stats = Vec::with_capacity(d_blocks.len());
    let mut states = Vec::with_capacity(d_blocks.len());
    for blk in d_blocks {
        let xm = xd.select_rows(blk);
        let ym: Vec<f64> = blk.iter().map(|&i| y[i]).collect();
        let (st, state) = local_stats_ctx(lctx, hyp, &xm, &ym, &sup);
        stats.push(st);
        states.push(state);
    }
    let refs: Vec<&LocalStats> = stats.iter().collect();
    let master = master_assemble_ctx(lctx, hyp, &sup, &refs, xd.rows);
    let mut grad = master.grad_master.clone();
    for state in &states {
        let gm = local_grad_ctx(lctx, hyp, state, &sup, &master.bcast);
        for (acc, v) in grad.iter_mut().zip(gm.iter()) {
            *acc += v;
        }
    }
    (master.value, grad)
}

/// Dense O(n³) oracle: builds the full PITC covariance C and evaluates
/// the NLML directly. Test-only ground truth (value; gradients are
/// checked against finite differences of this).
pub fn pitc_nlml_dense_oracle(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    d_blocks: &[Vec<usize>],
) -> f64 {
    use crate::linalg::{cho_solve_mat, cholesky, matmul};
    let n = xd.rows;
    let sj = hyp.cov_same(xs, true);
    let l_s = cholesky(&sj).expect("oracle: Σ_SS not SPD");
    let k_ds = hyp.cov_cross(xd, xs);
    let q = matmul(&k_ds, &cho_solve_mat(&l_s, &k_ds.transpose()));
    let sigma = hyp.cov_same(xd, true);
    let mut c = q;
    for blk in d_blocks {
        for &i in blk {
            for &j in blk {
                c[(i, j)] = sigma[(i, j)];
            }
        }
    }
    let l_c = cholesky(&c).expect("oracle: C not SPD");
    let alpha = cho_solve_vec(&l_c, y);
    0.5 * dot(y, &alpha)
        + 0.5 * logdet_from_chol(&l_c)
        + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.4, 0.4),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// Woodbury block value == dense-C oracle.
    #[test]
    fn value_matches_dense_oracle() {
        prop_check("train-value-oracle", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let per = g.usize_in(3, 6);
            let n = m * per;
            let s = g.usize_in(3, 6);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let mut y = g.normal_vec(n);
            let mean = y.iter().sum::<f64>() / n as f64;
            for v in y.iter_mut() {
                *v -= mean;
            }
            let blocks = random_partition(n, m, g.rng());
            let (value, _) = pitc_nlml_and_grad(&hyp, &xd, &y, &xs, &blocks);
            let want = pitc_nlml_dense_oracle(&hyp, &xd, &y, &xs, &blocks);
            assert_close(value, want, 1e-9, 1e-9);
        });
    }

    /// Analytic gradient == central finite differences of the value.
    #[test]
    fn gradient_matches_finite_differences() {
        prop_check("train-grad-fd", 4, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 3);
            let per = g.usize_in(3, 5);
            let n = m * per;
            let s = g.usize_in(3, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let mut y = g.normal_vec(n);
            let mean = y.iter().sum::<f64>() / n as f64;
            for v in y.iter_mut() {
                *v -= mean;
            }
            let blocks = random_partition(n, m, g.rng());
            let (_, grad) = pitc_nlml_and_grad(&hyp, &xd, &y, &xs, &blocks);
            let theta = hyp.to_vec();
            let eps = 1e-6;
            for p in 0..theta.len() {
                let mut tp = theta.clone();
                tp[p] += eps;
                let mut tm = theta.clone();
                tm[p] -= eps;
                let (vp, _) = pitc_nlml_and_grad(&SeArd::from_vec(&tp), &xd,
                                                 &y, &xs, &blocks);
                let (vm, _) = pitc_nlml_and_grad(&SeArd::from_vec(&tm), &xd,
                                                 &y, &xs, &blocks);
                let fd = (vp - vm) / (2.0 * eps);
                assert_close(grad[p], fd, 1e-4, 1e-6);
            }
        });
    }

    /// M = 1: C = Σ_DD, so the PITC NLML is the exact (jittered) GP NLML
    /// — it must match `gp::likelihood::nlml_and_grad` on value, and on
    /// gradient up to the (≈1e-8-relative) jitter-derivative term that
    /// the exact path deliberately ignores.
    #[test]
    fn single_block_equals_exact_gp() {
        let mut rng = crate::util::Pcg64::seed(21);
        let (n, d, s) = (14, 2, 5);
        let hyp = SeArd {
            log_ls: vec![0.2, -0.1],
            log_sf2: 0.3,
            log_sn2: -1.8,
        };
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let mut y = rng.normals(n);
        let mean = y.iter().sum::<f64>() / n as f64;
        for v in y.iter_mut() {
            *v -= mean;
        }
        let blocks = vec![(0..n).collect::<Vec<usize>>()];
        let (value, grad) = pitc_nlml_and_grad(&hyp, &xd, &y, &xs, &blocks);
        let (want_v, want_g) =
            crate::gp::likelihood::nlml_and_grad(&hyp, &xd, &y);
        assert_close(value, want_v, 1e-9, 1e-9);
        for (a, b) in grad.iter().zip(want_g.iter()) {
            assert_close(*a, *b, 1e-5, 1e-5);
        }
    }

    /// Pooled execution is exactly equal to serial (engine bitwise
    /// guarantee propagated through the training math).
    #[test]
    fn pooled_equals_serial() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        let mut rng = crate::util::Pcg64::seed(33);
        let (n, d, s, m) = (24, 2, 5, 4);
        let hyp = SeArd::isotropic(d, 0.9, 1.1, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let y = rng.normals(n);
        let blocks = random_partition(n, m, &mut rng);
        let serial = pitc_nlml_and_grad(&hyp, &xd, &y, &xs, &blocks);
        let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        let pooled =
            pitc_nlml_and_grad_ctx(&ctx, &hyp, &xd, &y, &xs, &blocks);
        assert_eq!(serial.0.to_bits(), pooled.0.to_bits(), "value drifted");
        assert_eq!(serial.1, pooled.1, "gradient drifted");
    }

    /// Message sizes are the paper-style O(|S|²) quantities.
    #[test]
    fn message_sizes() {
        let mut rng = crate::util::Pcg64::seed(5);
        let (n, d, s) = (8, 2, 4);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let y = rng.normals(n);
        let sup = TrainSupport::new(&hyp, &xs);
        let (st, state) = local_stats(&hyp, &xd, &y, &sup);
        assert_eq!(st.message_f64s(), s * s + s + 2);
        let master = master_assemble(&hyp, &sup, &[&st], n);
        assert_eq!(master.bcast.message_f64s(), 2 * s * s + 2 * s);
        let grad = local_grad(&hyp, &state, &sup, &master.bcast);
        assert_eq!(grad.len(), d + 2);
    }
}
