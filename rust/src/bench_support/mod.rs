//! Benchmark support: workload preparation, the per-figure experiment
//! harness, a micro-benchmark timing loop, and table rendering. Shared
//! by `rust/benches/*` (cargo bench) and the `pgpr sweep` CLI.

pub mod experiments;
pub mod figures;
pub mod harness;
pub mod table;
pub mod workloads;

pub use experiments::{run_methods, ExperimentConfig, Method, MethodResult};
pub use harness::{bench_fn, BenchResult};
pub use table::Table;
pub use workloads::{prepare, Domain, Workload};
