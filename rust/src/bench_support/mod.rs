//! Benchmark support: workload preparation, the per-figure experiment
//! harness, a micro-benchmark timing loop, and table rendering. Shared
//! by `rust/benches/*` (cargo bench) and the `pgpr sweep` CLI.

pub mod experiments;
pub mod figures;
pub mod harness;
pub mod linalg_bench;
pub mod serve_bench;
pub mod table;
pub mod train_bench;
pub mod workloads;

pub use experiments::{run_methods, ExperimentConfig, Method, MethodResult};
pub use harness::{bench_fn, BenchResult};
pub use table::Table;
pub use workloads::{prepare, Domain, Workload};

/// Boolean `PGPR_*` env flag: set and neither empty nor `"0"`. The
/// shared truthiness convention of the bench sweeps
/// (`PGPR_LINALG_SMOKE`, `PGPR_TRAIN_SMOKE`, `PGPR_LENIENT_PERF`).
pub fn env_flag(name: &str) -> bool {
    match std::env::var_os(name) {
        Some(v) => v != "0" && !v.is_empty(),
        None => false,
    }
}

/// Telemetry snapshot destination for bench mains: the
/// `--telemetry-out=PATH` argument (equals form only — via
/// [`crate::cli::args::process_eq`] — so the mains'
/// "first non-dash argument is the out path" scanning is untouched),
/// falling back to the `PGPR_TELEMETRY_OUT` env var. `None` when
/// neither is given.
pub fn telemetry_out_from_args() -> Option<String> {
    if let Some(p) = crate::cli::args::process_eq("telemetry-out") {
        return Some(p);
    }
    std::env::var("PGPR_TELEMETRY_OUT").ok().filter(|s| !s.is_empty())
}

/// Write the global registry's full telemetry snapshot as pretty JSON
/// to `path` (bench mains, after their sweep). Callers that take a
/// [`telemetry_out_from_args`] destination should
/// `crate::obsv::set_enabled(true)` *before* the sweep — an explicit
/// `--telemetry-out` must never produce an empty document.
pub fn write_telemetry_snapshot(path: &str) {
    let snap = crate::obsv::snapshot(crate::obsv::SnapshotMode::Full);
    std::fs::write(path, snap.to_json().to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote telemetry snapshot {path}");
}

/// Host worker threads for bench mains, from `PGPR_BENCH_THREADS`
/// (unset = 0 = serial). Panics on an unparsable value — mirroring
/// `PGPR_BENCH_SCALE` — so a typo can't silently produce a serial run
/// and wrong wall-clock conclusions.
pub fn threads_from_env() -> usize {
    match std::env::var_os("PGPR_BENCH_THREADS") {
        None => 0,
        // var_os so a non-Unicode value also panics instead of silently
        // reading as unset
        Some(v) => v
            .to_str()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or_else(|| {
                panic!("PGPR_BENCH_THREADS must be an integer, got {v:?}")
            }),
    }
}
