//! The `linalg_bench` sweep: blocked-engine kernels × sizes × thread
//! counts, against the seed scalar baselines, written to
//! `BENCH_linalg.json` — the repo's perf trajectory seed (§Perf in the
//! README; CI runs the smoke mode and uploads the JSON as an artifact).
//!
//! Modes (env):
//! * `PGPR_LINALG_SMOKE=1` — small sizes/thread counts and a tiny time
//!   budget, for CI smoke runs; perf gates are skipped.
//! * `PGPR_LENIENT_PERF=1` — keep the perf gates advisory (print but
//!   don't fail) on oversubscribed/shared hosts, matching the PR-1
//!   convention in `tests/integration_parallel_exec.rs`.
//!
//! Gates (full mode, largest size): blocked GEMM ≥2× the seed scalar
//! kernel single-thread, >1× scaling from 1 to ≥4 threads, ≥1.5×
//! scaling from 1 to 2 threads, and — when AVX-512 is the active tier
//! — the vectorized GEMM ≥1.5× the portable microkernel single-thread
//! (the portable rung itself auto-vectorizes under `target-cpu=native`,
//! so the honest explicit-SIMD margin over it is smaller than the
//! ~3.8× margin over the seed scalar kernel; the C-mirror sweep
//! measured 1.73×).
//! In every mode (smoke included) handing a pool to any kernel must
//! not cost more than 10% over serial at any measured size (the
//! small-problem serial-fallback cutoffs make this hold); all checks
//! go advisory under `PGPR_LENIENT_PERF=1`. The telemetry record
//! sites in [`crate::linalg::LinalgCtx`]'s pool dispatch sit *inside*
//! the measured kernels, so with `PGPR_TELEMETRY=0` this pooled ≤10%
//! gate doubles as the disabled-mode overhead assertion (every record
//! call must reduce to one relaxed atomic load); the run prints and
//! records which state it measured under `config.telemetry_enabled`.
//!
//! The SIMD dispatch ladder is measured rung by rung at the largest
//! size: one forced-tier single-thread case per supported tier
//! (`gemm_portable`, `gemm_avx2`, … — see
//! [`crate::linalg::force_tier`]), with the active tier and the
//! vectorized-vs-portable speedups surfaced under `derived`.

use std::sync::Arc;

use crate::bench_support::harness::bench_fn;
use crate::kernel::SeArd;
use crate::linalg::{active_tier, cholesky_blocked, cholesky_scalar,
                    force_tier, gemm, solve_lower_mat_ctx, LinalgCtx, Mat,
                    SimdTier};
use crate::linalg::cholesky::solve_lower_mat_scalar;
use crate::linalg::matmul_scalar;
use crate::util::json::{obj, Json};
use crate::util::pool::ThreadPool;
use crate::util::Pcg64;

/// Sweep configuration.
pub struct LinalgBenchConfig {
    pub sizes: Vec<usize>,
    pub threads: Vec<usize>,
    /// Per-case measurement budget in seconds.
    pub budget_s: f64,
    pub smoke: bool,
    pub lenient: bool,
}

impl LinalgBenchConfig {
    /// Full sweep unless `PGPR_LINALG_SMOKE=1`; gates advisory when
    /// `PGPR_LENIENT_PERF=1` (both matching the repo's env conventions).
    pub fn from_env() -> LinalgBenchConfig {
        let flag = crate::bench_support::env_flag;
        let smoke = flag("PGPR_LINALG_SMOKE");
        if smoke {
            LinalgBenchConfig {
                sizes: vec![128, 256],
                threads: vec![1, 2],
                budget_s: 0.15,
                smoke: true,
                lenient: true,
            }
        } else {
            LinalgBenchConfig {
                sizes: vec![128, 256, 512, 1024],
                threads: vec![1, 2, 4, 8],
                budget_s: 1.2,
                smoke: false,
                lenient: flag("PGPR_LENIENT_PERF"),
            }
        }
    }
}

/// One measured case. `wall_s` is the median sample; `min_s` is the
/// fastest sample — the noise-robust statistic the derived ratios use
/// (shared hosts can slow arbitrary samples, never speed them up).
struct Case {
    kernel: String,
    n: usize,
    threads: usize,
    wall_s: f64,
    min_s: f64,
    gflops: Option<f64>,
}

impl Case {
    fn json(&self) -> Json {
        obj(vec![
            ("kernel", Json::from(self.kernel.as_str())),
            ("n", Json::from(self.n)),
            ("threads", Json::from(self.threads)),
            ("wall_s", Json::from(self.wall_s)),
            ("min_s", Json::from(self.min_s)),
            (
                "gflops",
                self.gflops.map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }
}

fn measure(
    name: &str,
    n: usize,
    threads: usize,
    flops: Option<f64>,
    budget_s: f64,
    mut f: impl FnMut(),
) -> Case {
    let label = format!("{name} n={n} t={threads}");
    let r = bench_fn(&label, 64, budget_s, &mut f);
    println!("{}", r.report());
    Case {
        kernel: name.to_string(),
        n,
        threads,
        wall_s: r.median_s,
        min_s: r.min_s,
        gflops: flops.map(|fl| fl / r.min_s / 1e9),
    }
}

/// Run the sweep, write `out_path`, and return the JSON document.
/// Applies the perf gates (unless smoke/lenient) before returning.
pub fn run(cfg: &LinalgBenchConfig, out_path: &str) -> Json {
    let mut rng = Pcg64::seed(0x11a1_6);
    let mut cases: Vec<Case> = Vec::new();
    let d = 8usize; // gram input dimensionality
    println!("telemetry: {} (PGPR_TELEMETRY)",
             if crate::obsv::enabled() { "on" } else { "off" });

    for &n in &cfg.sizes {
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let b = Mat::from_vec(n, n, rng.normals(n * n));
        let mut spd = gemm(&LinalgCtx::serial(), &a, &b);
        spd.symmetrize();
        spd.add_diag(n as f64 + 1.0);
        let l = cholesky_blocked(&LinalgCtx::serial(), &spd).unwrap();
        let w = 256.min(n);
        let rhs = Mat::from_vec(n, w, rng.normals(n * w));
        let x1 = Mat::from_vec(n, d, rng.normals(n * d));
        let x2 = Mat::from_vec(n, d, rng.normals(n * d));
        let hyp = SeArd::isotropic(d, 1.3, 1.0, 0.1);

        let gemm_flops = 2.0 * (n as f64).powi(3);
        let chol_flops = (n as f64).powi(3) / 3.0;
        let solve_flops = (n as f64) * (n as f64) * w as f64;
        let gram_flops = 2.0 * (n as f64) * (n as f64) * d as f64;

        // Seed scalar baselines (single-thread by construction).
        cases.push(measure("gemm_scalar", n, 1, Some(gemm_flops),
                           cfg.budget_s, || {
            let _ = matmul_scalar(&a, &b);
        }));
        cases.push(measure("cholesky_scalar", n, 1, Some(chol_flops),
                           cfg.budget_s, || {
            let _ = cholesky_scalar(&spd).unwrap();
        }));
        cases.push(measure("solve_lower_scalar", n, 1, Some(solve_flops),
                           cfg.budget_s, || {
            let _ = solve_lower_mat_scalar(&l, &rhs);
        }));

        // Blocked engine across thread counts.
        for &t in &cfg.threads {
            let ctx = if t <= 1 {
                LinalgCtx::serial()
            } else {
                LinalgCtx::pooled(Arc::new(ThreadPool::new(t)))
            };
            cases.push(measure("gemm", n, t, Some(gemm_flops),
                               cfg.budget_s, || {
                let _ = gemm(&ctx, &a, &b);
            }));
            cases.push(measure("cholesky", n, t, Some(chol_flops),
                               cfg.budget_s, || {
                let _ = cholesky_blocked(&ctx, &spd).unwrap();
            }));
            cases.push(measure("solve_lower", n, t, Some(solve_flops),
                               cfg.budget_s, || {
                let _ = solve_lower_mat_ctx(&ctx, &l, &rhs);
            }));
            cases.push(measure("se_gram", n, t, Some(gram_flops),
                               cfg.budget_s, || {
                let _ = hyp.gram_ctx(&ctx, &x1, &x2);
            }));
        }

        // The dispatch ladder, rung by rung: forced-tier single-thread
        // GEMM and Cholesky at the largest size only (the tier ratio is
        // size-stable; smaller sizes would just dilute the budget).
        if n == *cfg.sizes.iter().max().unwrap() {
            let serial = LinalgCtx::serial();
            for tier in SimdTier::available() {
                let _forced = force_tier(tier);
                cases.push(measure(&format!("gemm_{}", tier.name()), n, 1,
                                   Some(gemm_flops), cfg.budget_s, || {
                    let _ = gemm(&serial, &a, &b);
                }));
                cases.push(measure(&format!("cholesky_{}", tier.name()),
                                   n, 1, Some(chol_flops), cfg.budget_s,
                                   || {
                    let _ = cholesky_blocked(&serial, &spd).unwrap();
                }));
            }
        }
    }

    let doc = build_doc(cfg, &cases);
    std::fs::write(out_path, doc.to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
    apply_gates(cfg, &doc, &cases);
    doc
}

/// Fastest sample (`min_s`) of a case — the statistic every derived
/// ratio and perf gate uses (noise-robust on shared hosts).
fn min_of(cases: &[Case], kernel: &str, n: usize, threads: usize)
    -> Option<f64>
{
    cases
        .iter()
        .find(|c| c.kernel == kernel && c.n == n && c.threads == threads)
        .map(|c| c.min_s)
}

fn build_doc(cfg: &LinalgBenchConfig, cases: &[Case]) -> Json {
    let nmax = *cfg.sizes.iter().max().unwrap();
    let tmax = *cfg.threads.iter().max().unwrap();
    // ratio of min_s samples, Null when either case is missing
    let ratio = |num: (&str, usize), den: (&str, usize)| match (
        min_of(cases, num.0, nmax, num.1),
        min_of(cases, den.0, nmax, den.1),
    ) {
        (Some(a), Some(b)) if b > 0.0 => Json::from(a / b),
        _ => Json::Null,
    };
    let host_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(0);
    let gemm_active = format!("gemm_{}", active_tier().name());
    let chol_active = format!("cholesky_{}", active_tier().name());
    // Same document shape as the checked-in BENCH_linalg.json (whose
    // provenance records the C-mirror measurement instead).
    obj(vec![
        ("schema", Json::from("pgpr-linalg-bench/1")),
        (
            "provenance",
            obj(vec![
                ("harness", Json::from("cargo-bench")),
                (
                    "note",
                    Json::from(
                        "cargo bench --bench linalg_bench; min_s/wall_s                          are the min/median sample of one run",
                    ),
                ),
                ("runs_merged", Json::from(1usize)),
            ]),
        ),
        (
            "config",
            obj(vec![
                ("sizes", Json::from(cfg.sizes.clone())),
                ("threads", Json::from(cfg.threads.clone())),
                ("budget_s", Json::from(cfg.budget_s)),
                ("smoke", Json::Bool(cfg.smoke)),
                ("telemetry_enabled", Json::Bool(crate::obsv::enabled())),
            ]),
        ),
        (
            "host",
            obj(vec![
                ("available_parallelism", Json::from(host_threads)),
                ("cpu", Json::from("unknown")),
            ]),
        ),
        (
            "derived",
            obj(vec![
                ("gemm_largest_n", Json::from(nmax)),
                (
                    "gemm_speedup_vs_scalar_1t",
                    ratio(("gemm_scalar", 1), ("gemm", 1)),
                ),
                (
                    "gemm_scaling_1t_to_max_threads",
                    ratio(("gemm", 1), ("gemm", tmax)),
                ),
                (
                    "gemm_scaling_1t_to_4t",
                    if cfg.threads.contains(&4) {
                        ratio(("gemm", 1), ("gemm", 4))
                    } else {
                        Json::Null
                    },
                ),
                (
                    "gemm_scaling_1t_to_2t",
                    if cfg.threads.contains(&2) {
                        ratio(("gemm", 1), ("gemm", 2))
                    } else {
                        Json::Null
                    },
                ),
                (
                    "cholesky_speedup_vs_scalar_1t",
                    ratio(("cholesky_scalar", 1), ("cholesky", 1)),
                ),
                (
                    "solve_lower_speedup_vs_scalar_1t",
                    ratio(("solve_lower_scalar", 1), ("solve_lower", 1)),
                ),
                ("simd_tier", Json::from(active_tier().name())),
                (
                    "simd_tiers_measured",
                    Json::Arr(
                        SimdTier::available()
                            .into_iter()
                            .map(|t| Json::from(t.name()))
                            .collect(),
                    ),
                ),
                (
                    "gemm_vectorized_speedup_vs_portable",
                    ratio(("gemm_portable", 1), (gemm_active.as_str(), 1)),
                ),
                (
                    "cholesky_vectorized_speedup_vs_portable",
                    ratio(("cholesky_portable", 1),
                          (chol_active.as_str(), 1)),
                ),
            ]),
        ),
        (
            "results",
            Json::Arr(cases.iter().map(Case::json).collect()),
        ),
    ])
}

/// Enforce the §Perf acceptance gates. Full mode, largest size: ≥2×
/// single-thread GEMM speedup over the seed kernel, >1× scaling to the
/// max thread count, ≥1.5× scaling from 1 to 2 threads, and — when
/// AVX-512 is the active tier — the vectorized GEMM ≥1.5× the portable
/// microkernel (compilers auto-vectorize the portable rung at
/// `target-cpu=native`, so 1.5× is the defensible explicit-SIMD margin;
/// the measured value is 1.73×). Every mode (smoke included): pooled execution must not
/// lose more than 10% to serial on any (kernel, size) pair — the
/// small-problem serial-fallback cutoffs exist precisely to make this
/// hold. All checks go advisory under `PGPR_LENIENT_PERF=1` (smoke
/// runs are always lenient).
fn apply_gates(cfg: &LinalgBenchConfig, doc: &Json, cases: &[Case]) {
    // Pooled-regression check, all modes: min_s is the noise-robust
    // statistic, so a >10% pooled loss is a real dispatch-overhead
    // regression, not jitter.
    let mut pooled_ok = true;
    for c in cases.iter().filter(|c| c.threads > 1) {
        if let Some(serial) = min_of(cases, &c.kernel, c.n, 1) {
            if c.min_s > 1.10 * serial {
                pooled_ok = false;
                println!(
                    "pooled regression: {} n={} t={} is {:.1}% slower \
                     than serial",
                    c.kernel, c.n, c.threads,
                    (c.min_s / serial - 1.0) * 100.0
                );
            }
        }
    }
    if !pooled_ok {
        if cfg.lenient || cfg.smoke {
            println!("PGPR_LENIENT_PERF: pooled check advisory, continuing");
        } else {
            panic!(
                "linalg_bench: pooled execution lost >10% to serial; \
                 set PGPR_LENIENT_PERF=1 on oversubscribed hosts"
            );
        }
    }
    if cfg.smoke {
        println!("smoke mode: perf gates skipped");
        return;
    }
    let derived = doc.get("derived").expect("derived");
    let num = |key: &str| {
        derived.get(key).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let speedup = num("gemm_speedup_vs_scalar_1t");
    let scaling = num("gemm_scaling_1t_to_max_threads");
    let scaling2 = num("gemm_scaling_1t_to_2t");
    let vec_speedup = num("gemm_vectorized_speedup_vs_portable");
    let want_vec = active_tier() == SimdTier::Avx512;
    let ok = speedup >= 2.0
        && scaling > 1.0
        && scaling2 >= 1.5
        && (!want_vec || vec_speedup >= 1.5);
    println!(
        "perf gates: gemm 1t speedup {speedup:.2}x (want >= 2), \
         scaling {scaling:.2}x (want > 1), 2t scaling {scaling2:.2}x \
         (want >= 1.5), vectorized vs portable {vec_speedup:.2}x \
         (want >= 1.5 on avx512; active tier {})",
        active_tier().name()
    );
    if !ok && !cfg.lenient {
        panic!(
            "linalg_bench perf gates failed (speedup {speedup:.2}x, \
             scaling {scaling:.2}x, 2t {scaling2:.2}x, vectorized \
             {vec_speedup:.2}x); set PGPR_LENIENT_PERF=1 on \
             oversubscribed hosts"
        );
    }
    if !ok {
        println!("PGPR_LENIENT_PERF: gates advisory, continuing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A micro smoke run end-to-end: produces valid JSON with the
    /// expected schema/derived fields and parses back.
    #[test]
    fn smoke_sweep_writes_valid_json() {
        let cfg = LinalgBenchConfig {
            sizes: vec![16, 32],
            threads: vec![1, 2],
            budget_s: 0.005,
            smoke: true,
            lenient: true,
        };
        let path = std::env::temp_dir().join("pgpr_linalg_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let doc = run(&cfg, &path);
        let raw = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&raw).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(),
                   "pgpr-linalg-bench/1");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        // 3 scalar baselines + 4 blocked kernels × 2 thread counts, × 2
        // sizes, + 2 forced-tier kernels per supported tier at nmax
        assert_eq!(results.len(),
                   (3 + 4 * 2) * 2 + 2 * SimdTier::available().len());
        let derived = doc.get("derived").unwrap();
        assert!(derived.get("gemm_speedup_vs_scalar_1t").is_some());
        assert_eq!(derived.get("simd_tier").unwrap().as_str().unwrap(),
                   active_tier().name());
        assert!(derived.get("gemm_vectorized_speedup_vs_portable")
            .is_some());
        let _ = std::fs::remove_file(&path);
    }
}
