//! The `linalg_bench` sweep: blocked-engine kernels × sizes × thread
//! counts, against the seed scalar baselines, written to
//! `BENCH_linalg.json` — the repo's perf trajectory seed (§Perf in the
//! README; CI runs the smoke mode and uploads the JSON as an artifact).
//!
//! Modes (env):
//! * `PGPR_LINALG_SMOKE=1` — small sizes/thread counts and a tiny time
//!   budget, for CI smoke runs; perf gates are skipped.
//! * `PGPR_LENIENT_PERF=1` — keep the perf gates advisory (print but
//!   don't fail) on oversubscribed/shared hosts, matching the PR-1
//!   convention in `tests/integration_parallel_exec.rs`.
//!
//! Gates (full mode, largest size): blocked GEMM ≥2× the seed scalar
//! kernel single-thread, and >1× scaling from 1 to ≥4 threads.

use std::sync::Arc;

use crate::bench_support::harness::bench_fn;
use crate::kernel::SeArd;
use crate::linalg::{cholesky_blocked, cholesky_scalar, gemm,
                    solve_lower_mat_ctx, LinalgCtx, Mat};
use crate::linalg::cholesky::solve_lower_mat_scalar;
use crate::linalg::matmul_scalar;
use crate::util::json::{obj, Json};
use crate::util::pool::ThreadPool;
use crate::util::Pcg64;

/// Sweep configuration.
pub struct LinalgBenchConfig {
    pub sizes: Vec<usize>,
    pub threads: Vec<usize>,
    /// Per-case measurement budget in seconds.
    pub budget_s: f64,
    pub smoke: bool,
    pub lenient: bool,
}

impl LinalgBenchConfig {
    /// Full sweep unless `PGPR_LINALG_SMOKE=1`; gates advisory when
    /// `PGPR_LENIENT_PERF=1` (both matching the repo's env conventions).
    pub fn from_env() -> LinalgBenchConfig {
        let flag = crate::bench_support::env_flag;
        let smoke = flag("PGPR_LINALG_SMOKE");
        if smoke {
            LinalgBenchConfig {
                sizes: vec![128, 256],
                threads: vec![1, 2],
                budget_s: 0.15,
                smoke: true,
                lenient: true,
            }
        } else {
            LinalgBenchConfig {
                sizes: vec![128, 256, 512, 1024],
                threads: vec![1, 2, 4, 8],
                budget_s: 1.2,
                smoke: false,
                lenient: flag("PGPR_LENIENT_PERF"),
            }
        }
    }
}

/// One measured case. `wall_s` is the median sample; `min_s` is the
/// fastest sample — the noise-robust statistic the derived ratios use
/// (shared hosts can slow arbitrary samples, never speed them up).
struct Case {
    kernel: String,
    n: usize,
    threads: usize,
    wall_s: f64,
    min_s: f64,
    gflops: Option<f64>,
}

impl Case {
    fn json(&self) -> Json {
        obj(vec![
            ("kernel", Json::from(self.kernel.as_str())),
            ("n", Json::from(self.n)),
            ("threads", Json::from(self.threads)),
            ("wall_s", Json::from(self.wall_s)),
            ("min_s", Json::from(self.min_s)),
            (
                "gflops",
                self.gflops.map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }
}

fn measure(
    name: &str,
    n: usize,
    threads: usize,
    flops: Option<f64>,
    budget_s: f64,
    mut f: impl FnMut(),
) -> Case {
    let label = format!("{name} n={n} t={threads}");
    let r = bench_fn(&label, 64, budget_s, &mut f);
    println!("{}", r.report());
    Case {
        kernel: name.to_string(),
        n,
        threads,
        wall_s: r.median_s,
        min_s: r.min_s,
        gflops: flops.map(|fl| fl / r.min_s / 1e9),
    }
}

/// Run the sweep, write `out_path`, and return the JSON document.
/// Applies the perf gates (unless smoke/lenient) before returning.
pub fn run(cfg: &LinalgBenchConfig, out_path: &str) -> Json {
    let mut rng = Pcg64::seed(0x11a1_6);
    let mut cases: Vec<Case> = Vec::new();
    let d = 8usize; // gram input dimensionality

    for &n in &cfg.sizes {
        let a = Mat::from_vec(n, n, rng.normals(n * n));
        let b = Mat::from_vec(n, n, rng.normals(n * n));
        let mut spd = gemm(&LinalgCtx::serial(), &a, &b);
        spd.symmetrize();
        spd.add_diag(n as f64 + 1.0);
        let l = cholesky_blocked(&LinalgCtx::serial(), &spd).unwrap();
        let w = 256.min(n);
        let rhs = Mat::from_vec(n, w, rng.normals(n * w));
        let x1 = Mat::from_vec(n, d, rng.normals(n * d));
        let x2 = Mat::from_vec(n, d, rng.normals(n * d));
        let hyp = SeArd::isotropic(d, 1.3, 1.0, 0.1);

        let gemm_flops = 2.0 * (n as f64).powi(3);
        let chol_flops = (n as f64).powi(3) / 3.0;
        let solve_flops = (n as f64) * (n as f64) * w as f64;
        let gram_flops = 2.0 * (n as f64) * (n as f64) * d as f64;

        // Seed scalar baselines (single-thread by construction).
        cases.push(measure("gemm_scalar", n, 1, Some(gemm_flops),
                           cfg.budget_s, || {
            let _ = matmul_scalar(&a, &b);
        }));
        cases.push(measure("cholesky_scalar", n, 1, Some(chol_flops),
                           cfg.budget_s, || {
            let _ = cholesky_scalar(&spd).unwrap();
        }));
        cases.push(measure("solve_lower_scalar", n, 1, Some(solve_flops),
                           cfg.budget_s, || {
            let _ = solve_lower_mat_scalar(&l, &rhs);
        }));

        // Blocked engine across thread counts.
        for &t in &cfg.threads {
            let ctx = if t <= 1 {
                LinalgCtx::serial()
            } else {
                LinalgCtx::pooled(Arc::new(ThreadPool::new(t)))
            };
            cases.push(measure("gemm", n, t, Some(gemm_flops),
                               cfg.budget_s, || {
                let _ = gemm(&ctx, &a, &b);
            }));
            cases.push(measure("cholesky", n, t, Some(chol_flops),
                               cfg.budget_s, || {
                let _ = cholesky_blocked(&ctx, &spd).unwrap();
            }));
            cases.push(measure("solve_lower", n, t, Some(solve_flops),
                               cfg.budget_s, || {
                let _ = solve_lower_mat_ctx(&ctx, &l, &rhs);
            }));
            cases.push(measure("se_gram", n, t, Some(gram_flops),
                               cfg.budget_s, || {
                let _ = hyp.gram_ctx(&ctx, &x1, &x2);
            }));
        }
    }

    let doc = build_doc(cfg, &cases);
    std::fs::write(out_path, doc.to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
    apply_gates(cfg, &doc);
    doc
}

/// Fastest sample (`min_s`) of a case — the statistic every derived
/// ratio and perf gate uses (noise-robust on shared hosts).
fn min_of(cases: &[Case], kernel: &str, n: usize, threads: usize)
    -> Option<f64>
{
    cases
        .iter()
        .find(|c| c.kernel == kernel && c.n == n && c.threads == threads)
        .map(|c| c.min_s)
}

fn build_doc(cfg: &LinalgBenchConfig, cases: &[Case]) -> Json {
    let nmax = *cfg.sizes.iter().max().unwrap();
    let tmax = *cfg.threads.iter().max().unwrap();
    // ratio of min_s samples, Null when either case is missing
    let ratio = |num: (&str, usize), den: (&str, usize)| match (
        min_of(cases, num.0, nmax, num.1),
        min_of(cases, den.0, nmax, den.1),
    ) {
        (Some(a), Some(b)) if b > 0.0 => Json::from(a / b),
        _ => Json::Null,
    };
    let host_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(0);
    // Same document shape as the checked-in BENCH_linalg.json (whose
    // provenance records the C-mirror measurement instead).
    obj(vec![
        ("schema", Json::from("pgpr-linalg-bench/1")),
        (
            "provenance",
            obj(vec![
                ("harness", Json::from("cargo-bench")),
                (
                    "note",
                    Json::from(
                        "cargo bench --bench linalg_bench; min_s/wall_s                          are the min/median sample of one run",
                    ),
                ),
                ("runs_merged", Json::from(1usize)),
            ]),
        ),
        (
            "config",
            obj(vec![
                ("sizes", Json::from(cfg.sizes.clone())),
                ("threads", Json::from(cfg.threads.clone())),
                ("budget_s", Json::from(cfg.budget_s)),
                ("smoke", Json::Bool(cfg.smoke)),
            ]),
        ),
        (
            "host",
            obj(vec![
                ("available_parallelism", Json::from(host_threads)),
                ("cpu", Json::from("unknown")),
            ]),
        ),
        (
            "derived",
            obj(vec![
                ("gemm_largest_n", Json::from(nmax)),
                (
                    "gemm_speedup_vs_scalar_1t",
                    ratio(("gemm_scalar", 1), ("gemm", 1)),
                ),
                (
                    "gemm_scaling_1t_to_max_threads",
                    ratio(("gemm", 1), ("gemm", tmax)),
                ),
                (
                    "gemm_scaling_1t_to_4t",
                    if cfg.threads.contains(&4) {
                        ratio(("gemm", 1), ("gemm", 4))
                    } else {
                        Json::Null
                    },
                ),
                (
                    "gemm_scaling_1t_to_2t",
                    if cfg.threads.contains(&2) {
                        ratio(("gemm", 1), ("gemm", 2))
                    } else {
                        Json::Null
                    },
                ),
                (
                    "cholesky_speedup_vs_scalar_1t",
                    ratio(("cholesky_scalar", 1), ("cholesky", 1)),
                ),
                (
                    "solve_lower_speedup_vs_scalar_1t",
                    ratio(("solve_lower_scalar", 1), ("solve_lower", 1)),
                ),
            ]),
        ),
        (
            "results",
            Json::Arr(cases.iter().map(Case::json).collect()),
        ),
    ])
}

/// Enforce the §Perf acceptance gates on a full run: ≥2× single-thread
/// GEMM speedup over the seed kernel at the largest size, and >1×
/// multi-thread scaling. Advisory in smoke/lenient modes.
fn apply_gates(cfg: &LinalgBenchConfig, doc: &Json) {
    if cfg.smoke {
        println!("smoke mode: perf gates skipped");
        return;
    }
    let derived = doc.get("derived").expect("derived");
    let speedup = derived
        .get("gemm_speedup_vs_scalar_1t")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let scaling = derived
        .get("gemm_scaling_1t_to_max_threads")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let ok = speedup >= 2.0 && scaling > 1.0;
    println!(
        "perf gates: gemm 1t speedup {speedup:.2}x (want >= 2), \
         scaling {scaling:.2}x (want > 1)"
    );
    if !ok && !cfg.lenient {
        panic!(
            "linalg_bench perf gates failed (speedup {speedup:.2}x, \
             scaling {scaling:.2}x); set PGPR_LENIENT_PERF=1 on \
             oversubscribed hosts"
        );
    }
    if !ok {
        println!("PGPR_LENIENT_PERF: gates advisory, continuing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A micro smoke run end-to-end: produces valid JSON with the
    /// expected schema/derived fields and parses back.
    #[test]
    fn smoke_sweep_writes_valid_json() {
        let cfg = LinalgBenchConfig {
            sizes: vec![16, 32],
            threads: vec![1, 2],
            budget_s: 0.005,
            smoke: true,
            lenient: true,
        };
        let path = std::env::temp_dir().join("pgpr_linalg_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let doc = run(&cfg, &path);
        let raw = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&raw).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(),
                   "pgpr-linalg-bench/1");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        // 3 scalar baselines + 4 blocked kernels × 2 thread counts, × 2 sizes
        assert_eq!(results.len(), (3 + 4 * 2) * 2);
        assert!(doc.get("derived").unwrap()
            .get("gemm_speedup_vs_scalar_1t").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
