//! The `serve_bench` sweep: per-batch predict latency of the serving
//! layer, old (seed solve-based, backend-driven) path vs the fast
//! (fit-staged predictive operator) path, across batch sizes × thread
//! counts × support-set sizes, written to `BENCH_serve.json` — the
//! serving layer's perf trajectory, matching the `BENCH_linalg.json` /
//! `BENCH_train.json` conventions.
//!
//! Modes (env):
//! * `PGPR_SERVE_SMOKE=1` — tiny model and a tiny time budget for CI
//!   smoke runs; perf gates are skipped.
//! * `PGPR_LENIENT_PERF=1` — keep the perf gate advisory (print but
//!   don't fail) on oversubscribed/shared hosts.
//!
//! Gate (full mode): fast-path per-batch latency ≥ 3× faster than the
//! old path at the largest |S|, largest batch, 1 thread (`min_s`
//! ratio — shared hosts can slow samples, never speed them up).
//!
//! The mixed-precision serve mode (`path: "f32"` cases) is measured
//! alongside the f64 fast path, and its observed worst-case relative
//! error against the f64 path is re-measured per run, reported under
//! `derived`, and **hard-asserted** (every mode, perf-lenient or not —
//! accuracy is not a perf gate) against
//! [`crate::gp::predictor::F32_SERVE_REL_BUDGET`].

use std::sync::Arc;

use crate::data::partition::random_partition;
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};
use crate::runtime::NativeBackend;
use crate::server::{ServeScratch, ServedModel};
use crate::util::json::{obj, Json};
use crate::util::pool::ThreadPool;
use crate::util::time::DurationStats;
use crate::util::{Pcg64, Stopwatch};

/// Sweep configuration.
pub struct ServeBenchConfig {
    /// Support-set sizes |S| to fit models at.
    pub support_sizes: Vec<usize>,
    /// Per-request batch sizes (the AOT pred_block analogue).
    pub batch_sizes: Vec<usize>,
    /// Thread counts for the fast path's linalg ctx (the old path is
    /// internally serial and is measured once per case at t=1).
    pub threads: Vec<usize>,
    /// Simulated machines M and per-machine training block |D|/M.
    pub machines: usize,
    pub block: usize,
    pub d: usize,
    /// Per-case measurement budget in seconds.
    pub budget_s: f64,
    pub smoke: bool,
    pub lenient: bool,
}

impl ServeBenchConfig {
    /// Full sweep unless `PGPR_SERVE_SMOKE=1`; gate advisory when
    /// `PGPR_LENIENT_PERF=1` (the repo's shared env conventions).
    pub fn from_env() -> ServeBenchConfig {
        let flag = crate::bench_support::env_flag;
        let smoke = flag("PGPR_SERVE_SMOKE");
        if smoke {
            ServeBenchConfig {
                support_sizes: vec![16, 32],
                batch_sizes: vec![1, 8],
                threads: vec![1, 2],
                machines: 4,
                block: 32,
                d: 4,
                budget_s: 0.05,
                smoke: true,
                lenient: true,
            }
        } else {
            ServeBenchConfig {
                support_sizes: vec![256, 512],
                batch_sizes: vec![1, 64, 256],
                threads: vec![1, 2, 4],
                machines: 8,
                block: 256,
                d: 8,
                budget_s: 0.6,
                smoke: false,
                lenient: flag("PGPR_LENIENT_PERF"),
            }
        }
    }
}

/// One measured case: per-batch latency distribution + derived qps.
struct Case {
    path: &'static str,
    s: usize,
    batch: usize,
    threads: usize,
    p50_s: f64,
    p99_s: f64,
    min_s: f64,
    /// rows served per second at the median latency
    qps: f64,
}

impl Case {
    fn json(&self) -> Json {
        obj(vec![
            ("path", Json::from(self.path)),
            ("s", Json::from(self.s)),
            ("batch", Json::from(self.batch)),
            ("threads", Json::from(self.threads)),
            ("p50_s", Json::from(self.p50_s)),
            ("p99_s", Json::from(self.p99_s)),
            ("min_s", Json::from(self.min_s)),
            ("qps", Json::from(self.qps)),
        ])
    }
}

/// Sample a closure's per-call latency: 1 warmup, then up to 256 calls
/// or `budget_s` of measurement, minimum 3 samples.
fn sample_latency(budget_s: f64, mut f: impl FnMut()) -> Vec<f64> {
    f(); // warmup
    let mut samples = Vec::new();
    let total = Stopwatch::new();
    while samples.len() < 256
        && (samples.len() < 3 || total.elapsed() < budget_s)
    {
        let sw = Stopwatch::new();
        f();
        samples.push(sw.elapsed());
    }
    samples
}

fn case_from(path: &'static str, s: usize, batch: usize, threads: usize,
             samples: &[f64]) -> Case {
    let stats = DurationStats::from_samples(samples).expect("samples");
    let min_s = stats.min;
    println!(
        "{path:<7} s={s:<4} b={batch:<4} t={threads}  p50 {:>10.3e}s  \
         p99 {:>10.3e}s  min {:>10.3e}s  {:.0} qps",
        stats.p50, stats.p99, min_s, batch as f64 / stats.p50
    );
    Case {
        path,
        s,
        batch,
        threads,
        p50_s: stats.p50,
        p99_s: stats.p99,
        min_s,
        qps: batch as f64 / stats.p50,
    }
}

/// Run the sweep, write `out_path`, and return the JSON document.
/// Applies the ≥3× fast-vs-old gate (unless smoke/lenient).
pub fn run(cfg: &ServeBenchConfig, out_path: &str) -> Json {
    let mut rng = Pcg64::seed(0x5E54E);
    let mut cases: Vec<Case> = Vec::new();
    let d = cfg.d;
    let n = cfg.machines * cfg.block;
    // observed worst-case f32-vs-f64 relative error across the sweep
    let (mut f32_err_mean, mut f32_err_var) = (0.0f64, 0.0f64);

    for &s in &cfg.support_sizes {
        // one served model per |S|: M machines, |D|/M-point blocks
        let hyp = SeArd::isotropic(d, 2.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let blocks = random_partition(n, cfg.machines, &mut rng);
        let fit_sw = Stopwatch::new();
        // one fit serves all three paths: predict_batch (oracle),
        // predict_batch_fast (f64 operators) and predict_batch_fast_f32
        // (the staged mixed-precision operators)
        let model = ServedModel::fit(&hyp, &xd, &y, &xs, &blocks,
                                     &NativeBackend)
            .expect("serve bench fit")
            .with_mixed_precision();
        println!("fitted |S|={s} n={n} M={} in {:.2}s", cfg.machines,
                 fit_sw.elapsed());
        let c0 = hyp.prior_var();

        for &b in &cfg.batch_sizes {
            let q: Vec<f64> = rng.normals(b * d);
            // old path: per-batch Definition-5 through the backend
            // (re-factorizes the support/global Cholesky per call) —
            // internally serial, measured once at t=1.
            let samples = sample_latency(cfg.budget_s, || {
                let _ =
                    model.predict_batch(&NativeBackend, 0, &q, b, b);
            });
            cases.push(case_from("oracle", s, b, 1, &samples));

            // fast paths (f64 and f32 storage) across thread counts
            for &t in &cfg.threads {
                let lctx = if t <= 1 {
                    LinalgCtx::serial()
                } else {
                    LinalgCtx::pooled(Arc::new(ThreadPool::new(t)))
                };
                let mut scratch = ServeScratch::new();
                let samples = sample_latency(cfg.budget_s, || {
                    let _ = model.predict_batch_fast(0, &q, b, b, &lctx,
                                                     &mut scratch);
                });
                cases.push(case_from("fast", s, b, t, &samples));
                let samples = sample_latency(cfg.budget_s, || {
                    let _ = model.predict_batch_fast_f32(
                        0, &q, b, b, &lctx, &mut scratch);
                });
                cases.push(case_from("f32", s, b, t, &samples));
            }

            // mixed-precision accuracy, re-measured on this run's data
            let lctx = LinalgCtx::serial();
            let mut s64 = ServeScratch::new();
            let (mean_o, var_o) = {
                let (m, v) =
                    model.predict_batch_fast(0, &q, b, b, &lctx, &mut s64);
                (m.to_vec(), v.to_vec())
            };
            let mut s32 = ServeScratch::new();
            let (mean_f, var_f) = model.predict_batch_fast_f32(
                0, &q, b, b, &lctx, &mut s32);
            for i in 0..b {
                let em = (mean_f[i] - mean_o[i]).abs()
                    / mean_o[i].abs().max(1.0);
                let ev =
                    (var_f[i] - var_o[i]).abs() / var_o[i].abs().max(c0);
                f32_err_mean = f32_err_mean.max(em);
                f32_err_var = f32_err_var.max(ev);
            }
        }
    }

    // Accuracy is not a perf gate: the budget holds in every mode.
    let budget = crate::gp::predictor::F32_SERVE_REL_BUDGET;
    println!(
        "f32 serve accuracy: max rel err mean {f32_err_mean:.3e}, \
         var {f32_err_var:.3e} (budget {budget:.1e})"
    );
    assert!(
        f32_err_mean <= budget && f32_err_var <= budget,
        "mixed-precision serve exceeded its error budget: \
         mean {f32_err_mean:.3e}, var {f32_err_var:.3e} > {budget:.1e}"
    );

    let doc = build_doc(cfg, &cases, f32_err_mean, f32_err_var);
    std::fs::write(out_path, doc.to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
    apply_gates(cfg, &doc);
    doc
}

fn min_of(cases: &[Case], path: &str, s: usize, batch: usize,
          threads: usize) -> Option<f64> {
    cases
        .iter()
        .find(|c| {
            c.path == path && c.s == s && c.batch == batch
                && c.threads == threads
        })
        .map(|c| c.min_s)
}

fn build_doc(cfg: &ServeBenchConfig, cases: &[Case], f32_err_mean: f64,
             f32_err_var: f64) -> Json {
    let smax = *cfg.support_sizes.iter().max().unwrap();
    let bmax = *cfg.batch_sizes.iter().max().unwrap();
    let tmax = *cfg.threads.iter().max().unwrap();
    let ratio = |num: Option<f64>, den: Option<f64>| match (num, den) {
        (Some(a), Some(b)) if b > 0.0 => Json::from(a / b),
        _ => Json::Null,
    };
    let host_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(0);
    obj(vec![
        ("schema", Json::from("pgpr-serve-bench/1")),
        (
            "provenance",
            obj(vec![
                ("harness", Json::from("cargo-bench")),
                (
                    "note",
                    Json::from(
                        "cargo bench --bench serve_bench; latencies are \
                         per predict_batch call on one machine's block",
                    ),
                ),
            ]),
        ),
        (
            "config",
            obj(vec![
                ("support_sizes", Json::from(cfg.support_sizes.clone())),
                ("batch_sizes", Json::from(cfg.batch_sizes.clone())),
                ("threads", Json::from(cfg.threads.clone())),
                ("machines", Json::from(cfg.machines)),
                ("block", Json::from(cfg.block)),
                ("d", Json::from(cfg.d)),
                ("budget_s", Json::from(cfg.budget_s)),
                ("smoke", Json::Bool(cfg.smoke)),
            ]),
        ),
        (
            "host",
            obj(vec![
                ("available_parallelism", Json::from(host_threads)),
                ("cpu", Json::from("unknown")),
            ]),
        ),
        (
            "derived",
            obj(vec![
                ("gate_s", Json::from(smax)),
                ("gate_batch", Json::from(bmax)),
                (
                    // the acceptance gate: old/fast at |S|max, bmax, 1t
                    "fast_speedup_vs_oracle_1t",
                    ratio(min_of(cases, "oracle", smax, bmax, 1),
                          min_of(cases, "fast", smax, bmax, 1)),
                ),
                (
                    "fast_speedup_vs_oracle_b1_1t",
                    ratio(min_of(cases, "oracle", smax, 1, 1),
                          min_of(cases, "fast", smax, 1, 1)),
                ),
                (
                    "fast_scaling_1t_to_max_threads",
                    ratio(min_of(cases, "fast", smax, bmax, 1),
                          min_of(cases, "fast", smax, bmax, tmax)),
                ),
                (
                    // mixed-precision latency win at the gate point
                    "f32_speedup_vs_fast_1t",
                    ratio(min_of(cases, "fast", smax, bmax, 1),
                          min_of(cases, "f32", smax, bmax, 1)),
                ),
                (
                    "f32_rel_budget",
                    Json::from(crate::gp::predictor::F32_SERVE_REL_BUDGET),
                ),
                ("f32_max_rel_err_mean", Json::from(f32_err_mean)),
                ("f32_max_rel_err_var", Json::from(f32_err_var)),
            ]),
        ),
        (
            "results",
            Json::Arr(cases.iter().map(Case::json).collect()),
        ),
    ])
}

/// Enforce the serve acceptance gate on a full run: fast path ≥3× the
/// old path at the largest |S|, largest batch, 1 thread. Advisory in
/// smoke/lenient modes.
fn apply_gates(cfg: &ServeBenchConfig, doc: &Json) {
    if cfg.smoke {
        println!("smoke mode: perf gates skipped");
        return;
    }
    let speedup = doc
        .get("derived")
        .and_then(|d| d.get("fast_speedup_vs_oracle_1t"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let ok = speedup >= 3.0;
    println!("perf gate: fast-path per-batch speedup {speedup:.2}x \
              (want >= 3)");
    if !ok && !cfg.lenient {
        panic!(
            "serve_bench perf gate failed (speedup {speedup:.2}x < 3); \
             set PGPR_LENIENT_PERF=1 on oversubscribed hosts"
        );
    }
    if !ok {
        println!("PGPR_LENIENT_PERF: gate advisory, continuing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A micro smoke run end-to-end: valid JSON with the expected
    /// schema and derived fields, parses back, covers both paths.
    #[test]
    fn smoke_sweep_writes_valid_json() {
        let cfg = ServeBenchConfig {
            support_sizes: vec![6, 8],
            batch_sizes: vec![1, 4],
            threads: vec![1, 2],
            machines: 2,
            block: 8,
            d: 2,
            budget_s: 0.002,
            smoke: true,
            lenient: true,
        };
        let path = std::env::temp_dir().join("pgpr_serve_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let doc = run(&cfg, &path);
        let raw = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&raw).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(),
                   "pgpr-serve-bench/1");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        // per (s, batch): 1 oracle + |threads| × (fast + f32) cases
        assert_eq!(results.len(), 2 * 2 * (1 + 2 + 2));
        let derived = doc.get("derived").unwrap();
        assert!(derived.get("fast_speedup_vs_oracle_1t").is_some());
        assert!(derived.get("f32_speedup_vs_fast_1t").is_some());
        let err = derived.get("f32_max_rel_err_var").unwrap()
            .as_f64().unwrap();
        assert!(err <= crate::gp::predictor::F32_SERVE_REL_BUDGET);
        let _ = std::fs::remove_file(&path);
    }

    /// The BENCH_serve percentile fields (`p50_s`/`p99_s`, via
    /// [`DurationStats`]) ride on the shared telemetry histogram —
    /// the tree's one percentile implementation. Pin: for identical
    /// samples they match the sort-based oracle within the documented
    /// tolerance of one bucket width
    /// ([`crate::obsv::RELATIVE_BUCKET_WIDTH`]), and `min_s` stays
    /// exact.
    #[test]
    fn case_percentiles_match_sort_oracle_within_bucket() {
        let mut rng = Pcg64::seed(77);
        for n in [5usize, 64, 300] {
            let samples: Vec<f64> = (0..n)
                .map(|_| 1e-6 + 1e-4 * rng.normal().abs())
                .collect();
            let stats = DurationStats::from_samples(&samples).unwrap();
            let mut sorted = samples.clone();
            sorted.sort_by(f64::total_cmp);
            let oracle = |q: f64| {
                let k = ((q * n as f64).ceil() as usize).clamp(1, n);
                sorted[k - 1]
            };
            for (got, q) in
                [(stats.p50, 0.50), (stats.p95, 0.95), (stats.p99, 0.99)]
            {
                let want = oracle(q);
                let tol = want.abs() * crate::obsv::RELATIVE_BUCKET_WIDTH
                    + crate::obsv::hist::BUCKET_LO;
                assert!(
                    (got - want).abs() <= tol,
                    "n={n} q={q}: histogram {got:.6e} vs oracle \
                     {want:.6e} (tol {tol:.3e})"
                );
            }
            assert_eq!(stats.min, sorted[0], "min must stay exact");
            assert_eq!(stats.max, sorted[n - 1], "max must stay exact");
        }
    }
}
