//! Figure/table regeneration — one function per evaluation artifact of
//! Section 6 (see DESIGN.md per-experiment index). Each returns [`Table`]s
//! with the same series the paper plots; `cargo bench` targets and the
//! `pgpr sweep` CLI both call through here.
//!
//! Scales: `Small` is the default single-host scale documented in
//! DESIGN.md §Substitutions (≈8× down from the paper); `Paper` uses the
//! paper's sizes (hours of single-core time — available, not default).

use super::experiments::{run_methods, speedup_order, ExperimentConfig, Method};
use super::table::{fmt3, Table};
use super::workloads::{prepare, Domain};
use crate::runtime::NativeBackend;
use std::sync::Arc;

/// Sweep scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// Fig. 1 — varying data size |D|; M=20, P fixed.
/// Columns: |D|, method, RMSE, MNLP, time(s), speedup.
pub fn fig1(domain: Domain, scale: Scale, seed: u64, threads: usize) -> Table {
    let (sizes, m, p): (Vec<usize>, usize, usize) = match scale {
        Scale::Small => (vec![500, 1000, 1500, 2000], 20, 128),
        Scale::Paper => (vec![8000, 16000, 24000, 32000], 20, 2048),
    };
    let rank = rank_for(domain, p);
    let mut t = Table::new(
        &format!("Fig.1 ({}) — vary |D|, M={m}, |S|={p}, R={rank}",
                 domain.name()),
        &["|D|", "method", "RMSE", "MNLP", "time_s", "speedup"],
    );
    for &n in &sizes {
        let u = (n / 10).max(m);
        let w = prepare(domain, n, u, seed, false);
        let cfg = ExperimentConfig {
            machines: m,
            support_size: p,
            rank,
            seed,
            threads,
        };
        let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                                  Arc::new(NativeBackend));
        for r in &results {
            t.row(vec![
                n.to_string(),
                r.method.name().into(),
                fmt3(r.rmse),
                fmt3(r.mnlp),
                fmt3(r.time_s),
                r.speedup.map(fmt3).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t
}

/// Fig. 2 — varying machine count M; |D|, P fixed.
pub fn fig2(domain: Domain, scale: Scale, seed: u64, threads: usize) -> Table {
    let (ms, n, p): (Vec<usize>, usize, usize) = match scale {
        Scale::Small => (vec![4, 8, 12, 16, 20], 2000, 128),
        Scale::Paper => (vec![4, 8, 12, 16, 20], 32000, 2048),
    };
    let rank = rank_for(domain, p);
    let mut t = Table::new(
        &format!("Fig.2 ({}) — vary M, |D|={n}, |S|={p}, R={rank}",
                 domain.name()),
        &["M", "method", "RMSE", "MNLP", "time_s", "speedup"],
    );
    // one workload shared across M values (paper: same data)
    let u = (n / 10).max(*ms.iter().max().unwrap());
    let w = prepare(domain, n, u, seed, false);
    for &m in &ms {
        let cfg = ExperimentConfig {
            machines: m,
            support_size: p,
            rank,
            seed,
            threads,
        };
        let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                                  Arc::new(NativeBackend));
        for r in &results {
            t.row(vec![
                m.to_string(),
                r.method.name().into(),
                fmt3(r.rmse),
                fmt3(r.mnlp),
                fmt3(r.time_s),
                r.speedup.map(fmt3).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t
}

/// Fig. 3 — varying parameter P = |S| = R (AIMPEAK) or |S| = R/2
/// (SARCOS); |D|, M fixed. FGP appears once as the flat reference.
pub fn fig3(domain: Domain, scale: Scale, seed: u64, threads: usize) -> Table {
    let (ps, n, m): (Vec<usize>, usize, usize) = match scale {
        Scale::Small => (vec![16, 32, 64, 128], 2000, 20),
        Scale::Paper => (vec![256, 512, 1024, 2048], 32000, 20),
    };
    let mut t = Table::new(
        &format!("Fig.3 ({}) — vary P, |D|={n}, M={m}", domain.name()),
        &["P", "method", "RMSE", "MNLP", "time_s", "speedup"],
    );
    let u = (n / 10).max(m);
    let w = prepare(domain, n, u, seed, false);
    // FGP reference (P-independent)
    let fgp = run_methods(
        &w,
        &ExperimentConfig { machines: m, support_size: ps[0], rank: ps[0],
                            seed, threads },
        &[Method::Fgp],
        Arc::new(NativeBackend),
    );
    t.row(vec![
        "-".into(),
        "FGP".into(),
        fmt3(fgp[0].rmse),
        fmt3(fgp[0].mnlp),
        fmt3(fgp[0].time_s),
        "-".into(),
    ]);
    for &p in &ps {
        let cfg = ExperimentConfig {
            machines: m,
            support_size: p,
            rank: rank_for(domain, p),
            seed,
            threads,
        };
        let methods = [Method::Pitc, Method::Pic, Method::Icf,
                       Method::PPitc, Method::PPic, Method::PIcf];
        let results = run_methods(&w, &cfg, &methods, Arc::new(NativeBackend));
        for r in &results {
            t.row(vec![
                p.to_string(),
                r.method.name().into(),
                fmt3(r.rmse),
                fmt3(r.mnlp),
                fmt3(r.time_s),
                r.speedup.map(fmt3).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t
}

/// Table 1 — empirical time-scaling exponents vs the analytic terms:
/// time each method at |D| = n and 2n and report log2(t₂/t₁), plus the
/// communication-volume ratio between M and 2M for the parallel methods.
pub fn table1(domain: Domain, seed: u64, threads: usize) -> Table {
    let (n1, m, p) = (600usize, 4usize, 32usize);
    let n2 = 2 * n1;
    let rank = rank_for(domain, p);
    let mut t = Table::new(
        &format!("Table 1 check ({}) — measured scaling in |D| \
                  (M={m}, |S|={p}, R={rank})", domain.name()),
        &["method", "t(n)", "t(2n)", "exp≈", "paper dominant term"],
    );
    let paper_term = |m: Method| -> &'static str {
        match m {
            Method::Fgp => "|D|^3",
            Method::Pitc | Method::Pic => "|D| (|D|/M)^2",
            Method::Icf => "R^2 |D| + R|U||D|",
            Method::PPitc | Method::PPic => "(|D|/M)^3",
            Method::PIcf => "R^2 |D|/M + R|U||D|/M",
            Method::Online => "(|D'|/M)^3 per batch (§5.2)",
        }
    };
    let u1 = n1 / 10;
    let w1 = prepare(domain, n1, u1, seed, false);
    let w2 = prepare(domain, n2, 2 * u1, seed, false);
    let cfg = |_: usize| ExperimentConfig {
        machines: m,
        support_size: p,
        rank,
        seed,
        threads,
    };
    let order = speedup_order(&Method::ALL);
    let r1 = run_methods(&w1, &cfg(n1), &order, Arc::new(NativeBackend));
    let r2 = run_methods(&w2, &cfg(n2), &order, Arc::new(NativeBackend));
    for method in Method::ALL {
        let a = r1.iter().find(|r| r.method == method).unwrap();
        let b = r2.iter().find(|r| r.method == method).unwrap();
        let exp = (b.time_s / a.time_s).log2();
        t.row(vec![
            method.name().into(),
            fmt3(a.time_s),
            fmt3(b.time_s),
            fmt3(exp),
            paper_term(method).into(),
        ]);
    }
    t
}

fn rank_for(domain: Domain, p: usize) -> usize {
    match domain {
        Domain::Aimpeak => p,      // paper: R = |S|
        Domain::Sarcos => 2 * p,   // paper: R = 2|S|
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Miniature fig1-shaped sweep (tiny sizes) exercises the plumbing.
    #[test]
    fn mini_sweep_runs() {
        let w = prepare(Domain::Sarcos, 80, 16, 1, false);
        let cfg = ExperimentConfig {
            machines: 4,
            support_size: 8,
            rank: 12,
            seed: 1,
            threads: 0,
        };
        let results = run_methods(&w, &cfg, &speedup_order(&Method::ALL),
                                  Arc::new(NativeBackend));
        assert_eq!(results.len(), 7);
    }

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("small"), Some(Scale::Small));
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("x"), None);
    }

    #[test]
    fn rank_rule_matches_paper() {
        assert_eq!(rank_for(Domain::Aimpeak, 64), 64);
        assert_eq!(rank_for(Domain::Sarcos, 64), 128);
    }
}
