//! The `train_bench` sweep: distributed PITC training wall-clock vs
//! host threads, plus the hyperparameter-recovery gate, written to
//! `BENCH_train.json` (CI uploads the smoke run as an artifact next to
//! `BENCH_linalg.json`).
//!
//! Modes (env), matching the `linalg_bench` conventions:
//! * `PGPR_TRAIN_SMOKE=1` — tiny dataset / few threads / few iters for
//!   CI; gates skipped.
//! * `PGPR_LENIENT_PERF=1` — gates advisory on oversubscribed hosts.
//!
//! Gates (full mode): >1× wall-clock scaling of one distributed
//! NLML+gradient evaluation from 1 thread to the max swept thread
//! count, and held-out RMSE after distributed-PITC training within 5%
//! of the exact-subset-MLE baseline (`rmse_ratio <= 1.05`) — the
//! ISSUE-3 acceptance criterion.

use crate::bench_support::harness::bench_fn;
use crate::bench_support::workloads::{pitc_heldout_rmse, rff_recovery};
use crate::gp::likelihood::{learn_hyperparameters, MleConfig};
use crate::parallel::ClusterSpec;
use crate::train::dist::{nlml_and_grad_dist, train_pitc};
use crate::train::optim::AdamConfig;
use crate::util::json::{obj, Json};

/// Sweep configuration.
pub struct TrainBenchConfig {
    pub n: usize,
    pub n_test: usize,
    pub machines: usize,
    pub support: usize,
    pub dim: usize,
    pub threads: Vec<usize>,
    /// Adam iterations for the recovery run.
    pub iters: usize,
    /// Per-timing-case measurement budget in seconds.
    pub budget_s: f64,
    pub smoke: bool,
    pub lenient: bool,
    pub seed: u64,
}

impl TrainBenchConfig {
    /// Full sweep unless `PGPR_TRAIN_SMOKE=1`; gates advisory when
    /// `PGPR_LENIENT_PERF=1`.
    pub fn from_env() -> TrainBenchConfig {
        let flag = crate::bench_support::env_flag;
        if flag("PGPR_TRAIN_SMOKE") {
            TrainBenchConfig {
                n: 256,
                n_test: 64,
                machines: 4,
                support: 24,
                dim: 2,
                threads: vec![1, 2],
                iters: 4,
                budget_s: 0.3,
                smoke: true,
                lenient: true,
                seed: 1,
            }
        } else {
            TrainBenchConfig {
                n: 8192,
                n_test: 1024,
                machines: 8,
                support: 96,
                dim: 4,
                threads: vec![1, 2, 4, 8],
                iters: 25,
                budget_s: 30.0,
                smoke: false,
                lenient: flag("PGPR_LENIENT_PERF"),
                seed: 1,
            }
        }
    }
}

/// Run the sweep, write `out_path`, and return the JSON document.
pub fn run(cfg: &TrainBenchConfig, out_path: &str) -> Json {
    // the canonical recovery problem shared with `pgpr train` and the
    // integration suite (one definition of truth/init/support/partition)
    let r = rff_recovery(cfg.n, cfg.n_test, cfg.dim, cfg.support,
                         cfg.machines, cfg.seed);
    let (train_ds, test_ds, init, xs, d_blocks) =
        (r.train, r.test, r.init, r.xs, r.d_blocks);
    let yc: Vec<f64> = {
        let mean =
            train_ds.y.iter().sum::<f64>() / train_ds.len().max(1) as f64;
        train_ds.y.iter().map(|v| v - mean).collect()
    };

    // --- timing: one distributed NLML+grad evaluation per thread count
    let mut timing = Vec::new();
    let mut bytes_per_eval = 0usize;
    for &t in &cfg.threads {
        let spec = ClusterSpec::with_threads(cfg.machines, t);
        let label = format!("train_eval n={} M={} t={t}", cfg.n, cfg.machines);
        let r = bench_fn(&label, 16, cfg.budget_s, &mut || {
            let ev = nlml_and_grad_dist(&init, &train_ds.x, &yc, &xs,
                                        &d_blocks, &spec);
            bytes_per_eval = ev.metrics.bytes_sent;
        });
        println!("{}", r.report());
        timing.push((t, r.median_s, r.min_s));
    }

    // --- recovery: full training at max threads vs exact-subset MLE
    let tmax = *cfg.threads.iter().max().unwrap();
    let spec = ClusterSpec::with_threads(cfg.machines, tmax);
    let lctx = spec.exec.linalg_ctx();
    let adam = AdamConfig { iters: cfg.iters, backtrack: true,
                            ..Default::default() };
    let trained = train_pitc(&init, &train_ds.x, &train_ds.y, &xs, &d_blocks,
                             &spec, &adam);
    let mle_cfg = MleConfig {
        iters: cfg.iters,
        subset: 256.min(train_ds.len()),
        seed: cfg.seed,
        ..Default::default()
    };
    let mle = learn_hyperparameters(&init, &train_ds.x, &train_ds.y, &mle_cfg);
    let heldout = |hyp: &crate::kernel::SeArd| -> f64 {
        pitc_heldout_rmse(&lctx, hyp, &train_ds, &test_ds, &xs, &d_blocks)
    };
    let rmse_dist = heldout(&trained.hyp);
    let rmse_subset = heldout(&mle.hyp);
    let rmse_init = heldout(&init);
    println!("held-out RMSE: init {rmse_init:.4}, distributed {rmse_dist:.4}, \
              exact-subset {rmse_subset:.4}");

    // --- document
    let min_at = |t: usize| {
        timing.iter().find(|&&(tt, _, _)| tt == t).map(|&(_, _, mn)| mn)
    };
    let scaling = match (min_at(1), min_at(tmax)) {
        (Some(a), Some(b)) if b > 0.0 => Json::from(a / b),
        _ => Json::Null,
    };
    let rmse_ratio = rmse_dist / rmse_subset.max(1e-12);
    let doc = obj(vec![
        ("schema", Json::from("pgpr-train-bench/1")),
        (
            "provenance",
            obj(vec![
                ("harness", Json::from("cargo-bench")),
                (
                    "note",
                    Json::from("cargo bench --bench train_bench; min_s is \
                                the fastest sample of one distributed \
                                NLML+gradient evaluation"),
                ),
            ]),
        ),
        (
            "config",
            obj(vec![
                ("n", Json::from(cfg.n)),
                ("n_test", Json::from(cfg.n_test)),
                ("machines", Json::from(cfg.machines)),
                ("support", Json::from(cfg.support)),
                ("dim", Json::from(cfg.dim)),
                ("threads", Json::from(cfg.threads.clone())),
                ("iters", Json::from(cfg.iters)),
                ("smoke", Json::Bool(cfg.smoke)),
            ]),
        ),
        (
            "comm",
            obj(vec![
                ("bytes_per_eval", Json::from(bytes_per_eval)),
                (
                    "bytes_per_eval_per_machine",
                    Json::from(
                        bytes_per_eval / cfg.machines.saturating_sub(1).max(1),
                    ),
                ),
            ]),
        ),
        (
            "derived",
            obj(vec![
                ("train_eval_scaling_1t_to_max_threads", scaling.clone()),
                ("rmse_init", Json::from(rmse_init)),
                ("rmse_distributed", Json::from(rmse_dist)),
                ("rmse_exact_subset", Json::from(rmse_subset)),
                ("rmse_ratio_vs_subset", Json::from(rmse_ratio)),
                ("nlml_first", Json::from(trained.nlml_trace[0])),
                (
                    "nlml_last",
                    Json::from(*trained.nlml_trace.last().unwrap()),
                ),
                ("train_wall_s", Json::from(trained.wall_s)),
                ("train_makespan_s", Json::from(trained.makespan_s)),
            ]),
        ),
        (
            "results",
            Json::Arr(
                timing
                    .iter()
                    .map(|&(t, median_s, min_s)| {
                        obj(vec![
                            ("kernel", Json::from("train_eval")),
                            ("threads", Json::from(t)),
                            ("wall_s", Json::from(median_s)),
                            ("min_s", Json::from(min_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(out_path, doc.to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
    apply_gates(cfg, &doc);
    doc
}

/// The acceptance gates: >1× thread scaling of a training evaluation
/// and held-out RMSE within 5% of the exact-subset baseline. Advisory
/// in smoke/lenient modes.
fn apply_gates(cfg: &TrainBenchConfig, doc: &Json) {
    if cfg.smoke {
        println!("smoke mode: train perf gates skipped");
        return;
    }
    let derived = doc.get("derived").expect("derived");
    let scaling = derived
        .get("train_eval_scaling_1t_to_max_threads")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let ratio = derived
        .get("rmse_ratio_vs_subset")
        .and_then(Json::as_f64)
        .unwrap_or(f64::INFINITY);
    let ok = scaling > 1.0 && ratio <= 1.05;
    println!(
        "train gates: eval scaling {scaling:.2}x (want > 1), rmse ratio \
         {ratio:.3} (want <= 1.05)"
    );
    if !ok && !cfg.lenient {
        panic!(
            "train_bench gates failed (scaling {scaling:.2}x, rmse ratio \
             {ratio:.3}); set PGPR_LENIENT_PERF=1 on oversubscribed hosts"
        );
    }
    if !ok {
        println!("PGPR_LENIENT_PERF: gates advisory, continuing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Micro end-to-end run: valid JSON with the expected schema and
    /// derived fields, parses back.
    #[test]
    fn smoke_sweep_writes_valid_json() {
        let cfg = TrainBenchConfig {
            n: 48,
            n_test: 16,
            machines: 3,
            support: 8,
            dim: 2,
            threads: vec![1, 2],
            iters: 2,
            budget_s: 0.01,
            smoke: true,
            lenient: true,
            seed: 3,
        };
        let path = std::env::temp_dir().join("pgpr_train_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let doc = run(&cfg, &path);
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(),
                   "pgpr-train-bench/1");
        let derived = doc.get("derived").unwrap();
        assert!(derived.get("rmse_ratio_vs_subset").is_some());
        assert!(derived.get("nlml_last").unwrap().as_f64().is_some());
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
