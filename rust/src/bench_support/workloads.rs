//! Workload preparation: generate the synthetic AIMPEAK / SARCOS
//! datasets at a requested size, split test data (paper: 10% random),
//! and fix hyperparameters (curated defaults learned via MLE, or learn
//! on a subset with `learn = true` as in Section 6).

use crate::data::partition::random_partition;
use crate::data::{aimpeak, rff, sarcos, Dataset};
use crate::gp::likelihood::{learn_hyperparameters, MleConfig};
use crate::gp::pitc::PitcGp;
use crate::gp::support::support_matrix;
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};
use crate::metrics::rmse;
use crate::util::Pcg64;

/// Evaluation domains of Section 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Aimpeak,
    Sarcos,
}

impl Domain {
    pub fn name(self) -> &'static str {
        match self {
            Domain::Aimpeak => "aimpeak",
            Domain::Sarcos => "sarcos",
        }
    }

    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "aimpeak" => Some(Domain::Aimpeak),
            "sarcos" => Some(Domain::Sarcos),
            _ => None,
        }
    }

    pub fn dim(self) -> usize {
        match self {
            Domain::Aimpeak => aimpeak::EMBED_DIM + 1,
            Domain::Sarcos => sarcos::INPUT_DIM,
        }
    }

    /// Curated hyperparameters (MLE on a 256-point subset, run once via
    /// `pgpr learn`; kept fixed so sweeps are comparable & fast).
    pub fn default_hyp(self) -> SeArd {
        match self {
            // long length-scales, high signal: the smooth traffic field
            // (MLE via `pgpr learn --domain aimpeak`: log_ls ≈ 0.2–0.5,
            // log_sf2 ≈ 6.0, log_sn2 ≈ 4.4)
            Domain::Aimpeak => SeArd {
                log_ls: vec![0.43, 0.27, 0.54, 0.17, -0.41],
                log_sf2: 6.0,          // sf2 ≈ 403 ≈ (20 km/h)^2
                log_sn2: (60.0f64).ln(),
            },
            // inverse-dynamics map: MLE (`pgpr learn --domain sarcos`)
            // finds long length-scales (log_ls mostly 1–4) and a high
            // signal floor — the regime where low-rank approximations
            // are meaningful (paper's choice of this dataset)
            Domain::Sarcos => SeArd {
                log_ls: vec![2.0; sarcos::INPUT_DIM],
                log_sf2: 6.0,          // sf2 ≈ 403
                log_sn2: 1.0,          // sn2 = e ≈ 2.7 (torque units)
            },
        }
    }
}

/// A prepared experiment workload.
#[derive(Debug, Clone)]
pub struct Workload {
    pub domain: Domain,
    pub train: Dataset,
    pub test: Dataset,
    pub hyp: SeArd,
}

/// Build a workload with `n_train` training and `n_test` test points.
///
/// Mirrors Section 6: generate the full dataset, randomly hold out the
/// test set, randomly select `n_train` of the rest, learn (or fix)
/// hyperparameters.
pub fn prepare(
    domain: Domain,
    n_train: usize,
    n_test: usize,
    seed: u64,
    learn: bool,
) -> Workload {
    let mut rng = Pcg64::new(seed, 0xB0);
    let needed = n_train + n_test;
    let full = match domain {
        Domain::Aimpeak => {
            // scale the grid until the record count covers the request
            let mut gw = 8;
            let mut gh = 6;
            loop {
                let cfg = aimpeak::AimpeakConfig {
                    grid_w: gw,
                    grid_h: gh,
                    seed,
                    ..Default::default()
                };
                let (_, ds) = aimpeak::generate(&cfg);
                if ds.len() >= needed {
                    break ds;
                }
                gw += 4;
                gh += 3;
            }
        }
        Domain::Sarcos => sarcos::generate(&sarcos::SarcosConfig {
            n_samples: needed.max(64),
            seed,
            ..Default::default()
        }),
    };
    assert!(full.len() >= needed, "workload generation too small");

    let idx = rng.sample_indices(full.len(), needed);
    let (test_idx, train_idx) = idx.split_at(n_test);
    let train = full.select(train_idx);
    let test = full.select(test_idx);

    let hyp = if learn {
        let init = domain.default_hyp();
        let cfg = MleConfig {
            iters: 40,
            subset: 192.min(train.len()),
            seed,
            ..Default::default()
        };
        learn_hyperparameters(&init, &train.x, &train.y, &cfg).hyp
    } else {
        domain.default_hyp()
    };

    Workload { domain, train, test, hyp }
}

/// The ground-truth hyperparameter-recovery problem shared by
/// `pgpr train`, the `train_bench` sweep and the integration suite —
/// one definition so the three acceptance claims (CLI table, bench 5%
/// gate, test 10% gate) measure the same experiment.
///
/// Latent field drawn from GP(0, k_truth) via RFF; the init is
/// deliberately far off (over-smoothed, under-signaled, over-noised) so
/// training must rediscover `truth`. Support set (entropy selection
/// under the init) and Definition 1 partition are fixed, as in the
/// training protocol.
#[derive(Debug, Clone)]
pub struct RffRecovery {
    pub train: Dataset,
    pub test: Dataset,
    pub truth: SeArd,
    pub init: SeArd,
    pub xs: Mat,
    pub d_blocks: Vec<Vec<usize>>,
}

/// Build the shared recovery problem. `n` is rounded down to a multiple
/// of `m` (Definition 1); `s` is clamped to the training size.
pub fn rff_recovery(
    n: usize,
    n_test: usize,
    d: usize,
    s: usize,
    m: usize,
    seed: u64,
) -> RffRecovery {
    assert!(m >= 1, "rff_recovery: need at least one machine");
    let n = (n / m) * m;
    assert!(n > 0, "rff_recovery: need at least {m} training points");
    let mut rng = Pcg64::new(seed, 0x7A);
    let truth = SeArd::isotropic(d, 1.2, 1.0, 0.05);
    let full = rff::synthetic_regression(&truth, n + n_test, 256, &mut rng);
    let idx: Vec<usize> = (0..n).collect();
    let tidx: Vec<usize> = (n..n + n_test).collect();
    let train = full.select(&idx);
    let test = full.select(&tidx);
    let init = SeArd::isotropic(d, 2.5, 0.4, 0.4);
    let (xs, d_blocks) = train_support_and_partition(&init, &train, s, m,
                                                     seed);
    RffRecovery { train, test, truth, init, xs, d_blocks }
}

/// Entropy support set + Definition 1 random partition for training —
/// one recipe (candidate pool = min(8·|S|, n) random rows, greedy
/// entropy selection under `init`, even random partition) shared by the
/// recovery problem above and `pgpr train`'s real-domain path. `train`
/// must already be trimmed to a multiple of `m`; `s` is clamped to n.
pub fn train_support_and_partition(
    init: &SeArd,
    train: &Dataset,
    s: usize,
    m: usize,
    seed: u64,
) -> (Mat, Vec<Vec<usize>>) {
    let n = train.len();
    assert!(m >= 1 && n % m == 0,
            "train_support_and_partition: {m} must divide {n}");
    let mut rng = Pcg64::new(seed, 0x7B);
    let s = s.min(n);
    let n_cand = n.min(s * 8).max(s);
    let cand_idx = rng.sample_indices(n, n_cand);
    let cand = train.x.select_rows(&cand_idx);
    let xs = support_matrix(init, &cand, s);
    let d_blocks = random_partition(n, m, &mut rng);
    (xs, d_blocks)
}

/// Held-out RMSE of a PITC refit under `hyp` on a fixed problem — the
/// consumer-side metric every trained hyper set is judged by.
pub fn pitc_heldout_rmse(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    train: &Dataset,
    test: &Dataset,
    xs: &Mat,
    d_blocks: &[Vec<usize>],
) -> f64 {
    let gp = PitcGp::fit_ctx(lctx, hyp, &train.x, &train.y, xs, d_blocks);
    rmse(&test.y, &gp.predict_ctx(lctx, &test.x).mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rff_recovery_shapes() {
        let r = rff_recovery(50, 16, 2, 12, 4, 3);
        assert_eq!(r.train.len(), 48, "rounded to a multiple of m");
        assert_eq!(r.test.len(), 16);
        assert_eq!(r.xs.rows, 12);
        assert_eq!(r.d_blocks.len(), 4);
        assert_eq!(r.truth.dim(), 2);
        // deterministic
        let r2 = rff_recovery(50, 16, 2, 12, 4, 3);
        assert_eq!(r.train.y, r2.train.y);
        assert_eq!(r.xs, r2.xs);
        // the refit metric runs end to end
        let v = pitc_heldout_rmse(&LinalgCtx::serial(), &r.init, &r.train,
                                  &r.test, &r.xs, &r.d_blocks);
        assert!(v.is_finite() && v > 0.0);
    }

    #[test]
    fn prepare_shapes_and_determinism() {
        let w = prepare(Domain::Sarcos, 120, 24, 3, false);
        assert_eq!(w.train.len(), 120);
        assert_eq!(w.test.len(), 24);
        assert_eq!(w.train.dim(), 21);
        let w2 = prepare(Domain::Sarcos, 120, 24, 3, false);
        assert_eq!(w.train.y, w2.train.y);
        assert_eq!(w.test.y, w2.test.y);
    }

    #[test]
    fn aimpeak_prepare_scales_grid() {
        let w = prepare(Domain::Aimpeak, 400, 40, 1, false);
        assert_eq!(w.train.len(), 400);
        assert_eq!(w.train.dim(), 5);
    }

    #[test]
    fn train_test_disjoint() {
        let w = prepare(Domain::Sarcos, 60, 20, 7, false);
        // rows drawn without replacement: no test row equals a train row
        for t in 0..w.test.len() {
            for r in 0..w.train.len() {
                assert_ne!(w.test.x.row(t), w.train.x.row(r));
            }
        }
    }

    #[test]
    fn domain_helpers() {
        assert_eq!(Domain::parse("aimpeak"), Some(Domain::Aimpeak));
        assert_eq!(Domain::parse("nope"), None);
        assert_eq!(Domain::Aimpeak.dim(), 5);
        assert_eq!(Domain::Sarcos.dim(), 21);
        assert_eq!(Domain::Aimpeak.default_hyp().dim(), 5);
    }

    #[test]
    fn learned_hyp_improves_nlml() {
        use crate::gp::likelihood::nlml_and_grad;
        let w0 = prepare(Domain::Sarcos, 150, 10, 5, false);
        let w1 = prepare(Domain::Sarcos, 150, 10, 5, true);
        // evaluate both hyps on the same subset
        let sub: Vec<usize> = (0..80).collect();
        let xs = w0.train.x.select_rows(&sub);
        let mean = w0.train.y[..80].iter().sum::<f64>() / 80.0;
        let ys: Vec<f64> = w0.train.y[..80].iter().map(|v| v - mean).collect();
        let (v0, _) = nlml_and_grad(&w0.hyp, &xs, &ys);
        let (v1, _) = nlml_and_grad(&w1.hyp, &xs, &ys);
        assert!(v1 <= v0 + 1.0, "learning made NLML worse: {v0} -> {v1}");
    }
}
