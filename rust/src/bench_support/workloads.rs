//! Workload preparation: generate the synthetic AIMPEAK / SARCOS
//! datasets at a requested size, split test data (paper: 10% random),
//! and fix hyperparameters (curated defaults learned via MLE, or learn
//! on a subset with `learn = true` as in Section 6).

use crate::data::{aimpeak, sarcos, Dataset};
use crate::gp::likelihood::{learn_hyperparameters, MleConfig};
use crate::kernel::SeArd;
use crate::util::Pcg64;

/// Evaluation domains of Section 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Aimpeak,
    Sarcos,
}

impl Domain {
    pub fn name(self) -> &'static str {
        match self {
            Domain::Aimpeak => "aimpeak",
            Domain::Sarcos => "sarcos",
        }
    }

    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "aimpeak" => Some(Domain::Aimpeak),
            "sarcos" => Some(Domain::Sarcos),
            _ => None,
        }
    }

    pub fn dim(self) -> usize {
        match self {
            Domain::Aimpeak => aimpeak::EMBED_DIM + 1,
            Domain::Sarcos => sarcos::INPUT_DIM,
        }
    }

    /// Curated hyperparameters (MLE on a 256-point subset, run once via
    /// `pgpr learn`; kept fixed so sweeps are comparable & fast).
    pub fn default_hyp(self) -> SeArd {
        match self {
            // long length-scales, high signal: the smooth traffic field
            // (MLE via `pgpr learn --domain aimpeak`: log_ls ≈ 0.2–0.5,
            // log_sf2 ≈ 6.0, log_sn2 ≈ 4.4)
            Domain::Aimpeak => SeArd {
                log_ls: vec![0.43, 0.27, 0.54, 0.17, -0.41],
                log_sf2: 6.0,          // sf2 ≈ 403 ≈ (20 km/h)^2
                log_sn2: (60.0f64).ln(),
            },
            // inverse-dynamics map: MLE (`pgpr learn --domain sarcos`)
            // finds long length-scales (log_ls mostly 1–4) and a high
            // signal floor — the regime where low-rank approximations
            // are meaningful (paper's choice of this dataset)
            Domain::Sarcos => SeArd {
                log_ls: vec![2.0; sarcos::INPUT_DIM],
                log_sf2: 6.0,          // sf2 ≈ 403
                log_sn2: 1.0,          // sn2 = e ≈ 2.7 (torque units)
            },
        }
    }
}

/// A prepared experiment workload.
#[derive(Debug, Clone)]
pub struct Workload {
    pub domain: Domain,
    pub train: Dataset,
    pub test: Dataset,
    pub hyp: SeArd,
}

/// Build a workload with `n_train` training and `n_test` test points.
///
/// Mirrors Section 6: generate the full dataset, randomly hold out the
/// test set, randomly select `n_train` of the rest, learn (or fix)
/// hyperparameters.
pub fn prepare(
    domain: Domain,
    n_train: usize,
    n_test: usize,
    seed: u64,
    learn: bool,
) -> Workload {
    let mut rng = Pcg64::new(seed, 0xB0);
    let needed = n_train + n_test;
    let full = match domain {
        Domain::Aimpeak => {
            // scale the grid until the record count covers the request
            let mut gw = 8;
            let mut gh = 6;
            loop {
                let cfg = aimpeak::AimpeakConfig {
                    grid_w: gw,
                    grid_h: gh,
                    seed,
                    ..Default::default()
                };
                let (_, ds) = aimpeak::generate(&cfg);
                if ds.len() >= needed {
                    break ds;
                }
                gw += 4;
                gh += 3;
            }
        }
        Domain::Sarcos => sarcos::generate(&sarcos::SarcosConfig {
            n_samples: needed.max(64),
            seed,
            ..Default::default()
        }),
    };
    assert!(full.len() >= needed, "workload generation too small");

    let idx = rng.sample_indices(full.len(), needed);
    let (test_idx, train_idx) = idx.split_at(n_test);
    let train = full.select(train_idx);
    let test = full.select(test_idx);

    let hyp = if learn {
        let init = domain.default_hyp();
        let cfg = MleConfig {
            iters: 40,
            subset: 192.min(train.len()),
            seed,
            ..Default::default()
        };
        learn_hyperparameters(&init, &train.x, &train.y, &cfg).hyp
    } else {
        domain.default_hyp()
    };

    Workload { domain, train, test, hyp }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_shapes_and_determinism() {
        let w = prepare(Domain::Sarcos, 120, 24, 3, false);
        assert_eq!(w.train.len(), 120);
        assert_eq!(w.test.len(), 24);
        assert_eq!(w.train.dim(), 21);
        let w2 = prepare(Domain::Sarcos, 120, 24, 3, false);
        assert_eq!(w.train.y, w2.train.y);
        assert_eq!(w.test.y, w2.test.y);
    }

    #[test]
    fn aimpeak_prepare_scales_grid() {
        let w = prepare(Domain::Aimpeak, 400, 40, 1, false);
        assert_eq!(w.train.len(), 400);
        assert_eq!(w.train.dim(), 5);
    }

    #[test]
    fn train_test_disjoint() {
        let w = prepare(Domain::Sarcos, 60, 20, 7, false);
        // rows drawn without replacement: no test row equals a train row
        for t in 0..w.test.len() {
            for r in 0..w.train.len() {
                assert_ne!(w.test.x.row(t), w.train.x.row(r));
            }
        }
    }

    #[test]
    fn domain_helpers() {
        assert_eq!(Domain::parse("aimpeak"), Some(Domain::Aimpeak));
        assert_eq!(Domain::parse("nope"), None);
        assert_eq!(Domain::Aimpeak.dim(), 5);
        assert_eq!(Domain::Sarcos.dim(), 21);
        assert_eq!(Domain::Aimpeak.default_hyp().dim(), 5);
    }

    #[test]
    fn learned_hyp_improves_nlml() {
        use crate::gp::likelihood::nlml_and_grad;
        let w0 = prepare(Domain::Sarcos, 150, 10, 5, false);
        let w1 = prepare(Domain::Sarcos, 150, 10, 5, true);
        // evaluate both hyps on the same subset
        let sub: Vec<usize> = (0..80).collect();
        let xs = w0.train.x.select_rows(&sub);
        let mean = w0.train.y[..80].iter().sum::<f64>() / 80.0;
        let ys: Vec<f64> = w0.train.y[..80].iter().map(|v| v - mean).collect();
        let (v0, _) = nlml_and_grad(&w0.hyp, &xs, &ys);
        let (v1, _) = nlml_and_grad(&w1.hyp, &xs, &ys);
        assert!(v1 <= v0 + 1.0, "learning made NLML worse: {v0} -> {v1}");
    }
}
