//! Fixed-width text tables for figure/table reproduction output, plus
//! JSON dumps for EXPERIMENTS.md bookkeeping.

use crate::util::json::{obj, Json};

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// JSON form (array of objects keyed by header).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                obj(self
                    .headers
                    .iter()
                    .zip(row.iter())
                    .map(|(h, v)| (h.as_str(), Json::Str(v.clone())))
                    .collect())
            })
            .collect();
        obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Format a float with 3 significant decimals, compactly; very large or
/// tiny magnitudes switch to scientific notation (e.g. the ICF MNLP
/// blow-ups at insufficient rank stay one cell wide).
pub fn fmt3(v: f64) -> String {
    let a = v.abs();
    if v == 0.0 {
        "0".into()
    } else if !(1e-4..1e4).contains(&a) {
        format!("{v:.2e}")
    } else if a >= 100.0 {
        format!("{v:.1}")
    } else if a >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("name"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("j", &["a"]);
        t.row(vec!["x".into()]);
        let j = t.to_json();
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str(), Some("j"));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt3_ranges() {
        assert_eq!(fmt3(0.0), "0");
        assert_eq!(fmt3(0.1234), "0.1234");
        assert_eq!(fmt3(1.23456), "1.235");
        assert_eq!(fmt3(123.456), "123.5");
    }
}
