//! The per-figure experiment harness: run any subset of the seven
//! methods (3 parallel + 3 centralized + FGP) on a workload and report
//! the paper's metrics (RMSE, MNLP, incurred time, speedup).

use std::sync::Arc;

use super::workloads::Workload;
use crate::api::{Gp, OnlineSession, PredictSpec, Regressor as _};
use crate::cluster::ParallelExecutor;
use crate::data::partition::cluster_partition;
use crate::gp::{support::support_from_pool, Prediction};
use crate::linalg::Mat;
use crate::metrics::{frac_nonpositive_var, mnlp, rmse};
use crate::runtime::Backend;
use crate::util::{Pcg64, Stopwatch};

// Method choice is a runtime value owned by the facade; re-exported here
// so pre-facade call sites (`experiments::Method`) keep compiling.
pub use crate::api::Method;

/// One experiment point (fixed |D|, M, |S|, R).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub machines: usize,
    pub support_size: usize,
    pub rank: usize,
    pub seed: u64,
    /// Host worker threads that actually execute the simulated machines'
    /// work in the parallel protocols (0 or 1 = serial, the seed
    /// behavior). Theorem-equivalence is executor-independent, so this
    /// only changes `wall_s`, never the predictions.
    pub threads: usize,
}

/// One method's measured row.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub method: Method,
    pub rmse: f64,
    pub mnlp: f64,
    /// incurred time: simulated makespan (parallel) or wall (centralized)
    pub time_s: f64,
    /// real host wall-clock seconds for the run (equals the measured
    /// wall for centralized methods; for parallel methods it shrinks
    /// toward the critical path as `ExperimentConfig::threads` grows)
    pub wall_s: f64,
    /// parallel method's speedup over its centralized counterpart (only
    /// set when both were run)
    pub speedup: Option<f64>,
    /// fraction of non-positive predictive variances (ICF pathology)
    pub bad_var: f64,
}

/// Trim train/test sizes so M divides both (Definition 1); returns
/// (xd, y, xu, yu) views.
fn evenize(w: &Workload, m: usize) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
    let n = (w.train.len() / m) * m;
    let u = (w.test.len() / m) * m;
    assert!(n > 0 && u > 0, "workload too small for M={m}");
    let idx_n: Vec<usize> = (0..n).collect();
    let idx_u: Vec<usize> = (0..u).collect();
    let train = w.train.select(&idx_n);
    let test = w.test.select(&idx_u);
    (train.x, train.y, test.x, test.y)
}

/// Incurred time of a parallel run *excluding* the reporting-only
/// collect phase (matches the paper's protocol cost; see ppitc.rs).
fn protocol_time(metrics: &crate::cluster::RunMetrics, last_phase: &str) -> f64 {
    metrics
        .phase(last_phase)
        .map(|p| p.end_makespan)
        .unwrap_or(metrics.makespan)
}

/// Run the requested methods on one workload/config. Support set and
/// partitions are shared across methods (paper setup: common S, data
/// "distributed based on the clustering scheme"), and every method is
/// constructed and driven through the [`crate::api`] facade — the same
/// `Regressor` code path the server and CLI use.
pub fn run_methods(
    w: &Workload,
    cfg: &ExperimentConfig,
    methods: &[Method],
    backend: Arc<dyn Backend>,
) -> Vec<MethodResult> {
    let m = cfg.machines;
    let (xd, y, xu, yu) = evenize(w, m);
    let mut rng = Pcg64::new(cfg.seed, 0xE1);

    // support set: the Section-6 pooled entropy recipe (shared with the
    // facade's `.support_size()` resolution)
    let xs = support_from_pool(&w.hyp, &xd, cfg.support_size, &mut rng);

    // the paper's clustering scheme fixes the partition for all methods
    let part = cluster_partition(&xd, &xu, m, &mut rng);
    let (d_blocks, u_blocks) = (part.d_blocks, part.u_blocks);

    // One executor (thread pool) shared by every method of this call —
    // centralized baselines run their blocked linalg on the same threads
    // that execute the parallel protocols' node work.
    let exec = ParallelExecutor::threads(cfg.threads);
    let builder = || {
        Gp::builder()
            .hyp(w.hyp.clone())
            .data(xd.clone(), y.clone())
            .machines(m)
            .support(xs.clone())
            .partition(d_blocks.clone())
            .rank(cfg.rank)
            .seed(cfg.seed)
            .backend(Arc::clone(&backend))
            .executor(exec.clone())
    };
    let ps = PredictSpec::new(xu.clone()).with_blocks(u_blocks.clone());

    let mut results: Vec<MethodResult> = Vec::new();
    let mut centralized_time: std::collections::HashMap<&'static str, f64> =
        std::collections::HashMap::new();

    for &method in methods {
        // spec assembly (data clones + validation) happens outside the
        // timed window — the clock measures fit + predict, as before
        let spec = builder().method(method).spec().expect("facade spec");
        let (pred, time_s, wall_s): (Prediction, f64, f64) = match method {
            Method::Online => {
                // absorb-everything-once + pPIC predict (§5.2 one-batch
                // degenerate case); incurred time = absorb + predict
                let wall = Stopwatch::new();
                let sess = OnlineSession::fit(&spec).expect("online fit");
                let out = sess.predict_full(&ps).expect("online predict");
                let secs = wall.elapsed();
                let metrics = out.metrics.expect("online runs report metrics");
                let t = sess.absorb_makespan() + protocol_time(&metrics, "predict");
                (out.prediction, t, secs)
            }
            _ => {
                let wall = Stopwatch::new();
                let gp = Gp::fit(&spec).expect("facade fit");
                let out = gp.predict_full(&ps).expect("facade predict");
                let secs = wall.elapsed();
                match out.metrics {
                    // distributed: the paper's incurred time is the
                    // simulated makespan up to the last protocol phase
                    Some(metrics) => {
                        let last = if method == Method::PIcf {
                            "finalize"
                        } else {
                            "predict"
                        };
                        (out.prediction, protocol_time(&metrics, last),
                         metrics.wall_s)
                    }
                    // centralized: incurred time = measured wall
                    None => (out.prediction, secs, secs),
                }
            }
        };
        match method {
            Method::Pitc => {
                centralized_time.insert("pitc", time_s);
            }
            Method::Pic => {
                centralized_time.insert("pic", time_s);
            }
            Method::Icf => {
                centralized_time.insert("icf", time_s);
            }
            _ => {}
        }
        let speedup = match method {
            Method::PPitc => centralized_time.get("pitc").map(|c| c / time_s),
            Method::PPic => centralized_time.get("pic").map(|c| c / time_s),
            Method::PIcf => centralized_time.get("icf").map(|c| c / time_s),
            _ => None,
        };
        results.push(MethodResult {
            method,
            rmse: rmse(&yu, &pred.mean),
            mnlp: mnlp(&yu, &pred.mean, &pred.var),
            time_s,
            wall_s,
            speedup,
            bad_var: frac_nonpositive_var(&pred.var),
        });
    }
    results
}

/// Order methods so centralized counterparts run before their parallel
/// versions (speedups need both).
pub fn speedup_order(methods: &[Method]) -> Vec<Method> {
    let mut out: Vec<Method> = methods
        .iter()
        .copied()
        .filter(|m| !Method::PARALLEL.contains(m))
        .collect();
    out.extend(methods.iter().copied().filter(|m| Method::PARALLEL.contains(m)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::workloads::{prepare, Domain};
    use crate::runtime::NativeBackend;

    fn small_workload() -> Workload {
        prepare(Domain::Sarcos, 96, 24, 11, false)
    }

    #[test]
    fn run_all_methods_small() {
        let w = small_workload();
        let cfg = ExperimentConfig {
            machines: 4,
            support_size: 12,
            rank: 16,
            seed: 1,
            threads: 0,
        };
        let order = speedup_order(&Method::ALL);
        let results = run_methods(&w, &cfg, &order, Arc::new(NativeBackend));
        assert_eq!(results.len(), 7);
        for r in &results {
            assert!(r.rmse.is_finite() && r.rmse > 0.0, "{:?}", r.method);
            assert!(r.mnlp.is_finite(), "{:?}", r.method);
            assert!(r.time_s > 0.0);
        }
        // speedups set for the parallel methods
        for m in Method::PARALLEL {
            let r = results.iter().find(|r| r.method == m).unwrap();
            assert!(r.speedup.is_some(), "{:?} missing speedup", m);
        }
        // FGP is the accuracy anchor: approximations shouldn't beat it
        // by a lot, nor be catastrophically worse on this smooth problem
        let fgp = results.iter().find(|r| r.method == Method::Fgp).unwrap();
        let ppic = results.iter().find(|r| r.method == Method::PPic).unwrap();
        assert!(ppic.rmse < fgp.rmse * 5.0 + 5.0);
    }

    #[test]
    fn theorem_equivalences_hold_in_harness() {
        // pPITC == PITC, pPIC == PIC, pICF == ICF inside the harness too
        let w = small_workload();
        let cfg = ExperimentConfig {
            machines: 3,
            support_size: 10,
            rank: 12,
            seed: 2,
            threads: 0,
        };
        let results = run_methods(
            &w, &cfg,
            &[Method::Pitc, Method::Pic, Method::Icf,
              Method::PPitc, Method::PPic, Method::PIcf],
            Arc::new(NativeBackend),
        );
        let get = |m: Method| results.iter().find(|r| r.method == m).unwrap();
        for (a, b) in [(Method::PPitc, Method::Pitc),
                       (Method::PPic, Method::Pic),
                       (Method::PIcf, Method::Icf)] {
            let (ra, rb) = (get(a), get(b));
            assert!((ra.rmse - rb.rmse).abs() < 1e-8,
                    "{:?} {} vs {:?} {}", a, ra.rmse, b, rb.rmse);
            assert_eq!(ra.bad_var, rb.bad_var);
            // MNLP is chaotic in the non-PSD-variance regime (1/var with
            // var ≈ 0 amplifies fp differences); compare only when sane.
            if ra.bad_var == 0.0 {
                assert!((ra.mnlp - rb.mnlp).abs()
                            < 1e-6 * (1.0 + rb.mnlp.abs()),
                        "{:?} mnlp {} vs {:?} {}", a, ra.mnlp, b, rb.mnlp);
            }
        }
    }

    /// Same config, serial vs thread-parallel executor: every accuracy
    /// metric must be identical — threads only change wall_s.
    #[test]
    fn harness_results_executor_independent() {
        let w = small_workload();
        let mk = |threads: usize| ExperimentConfig {
            machines: 4,
            support_size: 12,
            rank: 16,
            seed: 3,
            threads,
        };
        let methods = Method::PARALLEL;
        let serial = run_methods(&w, &mk(0), &methods, Arc::new(NativeBackend));
        let par = run_methods(&w, &mk(4), &methods, Arc::new(NativeBackend));
        for (a, b) in serial.iter().zip(par.iter()) {
            assert_eq!(a.method, b.method);
            assert_eq!(a.rmse, b.rmse, "{:?}", a.method);
            assert_eq!(a.mnlp, b.mnlp, "{:?}", a.method);
            assert_eq!(a.bad_var, b.bad_var);
            assert!(b.wall_s > 0.0);
        }
    }

    /// The §5.2 online mode runs in the harness and, with everything in
    /// one batch, reproduces pPIC on the same partition.
    #[test]
    fn online_runs_in_harness() {
        let w = small_workload();
        let cfg = ExperimentConfig {
            machines: 4,
            support_size: 10,
            rank: 12,
            seed: 5,
            threads: 0,
        };
        let results = run_methods(&w, &cfg, &[Method::PPic, Method::Online],
                                  Arc::new(NativeBackend));
        assert_eq!(results.len(), 2);
        let (ppic, online) = (&results[0], &results[1]);
        assert!((ppic.rmse - online.rmse).abs() < 1e-8,
                "online one-batch should equal pPIC: {} vs {}",
                online.rmse, ppic.rmse);
        assert!(online.time_s > 0.0);
        assert!(online.speedup.is_none());
    }

    #[test]
    fn speedup_order_puts_centralized_first() {
        let order = speedup_order(&[Method::PPic, Method::Pic, Method::Fgp]);
        assert_eq!(order, vec![Method::Pic, Method::Fgp, Method::PPic]);
    }

    #[test]
    fn evenize_trims() {
        let w = prepare(Domain::Sarcos, 50, 13, 3, false);
        let (xd, y, xu, yu) = evenize(&w, 4);
        assert_eq!(xd.rows, 48);
        assert_eq!(y.len(), 48);
        assert_eq!(xu.rows, 12);
        assert_eq!(yu.len(), 12);
    }
}
