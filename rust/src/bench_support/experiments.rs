//! The per-figure experiment harness: run any subset of the seven
//! methods (3 parallel + 3 centralized + FGP) on a workload and report
//! the paper's metrics (RMSE, MNLP, incurred time, speedup).

use super::workloads::Workload;
use crate::data::partition::cluster_partition;
use crate::gp::{fgp::FullGp, icf_gp::IcfGp, pic::PicGp, pitc::PitcGp,
                support::support_matrix, Prediction};
use crate::linalg::Mat;
use crate::metrics::{frac_nonpositive_var, mnlp, rmse};
use crate::parallel::{picf, ppic, ppitc, ClusterSpec};
use crate::runtime::Backend;
use crate::util::{Pcg64, Stopwatch};

/// The methods of Section 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    PPitc,
    PPic,
    PIcf,
    Pitc,
    Pic,
    Icf,
    Fgp,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::PPitc => "pPITC",
            Method::PPic => "pPIC",
            Method::PIcf => "pICF",
            Method::Pitc => "PITC",
            Method::Pic => "PIC",
            Method::Icf => "ICF",
            Method::Fgp => "FGP",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "ppitc" => Some(Method::PPitc),
            "ppic" => Some(Method::PPic),
            "picf" => Some(Method::PIcf),
            "pitc" => Some(Method::Pitc),
            "pic" => Some(Method::Pic),
            "icf" => Some(Method::Icf),
            "fgp" => Some(Method::Fgp),
            _ => None,
        }
    }

    pub const ALL: [Method; 7] = [
        Method::PPitc, Method::PPic, Method::PIcf,
        Method::Pitc, Method::Pic, Method::Icf, Method::Fgp,
    ];

    pub const PARALLEL: [Method; 3] =
        [Method::PPitc, Method::PPic, Method::PIcf];
}

/// One experiment point (fixed |D|, M, |S|, R).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub machines: usize,
    pub support_size: usize,
    pub rank: usize,
    pub seed: u64,
    /// Host worker threads that actually execute the simulated machines'
    /// work in the parallel protocols (0 or 1 = serial, the seed
    /// behavior). Theorem-equivalence is executor-independent, so this
    /// only changes `wall_s`, never the predictions.
    pub threads: usize,
}

/// One method's measured row.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub method: Method,
    pub rmse: f64,
    pub mnlp: f64,
    /// incurred time: simulated makespan (parallel) or wall (centralized)
    pub time_s: f64,
    /// real host wall-clock seconds for the run (equals the measured
    /// wall for centralized methods; for parallel methods it shrinks
    /// toward the critical path as `ExperimentConfig::threads` grows)
    pub wall_s: f64,
    /// parallel method's speedup over its centralized counterpart (only
    /// set when both were run)
    pub speedup: Option<f64>,
    /// fraction of non-positive predictive variances (ICF pathology)
    pub bad_var: f64,
}

/// Trim train/test sizes so M divides both (Definition 1); returns
/// (xd, y, xu, yu) views.
fn evenize(w: &Workload, m: usize) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
    let n = (w.train.len() / m) * m;
    let u = (w.test.len() / m) * m;
    assert!(n > 0 && u > 0, "workload too small for M={m}");
    let idx_n: Vec<usize> = (0..n).collect();
    let idx_u: Vec<usize> = (0..u).collect();
    let train = w.train.select(&idx_n);
    let test = w.test.select(&idx_u);
    (train.x, train.y, test.x, test.y)
}

/// Incurred time of a parallel run *excluding* the reporting-only
/// collect phase (matches the paper's protocol cost; see ppitc.rs).
fn protocol_time(metrics: &crate::cluster::RunMetrics, last_phase: &str) -> f64 {
    metrics
        .phase(last_phase)
        .map(|p| p.end_makespan)
        .unwrap_or(metrics.makespan)
}

/// Run the requested methods on one workload/config. Support set and
/// partitions are shared across methods (paper setup: common S, data
/// "distributed based on the clustering scheme").
pub fn run_methods(
    w: &Workload,
    cfg: &ExperimentConfig,
    methods: &[Method],
    backend: &dyn Backend,
) -> Vec<MethodResult> {
    let m = cfg.machines;
    let (xd, y, xu, yu) = evenize(w, m);
    let mut rng = Pcg64::new(cfg.seed, 0xE1);

    // support set: differential-entropy greedy selection over a candidate
    // subset of the training inputs (bounded for tractability)
    let n_cand = xd.rows.min(cfg.support_size * 8).max(cfg.support_size);
    let cand_idx = rng.sample_indices(xd.rows, n_cand);
    let cand = xd.select_rows(&cand_idx);
    let xs = support_matrix(&w.hyp, &cand, cfg.support_size);

    // the paper's clustering scheme fixes the partition for all methods
    let part = cluster_partition(&xd, &xu, m, &mut rng);
    let (d_blocks, u_blocks) = (part.d_blocks, part.u_blocks);

    let spec = ClusterSpec::with_threads(m, cfg.threads);
    // Centralized baselines use the same host threads through the
    // blocked engine (pooled LinalgCtx) — apples-to-apples with the
    // thread-parallel protocol runs.
    let lctx = spec.exec.linalg_ctx();
    let mut results: Vec<MethodResult> = Vec::new();
    let mut centralized_time: std::collections::HashMap<&'static str, f64> =
        std::collections::HashMap::new();

    for &method in methods {
        let (pred, time_s, wall_s): (Prediction, f64, f64) = match method {
            Method::Fgp => {
                let (p, secs) = Stopwatch::time(|| {
                    let gp = FullGp::fit_ctx(&lctx, &w.hyp, &xd, &y);
                    gp.predict_ctx(&lctx, &xu)
                });
                (p, secs, secs)
            }
            Method::Pitc => {
                let (p, secs) = Stopwatch::time(|| {
                    let gp = PitcGp::fit_ctx(&lctx, &w.hyp, &xd, &y, &xs,
                                             &d_blocks);
                    gp.predict_ctx(&lctx, &xu)
                });
                centralized_time.insert("pitc", secs);
                (p, secs, secs)
            }
            Method::Pic => {
                let (p, secs) = Stopwatch::time(|| {
                    let gp = PicGp::fit_ctx(&lctx, &w.hyp, &xd, &y, &xs,
                                            &d_blocks);
                    gp.predict_ctx(&lctx, &xu, &u_blocks)
                });
                centralized_time.insert("pic", secs);
                (p, secs, secs)
            }
            Method::Icf => {
                let (p, secs) = Stopwatch::time(|| {
                    let gp = IcfGp::fit_ctx(&lctx, &w.hyp, &xd, &y, cfg.rank,
                                            &d_blocks);
                    gp.predict_ctx(&lctx, &xu)
                });
                centralized_time.insert("icf", secs);
                (p, secs, secs)
            }
            Method::PPitc => {
                let out = ppitc::run(&w.hyp, &xd, &y, &xs, &xu, &d_blocks,
                                     &u_blocks, backend, &spec);
                let t = protocol_time(&out.metrics, "predict");
                (out.prediction, t, out.metrics.wall_s)
            }
            Method::PPic => {
                let out = ppic::run_with_partition(&w.hyp, &xd, &y, &xs, &xu,
                                                   &d_blocks, &u_blocks,
                                                   backend, &spec);
                let t = protocol_time(&out.metrics, "predict");
                (out.prediction, t, out.metrics.wall_s)
            }
            Method::PIcf => {
                let out = picf::run(&w.hyp, &xd, &y, &xu, &d_blocks,
                                    cfg.rank, backend, &spec);
                let t = protocol_time(&out.metrics, "finalize");
                (out.prediction, t, out.metrics.wall_s)
            }
        };
        let speedup = match method {
            Method::PPitc => centralized_time.get("pitc").map(|c| c / time_s),
            Method::PPic => centralized_time.get("pic").map(|c| c / time_s),
            Method::PIcf => centralized_time.get("icf").map(|c| c / time_s),
            _ => None,
        };
        results.push(MethodResult {
            method,
            rmse: rmse(&yu, &pred.mean),
            mnlp: mnlp(&yu, &pred.mean, &pred.var),
            time_s,
            wall_s,
            speedup,
            bad_var: frac_nonpositive_var(&pred.var),
        });
    }
    results
}

/// Order methods so centralized counterparts run before their parallel
/// versions (speedups need both).
pub fn speedup_order(methods: &[Method]) -> Vec<Method> {
    let mut out: Vec<Method> = methods
        .iter()
        .copied()
        .filter(|m| !Method::PARALLEL.contains(m))
        .collect();
    out.extend(methods.iter().copied().filter(|m| Method::PARALLEL.contains(m)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::workloads::{prepare, Domain};
    use crate::runtime::NativeBackend;

    fn small_workload() -> Workload {
        prepare(Domain::Sarcos, 96, 24, 11, false)
    }

    #[test]
    fn run_all_methods_small() {
        let w = small_workload();
        let cfg = ExperimentConfig {
            machines: 4,
            support_size: 12,
            rank: 16,
            seed: 1,
            threads: 0,
        };
        let order = speedup_order(&Method::ALL);
        let results = run_methods(&w, &cfg, &order, &NativeBackend);
        assert_eq!(results.len(), 7);
        for r in &results {
            assert!(r.rmse.is_finite() && r.rmse > 0.0, "{:?}", r.method);
            assert!(r.mnlp.is_finite(), "{:?}", r.method);
            assert!(r.time_s > 0.0);
        }
        // speedups set for the parallel methods
        for m in Method::PARALLEL {
            let r = results.iter().find(|r| r.method == m).unwrap();
            assert!(r.speedup.is_some(), "{:?} missing speedup", m);
        }
        // FGP is the accuracy anchor: approximations shouldn't beat it
        // by a lot, nor be catastrophically worse on this smooth problem
        let fgp = results.iter().find(|r| r.method == Method::Fgp).unwrap();
        let ppic = results.iter().find(|r| r.method == Method::PPic).unwrap();
        assert!(ppic.rmse < fgp.rmse * 5.0 + 5.0);
    }

    #[test]
    fn theorem_equivalences_hold_in_harness() {
        // pPITC == PITC, pPIC == PIC, pICF == ICF inside the harness too
        let w = small_workload();
        let cfg = ExperimentConfig {
            machines: 3,
            support_size: 10,
            rank: 12,
            seed: 2,
            threads: 0,
        };
        let results = run_methods(
            &w, &cfg,
            &[Method::Pitc, Method::Pic, Method::Icf,
              Method::PPitc, Method::PPic, Method::PIcf],
            &NativeBackend,
        );
        let get = |m: Method| results.iter().find(|r| r.method == m).unwrap();
        for (a, b) in [(Method::PPitc, Method::Pitc),
                       (Method::PPic, Method::Pic),
                       (Method::PIcf, Method::Icf)] {
            let (ra, rb) = (get(a), get(b));
            assert!((ra.rmse - rb.rmse).abs() < 1e-8,
                    "{:?} {} vs {:?} {}", a, ra.rmse, b, rb.rmse);
            assert_eq!(ra.bad_var, rb.bad_var);
            // MNLP is chaotic in the non-PSD-variance regime (1/var with
            // var ≈ 0 amplifies fp differences); compare only when sane.
            if ra.bad_var == 0.0 {
                assert!((ra.mnlp - rb.mnlp).abs()
                            < 1e-6 * (1.0 + rb.mnlp.abs()),
                        "{:?} mnlp {} vs {:?} {}", a, ra.mnlp, b, rb.mnlp);
            }
        }
    }

    /// Same config, serial vs thread-parallel executor: every accuracy
    /// metric must be identical — threads only change wall_s.
    #[test]
    fn harness_results_executor_independent() {
        let w = small_workload();
        let mk = |threads: usize| ExperimentConfig {
            machines: 4,
            support_size: 12,
            rank: 16,
            seed: 3,
            threads,
        };
        let methods = Method::PARALLEL;
        let serial = run_methods(&w, &mk(0), &methods, &NativeBackend);
        let par = run_methods(&w, &mk(4), &methods, &NativeBackend);
        for (a, b) in serial.iter().zip(par.iter()) {
            assert_eq!(a.method, b.method);
            assert_eq!(a.rmse, b.rmse, "{:?}", a.method);
            assert_eq!(a.mnlp, b.mnlp, "{:?}", a.method);
            assert_eq!(a.bad_var, b.bad_var);
            assert!(b.wall_s > 0.0);
        }
    }

    #[test]
    fn speedup_order_puts_centralized_first() {
        let order = speedup_order(&[Method::PPic, Method::Pic, Method::Fgp]);
        assert_eq!(order, vec![Method::Pic, Method::Fgp, Method::PPic]);
    }

    #[test]
    fn evenize_trims() {
        let w = prepare(Domain::Sarcos, 50, 13, 3, false);
        let (xd, y, xu, yu) = evenize(&w, 4);
        assert_eq!(xd.rows, 48);
        assert_eq!(y.len(), 48);
        assert_eq!(xu.rows, 12);
        assert_eq!(yu.len(), 12);
    }
}
