//! Micro-benchmark timing loop (the vendor set has no criterion): warm
//! up, run a target number of iterations or a time budget, report
//! median/mean/min. Used by `rust/benches/*` with `harness = false`.

use crate::util::Stopwatch;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>5} iters  median {:>12}  mean {:>12}  min {:>12}",
            self.name,
            self.iters,
            crate::util::time::fmt_secs(self.median_s),
            crate::util::time::fmt_secs(self.mean_s),
            crate::util::time::fmt_secs(self.min_s),
        )
    }
}

/// Benchmark a closure: 1 warmup + up to `max_iters` timed runs, stopping
/// early once `budget_s` of measurement time is spent (≥1 timed run).
pub fn bench_fn(
    name: &str,
    max_iters: usize,
    budget_s: f64,
    mut f: impl FnMut(),
) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(max_iters.max(1));
    let total = Stopwatch::new();
    for _ in 0..max_iters.max(1) {
        let sw = Stopwatch::new();
        f();
        samples.push(sw.elapsed());
        if total.elapsed() >= budget_s {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let iters = samples.len();
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: samples.iter().sum::<f64>() / iters as f64,
        median_s: samples[iters / 2],
        min_s: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut count = 0;
        let r = bench_fn("noop", 10, 10.0, || {
            count += 1;
        });
        assert_eq!(count, 11); // warmup + 10
        assert_eq!(r.iters, 10);
        assert!(r.min_s <= r.median_s && r.median_s <= r.mean_s * 10.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn budget_stops_early() {
        let r = bench_fn("sleepy", 1000, 0.02, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(r.iters < 1000);
        assert!(r.iters >= 1);
    }
}
