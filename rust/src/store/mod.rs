//! Durable model state: deterministic, versioned, checksummed
//! checkpoints for every `api::Method` plus the staged serving model
//! (ROADMAP item 3).
//!
//! Three checkpoint families cover the system:
//!
//! * [`BatchCheckpoint`] — the seven batch methods. The checkpoint
//!   carries the *resolved fit ingredients* (hyperparameters, data,
//!   machine count, materialized support set and partition, rank,
//!   threads, seed, precision mode) rather than the fitted factors:
//!   fitting from a resolved spec is bitwise-reproducible in this
//!   crate, so re-running the deterministic fit on load reproduces the
//!   original model exactly while keeping the file format independent
//!   of every internal factor layout.
//! * [`ServedCheckpoint`] — a [`crate::server::ServedModel`]'s fitted
//!   state (support set, global/local summaries, centered targets).
//!   Loading re-stages the predictive operators through the same pure
//!   constructors `fit` uses, so a cold-started node serves bitwise
//!   what the original process served — without refitting.
//! * [`OnlineCheckpoint`] — an [`crate::api::OnlineSession`] mid-stream:
//!   fit ingredients plus the assimilated global summary, its Cholesky
//!   factor, and each machine's latest block. Restoring and absorbing
//!   the remaining batches is bitwise-identical to an uninterrupted
//!   run (pinned in `tests/integration_store.rs`). The wall-clock
//!   `absorb_makespan` accumulator is deliberately *not* persisted —
//!   it is measurement, not model state, and would break byte-identity
//!   of checkpoints across runs.
//!
//! File format and error taxonomy live in [`format`]; writes go through
//! [`write_bytes_atomic`] (temp file + fsync + rename) so a crash
//! mid-snapshot never clobbers the last good checkpoint.

pub mod format;

pub use format::{crc32, StoreError, FORMAT_VERSION, MAGIC};

use crate::api::Method;
use crate::gp::summaries::{GlobalSummary, LocalSummary};
use crate::kernel::SeArd;
use crate::linalg::Mat;
use format::{Reader, SectionWriter, Writer};
use std::path::Path;

/// One machine's durable block: inputs, centered targets, and the
/// Definition-2 local summary.
pub type BlockState = (Mat, Vec<f64>, LocalSummary);

// ---------------------------------------------------------------------
// Method tags
// ---------------------------------------------------------------------

/// Stable on-disk tag for each model family. Tags are append-only —
/// never renumber.
#[must_use]
pub fn tag_of(method: Method) -> u8 {
    match method {
        Method::Fgp => 1,
        Method::Pitc => 2,
        Method::Pic => 3,
        Method::Icf => 4,
        Method::PPitc => 5,
        Method::PPic => 6,
        Method::PIcf => 7,
        Method::Online => 8,
    }
}

/// Tag for the staged serving model (not an `api::Method`).
pub const SERVED_TAG: u8 = 9;

fn method_of(tag: u8) -> Option<Method> {
    Some(match tag {
        1 => Method::Fgp,
        2 => Method::Pitc,
        3 => Method::Pic,
        4 => Method::Icf,
        5 => Method::PPitc,
        6 => Method::PPic,
        7 => Method::PIcf,
        8 => Method::Online,
        _ => return None,
    })
}

// ---------------------------------------------------------------------
// Checkpoint payloads
// ---------------------------------------------------------------------

/// Resolved fit ingredients for one of the seven batch methods.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchCheckpoint {
    pub method: Method,
    pub hyp: SeArd,
    pub xd: Mat,
    pub y: Vec<f64>,
    pub machines: usize,
    /// Materialized support points (None for methods without S).
    pub support: Option<Mat>,
    /// Materialized Definition-1 partition (None for FGP).
    pub partition: Option<Vec<Vec<usize>>>,
    pub rank: Option<usize>,
    pub threads: usize,
    pub seed: u64,
    pub mixed_precision: bool,
}

/// A `ServedModel`'s fitted state (operators are re-staged on load).
#[derive(Debug, Clone, PartialEq)]
pub struct ServedCheckpoint {
    pub hyp: SeArd,
    pub xs: Mat,
    pub y_mean: f64,
    pub global: GlobalSummary,
    /// Per-machine (inputs, centered targets, local summary).
    pub blocks: Vec<BlockState>,
    pub mixed_precision: bool,
}

/// An `OnlineSession` mid-stream: fit ingredients + assimilated state.
#[derive(Debug, Clone, PartialEq)]
pub struct OnlineCheckpoint {
    pub hyp: SeArd,
    pub xd: Mat,
    pub y: Vec<f64>,
    pub machines: usize,
    pub support: Mat,
    pub partition: Vec<Vec<usize>>,
    pub threads: usize,
    pub seed: u64,
    pub mixed_precision: bool,
    /// Target mean fixed by the first absorbed batch (None before it).
    pub y_mean: Option<f64>,
    pub global: Option<GlobalSummary>,
    /// chol of the assimilated global summary matrix.
    pub l_g: Option<Mat>,
    /// Each machine's latest absorbed block (None if it never got one).
    pub latest: Vec<Option<BlockState>>,
    pub batches: usize,
}

/// Any pgpr checkpoint. Encode/decode are exact inverses and encoding
/// is a pure function of the state — the same state always produces the
/// same bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum Checkpoint {
    Batch(BatchCheckpoint),
    Served(ServedCheckpoint),
    Online(OnlineCheckpoint),
}

impl Checkpoint {
    /// On-disk method tag.
    #[must_use]
    pub fn tag(&self) -> u8 {
        match self {
            Checkpoint::Batch(b) => tag_of(b.method),
            Checkpoint::Online(_) => tag_of(Method::Online),
            Checkpoint::Served(_) => SERVED_TAG,
        }
    }

    /// Human name of the stored model family (paper terminology).
    #[must_use]
    pub fn method_name(&self) -> &'static str {
        match self {
            Checkpoint::Batch(b) => b.method.name(),
            Checkpoint::Online(_) => Method::Online.name(),
            Checkpoint::Served(_) => "served",
        }
    }

    /// Serialize to the versioned byte format (deterministic).
    #[must_use]
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(self.tag());
        match self {
            Checkpoint::Batch(b) => encode_batch(&mut w, b),
            Checkpoint::Served(s) => encode_served(&mut w, s),
            Checkpoint::Online(o) => encode_online(&mut w, o),
        }
        w.finish()
    }

    /// Parse + validate a checkpoint image.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, StoreError> {
        let (tag, mut r) = Reader::open(bytes)?;
        let ckpt = if tag == SERVED_TAG {
            Checkpoint::Served(decode_served(&mut r)?)
        } else {
            match method_of(tag) {
                None => return Err(StoreError::UnknownMethodTag(tag)),
                Some(Method::Online) => Checkpoint::Online(decode_online(&mut r)?),
                Some(m) => Checkpoint::Batch(decode_batch(&mut r, m)?),
            }
        };
        r.finish()?;
        Ok(ckpt)
    }

    /// CRC-32 of the encoded image — the "checkpoint version hash"
    /// surfaced by `/healthz`.
    #[must_use]
    pub fn version_hash(&self) -> u32 {
        crc32(&self.encode())
    }

    /// Atomically write to `path`; returns the byte count written.
    /// Instrumented once here so every snapshot path (CLI, periodic,
    /// admin endpoint, facade `save`) exports the same telemetry.
    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<u64, StoreError> {
        let _span = crate::obsv::span("store.snapshot")
            .with_str("method", self.method_name());
        let t0 = std::time::Instant::now();
        let bytes = self.encode();
        write_bytes_atomic(path, &bytes)?;
        if crate::obsv::enabled() {
            crate::obsv::counter_add("store.snapshot.count", 1);
            crate::obsv::counter_add("store.snapshot.bytes",
                                     bytes.len() as u64);
            crate::obsv::observe("store.snapshot.latency_s",
                                 crate::obsv::Unit::Seconds,
                                 t0.elapsed().as_secs_f64());
        }
        Ok(bytes.len() as u64)
    }

    /// Read + decode a checkpoint file.
    pub fn read_file(path: impl AsRef<Path>) -> Result<Checkpoint, StoreError> {
        let _span = crate::obsv::span("store.restore");
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))?;
        let ck = Checkpoint::decode(&bytes)?;
        crate::obsv::counter_add("store.restore.count", 1);
        Ok(ck)
    }
}

/// Crash-safe file write: temp sibling + fsync + atomic rename, so the
/// destination always holds either the old image or the complete new
/// one — never a torn write.
pub fn write_bytes_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<(), StoreError> {
    use std::io::Write;
    let path = path.as_ref();
    let mut tmp_os = path.as_os_str().to_os_string();
    tmp_os.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_os);
    let ctx = |e: std::io::Error| StoreError::Io(format!("{}: {e}", tmp.display()));
    let mut f = std::fs::File::create(&tmp).map_err(ctx)?;
    f.write_all(bytes).map_err(ctx)?;
    f.sync_all().map_err(ctx)?;
    drop(f);
    std::fs::rename(&tmp, path)
        .map_err(|e| StoreError::Io(format!("{}: {e}", path.display())))
}

// ---------------------------------------------------------------------
// Shared field codecs
// ---------------------------------------------------------------------

fn put_hyp(s: &mut SectionWriter<'_>, hyp: &SeArd) {
    s.put_vec_f64(&hyp.log_ls);
    s.put_f64(hyp.log_sf2);
    s.put_f64(hyp.log_sn2);
}

fn get_hyp(r: &mut Reader<'_>) -> Result<SeArd, StoreError> {
    Ok(SeArd {
        log_ls: r.get_vec_f64()?,
        log_sf2: r.get_f64()?,
        log_sn2: r.get_f64()?,
    })
}

fn put_partition(s: &mut SectionWriter<'_>, blocks: &[Vec<usize>]) {
    s.put_usize(blocks.len());
    for b in blocks {
        s.put_vec_usize(b);
    }
}

fn get_partition(r: &mut Reader<'_>) -> Result<Vec<Vec<usize>>, StoreError> {
    let n = r.get_usize()?;
    let mut blocks = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        blocks.push(r.get_vec_usize()?);
    }
    Ok(blocks)
}

fn put_block(s: &mut SectionWriter<'_>, (xm, ym, loc): &BlockState) {
    s.put_mat(xm);
    s.put_vec_f64(ym);
    s.put_vec_f64(&loc.y_dot);
    s.put_mat(&loc.s_dot);
    s.put_mat(&loc.l_m);
}

fn get_block(r: &mut Reader<'_>) -> Result<BlockState, StoreError> {
    let xm = r.get_mat()?;
    let ym = r.get_vec_f64()?;
    let loc = LocalSummary {
        y_dot: r.get_vec_f64()?,
        s_dot: r.get_mat()?,
        l_m: r.get_mat()?,
    };
    Ok((xm, ym, loc))
}

fn put_global(s: &mut SectionWriter<'_>, g: &GlobalSummary) {
    s.put_vec_f64(&g.y);
    s.put_mat(&g.s);
}

fn get_global(r: &mut Reader<'_>) -> Result<GlobalSummary, StoreError> {
    Ok(GlobalSummary { y: r.get_vec_f64()?, s: r.get_mat()? })
}

// ---------------------------------------------------------------------
// Batch
// ---------------------------------------------------------------------

fn encode_batch(w: &mut Writer, b: &BatchCheckpoint) {
    w.section("spec", |s| {
        s.put_usize(b.machines);
        s.put_usize(b.threads);
        s.put_u64(b.seed);
        s.put_opt_usize(b.rank);
        s.put_bool(b.mixed_precision);
    });
    w.section("hyp", |s| put_hyp(s, &b.hyp));
    w.section("data", |s| {
        s.put_mat(&b.xd);
        s.put_vec_f64(&b.y);
    });
    w.section("support", |s| s.put_opt_mat(b.support.as_ref()));
    w.section("partition", |s| match &b.partition {
        Some(p) => {
            s.put_bool(true);
            put_partition(s, p);
        }
        None => s.put_bool(false),
    });
}

fn decode_batch(r: &mut Reader<'_>, method: Method) -> Result<BatchCheckpoint, StoreError> {
    r.section("spec")?;
    let machines = r.get_usize()?;
    let threads = r.get_usize()?;
    let seed = r.get_u64()?;
    let rank = r.get_opt_usize()?;
    let mixed_precision = r.get_bool()?;
    r.section("hyp")?;
    let hyp = get_hyp(r)?;
    r.section("data")?;
    let xd = r.get_mat()?;
    let y = r.get_vec_f64()?;
    r.section("support")?;
    let support = r.get_opt_mat()?;
    r.section("partition")?;
    let partition = if r.get_bool()? { Some(get_partition(r)?) } else { None };
    Ok(BatchCheckpoint {
        method,
        hyp,
        xd,
        y,
        machines,
        support,
        partition,
        rank,
        threads,
        seed,
        mixed_precision,
    })
}

// ---------------------------------------------------------------------
// Served
// ---------------------------------------------------------------------

fn encode_served(w: &mut Writer, m: &ServedCheckpoint) {
    w.section("hyp", |s| put_hyp(s, &m.hyp));
    w.section("support", |s| s.put_mat(&m.xs));
    w.section("moments", |s| {
        s.put_f64(m.y_mean);
        put_global(s, &m.global);
    });
    w.section("blocks", |s| {
        s.put_usize(m.blocks.len());
        for b in &m.blocks {
            put_block(s, b);
        }
    });
    w.section("serve", |s| s.put_bool(m.mixed_precision));
}

fn decode_served(r: &mut Reader<'_>) -> Result<ServedCheckpoint, StoreError> {
    r.section("hyp")?;
    let hyp = get_hyp(r)?;
    r.section("support")?;
    let xs = r.get_mat()?;
    r.section("moments")?;
    let y_mean = r.get_f64()?;
    let global = get_global(r)?;
    r.section("blocks")?;
    let n = r.get_usize()?;
    let mut blocks = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        blocks.push(get_block(r)?);
    }
    r.section("serve")?;
    let mixed_precision = r.get_bool()?;
    if global.y.len() != xs.rows {
        return Err(StoreError::Corrupt {
            section: "moments",
            reason: format!(
                "global summary dim {} != support size {}",
                global.y.len(),
                xs.rows
            ),
        });
    }
    Ok(ServedCheckpoint { hyp, xs, y_mean, global, blocks, mixed_precision })
}

// ---------------------------------------------------------------------
// Online
// ---------------------------------------------------------------------

fn encode_online(w: &mut Writer, o: &OnlineCheckpoint) {
    w.section("spec", |s| {
        s.put_usize(o.machines);
        s.put_usize(o.threads);
        s.put_u64(o.seed);
        s.put_bool(o.mixed_precision);
    });
    w.section("hyp", |s| put_hyp(s, &o.hyp));
    w.section("data", |s| {
        s.put_mat(&o.xd);
        s.put_vec_f64(&o.y);
    });
    w.section("support", |s| s.put_mat(&o.support));
    w.section("partition", |s| put_partition(s, &o.partition));
    w.section("stream", |s| {
        s.put_opt_f64(o.y_mean);
        s.put_usize(o.batches);
    });
    w.section("global", |s| {
        match &o.global {
            Some(g) => {
                s.put_bool(true);
                put_global(s, g);
            }
            None => s.put_bool(false),
        }
        s.put_opt_mat(o.l_g.as_ref());
    });
    w.section("latest", |s| {
        s.put_usize(o.latest.len());
        for slot in &o.latest {
            match slot {
                Some(b) => {
                    s.put_bool(true);
                    put_block(s, b);
                }
                None => s.put_bool(false),
            }
        }
    });
}

fn decode_online(r: &mut Reader<'_>) -> Result<OnlineCheckpoint, StoreError> {
    r.section("spec")?;
    let machines = r.get_usize()?;
    let threads = r.get_usize()?;
    let seed = r.get_u64()?;
    let mixed_precision = r.get_bool()?;
    r.section("hyp")?;
    let hyp = get_hyp(r)?;
    r.section("data")?;
    let xd = r.get_mat()?;
    let y = r.get_vec_f64()?;
    r.section("support")?;
    let support = r.get_mat()?;
    r.section("partition")?;
    let partition = get_partition(r)?;
    r.section("stream")?;
    let y_mean = r.get_opt_f64()?;
    let batches = r.get_usize()?;
    r.section("global")?;
    let global = if r.get_bool()? { Some(get_global(r)?) } else { None };
    let l_g = r.get_opt_mat()?;
    r.section("latest")?;
    let n = r.get_usize()?;
    let mut latest = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        latest.push(if r.get_bool()? { Some(get_block(r)?) } else { None });
    }
    if latest.len() != machines {
        return Err(StoreError::Corrupt {
            section: "latest",
            reason: format!("{} slots for {} machines", latest.len(), machines),
        });
    }
    Ok(OnlineCheckpoint {
        hyp,
        xd,
        y,
        machines,
        support,
        partition,
        threads,
        seed,
        mixed_precision,
        y_mean,
        global,
        l_g,
        latest,
        batches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_batch() -> Checkpoint {
        Checkpoint::Batch(BatchCheckpoint {
            method: Method::PPic,
            hyp: SeArd::isotropic(2, 1.0, 1.0, 0.05),
            xd: Mat::from_vec(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]),
            y: vec![1.0, -2.0, 3.0],
            machines: 2,
            support: Some(Mat::from_vec(1, 2, vec![0.5, 0.5])),
            partition: Some(vec![vec![0, 2], vec![1]]),
            rank: None,
            threads: 0,
            seed: 7,
            mixed_precision: false,
        })
    }

    #[test]
    fn batch_roundtrip_and_determinism() {
        let ck = sample_batch();
        let a = ck.encode();
        let b = ck.encode();
        assert_eq!(a, b, "encoding must be deterministic");
        let back = Checkpoint::decode(&a).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.encode(), a, "re-serialization must be byte-identical");
        assert_eq!(ck.method_name(), "pPIC");
    }

    #[test]
    fn unknown_tag_rejected() {
        let mut bytes = sample_batch().encode();
        bytes[12] = 42;
        let len = bytes.len();
        let c = crc32(&bytes[..len - 4]);
        bytes[len - 4..].copy_from_slice(&c.to_le_bytes());
        assert_eq!(
            Checkpoint::decode(&bytes).unwrap_err(),
            StoreError::UnknownMethodTag(42)
        );
    }

    #[test]
    fn atomic_write_leaves_no_temp() {
        let dir = std::env::temp_dir();
        let path = dir.join("pgpr_store_unit.ckpt");
        let ck = sample_batch();
        let n = ck.write_file(&path).unwrap();
        assert_eq!(n, ck.encode().len() as u64);
        assert!(!path.with_extension("ckpt.tmp").exists());
        let back = Checkpoint::read_file(&path).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncation_every_prefix_yields_typed_error() {
        let bytes = sample_batch().encode();
        for cut in 0..bytes.len() {
            match Checkpoint::decode(&bytes[..cut]) {
                Err(_) => {}
                Ok(_) => panic!("prefix of {cut} bytes decoded successfully"),
            }
        }
    }
}
