//! Binary checkpoint framing: magic, format version, method tag, named
//! length-prefixed sections of little-endian scalars, trailing CRC-32.
//!
//! The framing is deliberately dumb — no compression, no alignment, no
//! implicit defaults. Every byte is written explicitly, so encoding the
//! same state twice yields the same bytes (the round-trip pin in
//! `tests/integration_store.rs` holds re-serialization to byte
//! identity). Readers never trust a length field: every primitive read
//! is bounds-checked against its enclosing section and vector/matrix
//! lengths are validated *before* allocation, so corrupt or truncated
//! input yields a typed [`StoreError`] naming the failing section —
//! never a panic, never an unbounded allocation.
//!
//! Layout:
//!
//! ```text
//! [ magic "PGPRCKPT" : 8 ]
//! [ format version   : u32 LE ]
//! [ method tag       : u8 ]
//! [ section ]*
//! [ crc32 of all preceding bytes : u32 LE ]
//!
//! section := [ name len : u16 LE ][ name : utf-8 ]
//!            [ payload len : u64 LE ][ payload ]
//! ```
//!
//! Open-check order (pinned by the corruption tests): minimum length →
//! magic → version → CRC → method tag. The CRC check runs before any
//! section parsing, so a random bit flip anywhere in the file is caught
//! as [`StoreError::Checksum`] without touching the payload decoders.

use crate::linalg::Mat;

/// File magic: the first 8 bytes of every pgpr checkpoint.
pub const MAGIC: [u8; 8] = *b"PGPRCKPT";

/// Current (and only) format version.
pub const FORMAT_VERSION: u32 = 1;

/// Header bytes before the first section: magic + version + method tag.
pub const HEADER_LEN: usize = 8 + 4 + 1;

/// Smallest well-formed file: header plus the trailing CRC.
pub const MIN_LEN: usize = HEADER_LEN + 4;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Typed checkpoint failure. Everything the decoder can object to maps
/// to one of these — the store layer never panics on hostile input.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// Filesystem failure (message carries the path and the OS error).
    Io(String),
    /// The first 8 bytes are not `PGPRCKPT` (or the file is shorter
    /// than a header).
    BadMagic,
    /// A format version this build does not understand.
    UnsupportedVersion { found: u32, supported: u32 },
    /// A method tag outside the known range.
    UnknownMethodTag(u8),
    /// The checkpoint decodes fine but holds a different model family
    /// than the caller asked for.
    MethodMismatch { expected: &'static str, found: &'static str },
    /// Trailing CRC-32 does not match the bytes on disk.
    Checksum { stored: u32, computed: u32 },
    /// A read ran off the end of the named section (or the file).
    Truncated { section: &'static str },
    /// A section decoded but its contents are inconsistent.
    Corrupt { section: &'static str, reason: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(msg) => write!(f, "checkpoint io: {msg}"),
            StoreError::BadMagic => write!(f, "not a pgpr checkpoint (bad magic)"),
            StoreError::UnsupportedVersion { found, supported } => write!(
                f,
                "checkpoint format v{found} not supported (this build reads v{supported})"
            ),
            StoreError::UnknownMethodTag(t) => {
                write!(f, "unknown checkpoint method tag {t}")
            }
            StoreError::MethodMismatch { expected, found } => write!(
                f,
                "checkpoint holds a {found} model, expected {expected}"
            ),
            StoreError::Checksum { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            StoreError::Truncated { section } => {
                write!(f, "checkpoint truncated in section '{section}'")
            }
            StoreError::Corrupt { section, reason } => {
                write!(f, "checkpoint corrupt in section '{section}': {reason}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

// ---------------------------------------------------------------------
// CRC-32 (IEEE, reflected, poly 0xEDB88320) — table-driven, no deps.
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 of `bytes`. Public so the corruption tests can re-stamp
/// hand-mangled checkpoints and reach the decoders behind the CRC gate.
#[must_use]
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ u32::from(b)) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Append-only checkpoint encoder. Sections are framed by
/// [`Writer::section`]; [`Writer::finish`] stamps the trailing CRC.
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Start a checkpoint with the given method tag.
    #[must_use]
    pub fn new(tag: u8) -> Writer {
        let mut buf = Vec::with_capacity(256);
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        buf.push(tag);
        Writer { buf }
    }

    /// Write one named section; the payload length prefix is
    /// back-patched after `f` runs, so sections nest arbitrary writes.
    pub fn section(&mut self, name: &str, f: impl FnOnce(&mut SectionWriter<'_>)) {
        let nb = name.as_bytes();
        debug_assert!(nb.len() <= u16::MAX as usize);
        self.buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        self.buf.extend_from_slice(nb);
        let len_at = self.buf.len();
        self.buf.extend_from_slice(&0u64.to_le_bytes());
        let start = self.buf.len();
        f(&mut SectionWriter { buf: &mut self.buf });
        let len = (self.buf.len() - start) as u64;
        self.buf[len_at..len_at + 8].copy_from_slice(&len.to_le_bytes());
    }

    /// Append the CRC and return the finished byte image.
    #[must_use]
    pub fn finish(mut self) -> Vec<u8> {
        let c = crc32(&self.buf);
        self.buf.extend_from_slice(&c.to_le_bytes());
        self.buf
    }
}

/// Payload writer handed to [`Writer::section`] closures.
pub struct SectionWriter<'a> {
    buf: &'a mut Vec<u8>,
}

impl SectionWriter<'_> {
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(u8::from(v));
    }

    /// f64 as its exact little-endian bit pattern (no text round-trip).
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn put_vec_f64(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f64(x);
        }
    }

    pub fn put_vec_usize(&mut self, v: &[usize]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x as u64);
        }
    }

    pub fn put_mat(&mut self, m: &Mat) {
        self.put_u64(m.rows as u64);
        self.put_u64(m.cols as u64);
        for &x in &m.data {
            self.put_f64(x);
        }
    }

    pub fn put_opt_mat(&mut self, m: Option<&Mat>) {
        match m {
            Some(m) => {
                self.put_bool(true);
                self.put_mat(m);
            }
            None => self.put_bool(false),
        }
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(v) => {
                self.put_bool(true);
                self.put_f64(v);
            }
            None => self.put_bool(false),
        }
    }

    pub fn put_opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(v) => {
                self.put_bool(true);
                self.put_u64(v as u64);
            }
            None => self.put_bool(false),
        }
    }
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Bounds-checked checkpoint decoder over a validated byte image.
///
/// [`Reader::open`] performs the header checks (min length → magic →
/// version → CRC) and returns the method tag; sections are then read in
/// writer order via [`Reader::section`], and every primitive read is
/// checked against the current section's end.
pub struct Reader<'a> {
    /// Body bytes: everything except the trailing CRC.
    buf: &'a [u8],
    pos: usize,
    /// Name of the section currently being read (for error reporting).
    section: &'static str,
    /// End offset of the current section's payload.
    sec_end: usize,
}

impl<'a> Reader<'a> {
    /// Validate the header and CRC; returns the method tag and a reader
    /// positioned at the first section.
    pub fn open(bytes: &'a [u8]) -> Result<(u8, Reader<'a>), StoreError> {
        if bytes.len() < MIN_LEN {
            return Err(StoreError::Truncated { section: "header" });
        }
        if bytes[..8] != MAGIC {
            return Err(StoreError::BadMagic);
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(StoreError::UnsupportedVersion {
                found: version,
                supported: FORMAT_VERSION,
            });
        }
        let body = &bytes[..bytes.len() - 4];
        let stored =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let computed = crc32(body);
        if stored != computed {
            return Err(StoreError::Checksum { stored, computed });
        }
        let tag = bytes[12];
        Ok((
            tag,
            Reader { buf: body, pos: HEADER_LEN, section: "header", sec_end: HEADER_LEN },
        ))
    }

    /// Enter the next section, which must be named `name` (sections are
    /// positional; a name mismatch means a corrupt or foreign file).
    /// The previous section must have been consumed exactly.
    pub fn section(&mut self, name: &'static str) -> Result<(), StoreError> {
        if self.pos != self.sec_end {
            return Err(StoreError::Corrupt {
                section: self.section,
                reason: format!(
                    "{} unconsumed payload bytes",
                    self.sec_end - self.pos
                ),
            });
        }
        self.section = name;
        if self.pos + 2 > self.buf.len() {
            return Err(StoreError::Truncated { section: name });
        }
        let nlen =
            u16::from_le_bytes(self.buf[self.pos..self.pos + 2].try_into().unwrap())
                as usize;
        self.pos += 2;
        if self.pos + nlen > self.buf.len() {
            return Err(StoreError::Truncated { section: name });
        }
        let found = &self.buf[self.pos..self.pos + nlen];
        if found != name.as_bytes() {
            return Err(StoreError::Corrupt {
                section: name,
                reason: format!(
                    "expected section '{name}', found '{}'",
                    String::from_utf8_lossy(found)
                ),
            });
        }
        self.pos += nlen;
        if self.pos + 8 > self.buf.len() {
            return Err(StoreError::Truncated { section: name });
        }
        let plen =
            u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        let plen = usize::try_from(plen).map_err(|_| StoreError::Corrupt {
            section: name,
            reason: "section length exceeds address space".into(),
        })?;
        let end = self.pos.checked_add(plen).ok_or(StoreError::Corrupt {
            section: name,
            reason: "section length overflow".into(),
        })?;
        if end > self.buf.len() {
            return Err(StoreError::Truncated { section: name });
        }
        self.sec_end = end;
        Ok(())
    }

    /// All sections read and nothing left over.
    pub fn finish(self) -> Result<(), StoreError> {
        if self.pos != self.sec_end {
            return Err(StoreError::Corrupt {
                section: self.section,
                reason: "unconsumed payload bytes".into(),
            });
        }
        if self.pos != self.buf.len() {
            return Err(StoreError::Corrupt {
                section: self.section,
                reason: "trailing bytes after last section".into(),
            });
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(StoreError::Truncated { section: self.section })?;
        if end > self.sec_end {
            return Err(StoreError::Truncated { section: self.section });
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize, StoreError> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| StoreError::Corrupt {
            section: self.section,
            reason: format!("value {v} exceeds address space"),
        })
    }

    pub fn get_bool(&mut self) -> Result<bool, StoreError> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(StoreError::Corrupt {
                section: self.section,
                reason: format!("invalid bool byte {b}"),
            }),
        }
    }

    pub fn get_f64(&mut self) -> Result<f64, StoreError> {
        Ok(f64::from_bits(u64::from_le_bytes(
            self.take(8)?.try_into().unwrap(),
        )))
    }

    /// Length-validated f64 vector: the count is checked against the
    /// bytes actually remaining in the section before any allocation.
    pub fn get_vec_f64(&mut self) -> Result<Vec<f64>, StoreError> {
        let n = self.get_usize()?;
        let nbytes = n.checked_mul(8).ok_or(StoreError::Corrupt {
            section: self.section,
            reason: "vector length overflow".into(),
        })?;
        if self.pos + nbytes > self.sec_end {
            return Err(StoreError::Truncated { section: self.section });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f64()?);
        }
        Ok(v)
    }

    pub fn get_vec_usize(&mut self) -> Result<Vec<usize>, StoreError> {
        let n = self.get_usize()?;
        let nbytes = n.checked_mul(8).ok_or(StoreError::Corrupt {
            section: self.section,
            reason: "vector length overflow".into(),
        })?;
        if self.pos + nbytes > self.sec_end {
            return Err(StoreError::Truncated { section: self.section });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_usize()?);
        }
        Ok(v)
    }

    pub fn get_mat(&mut self) -> Result<Mat, StoreError> {
        let rows = self.get_usize()?;
        let cols = self.get_usize()?;
        let n = rows.checked_mul(cols).ok_or(StoreError::Corrupt {
            section: self.section,
            reason: "matrix shape overflow".into(),
        })?;
        let nbytes = n.checked_mul(8).ok_or(StoreError::Corrupt {
            section: self.section,
            reason: "matrix shape overflow".into(),
        })?;
        if self.pos + nbytes > self.sec_end {
            return Err(StoreError::Truncated { section: self.section });
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.get_f64()?);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    pub fn get_opt_mat(&mut self) -> Result<Option<Mat>, StoreError> {
        Ok(if self.get_bool()? { Some(self.get_mat()?) } else { None })
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>, StoreError> {
        Ok(if self.get_bool()? { Some(self.get_f64()?) } else { None })
    }

    pub fn get_opt_usize(&mut self) -> Result<Option<usize>, StoreError> {
        Ok(if self.get_bool()? { Some(self.get_usize()?) } else { None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_primitives() {
        let mut w = Writer::new(7);
        w.section("nums", |s| {
            s.put_u8(3);
            s.put_u64(1 << 40);
            s.put_f64(-0.0);
            s.put_bool(true);
            s.put_vec_f64(&[1.5, f64::MIN_POSITIVE]);
            s.put_vec_usize(&[0, 9, 2]);
            s.put_mat(&Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]));
            s.put_opt_f64(None);
            s.put_opt_usize(Some(5));
        });
        let bytes = w.finish();
        let (tag, mut r) = Reader::open(&bytes).unwrap();
        assert_eq!(tag, 7);
        r.section("nums").unwrap();
        assert_eq!(r.get_u8().unwrap(), 3);
        assert_eq!(r.get_u64().unwrap(), 1 << 40);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_vec_f64().unwrap(), vec![1.5, f64::MIN_POSITIVE]);
        assert_eq!(r.get_vec_usize().unwrap(), vec![0, 9, 2]);
        let m = r.get_mat().unwrap();
        assert_eq!((m.rows, m.cols), (2, 2));
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_opt_f64().unwrap(), None);
        assert_eq!(r.get_opt_usize().unwrap(), Some(5));
        r.finish().unwrap();
    }

    #[test]
    fn open_rejects_garbage_in_order() {
        // Too short.
        assert_eq!(
            Reader::open(&[0; 4]).unwrap_err(),
            StoreError::Truncated { section: "header" }
        );
        // Wrong magic (long enough otherwise).
        let mut bad = Writer::new(1).finish();
        bad[0] ^= 0xFF;
        assert_eq!(Reader::open(&bad).unwrap_err(), StoreError::BadMagic);
        // Future version, CRC re-stamped so the version check fires.
        let mut fut = Writer::new(1).finish();
        let body_len = fut.len() - 4;
        fut[8..12].copy_from_slice(&99u32.to_le_bytes());
        let c = crc32(&fut[..body_len]);
        fut[body_len..].copy_from_slice(&c.to_le_bytes());
        assert_eq!(
            Reader::open(&fut).unwrap_err(),
            StoreError::UnsupportedVersion { found: 99, supported: FORMAT_VERSION }
        );
        // Flipped payload bit → checksum.
        let mut w = Writer::new(1);
        w.section("s", |s| s.put_f64(1.0));
        let mut bytes = w.finish();
        let mid = bytes.len() - 8;
        bytes[mid] ^= 0x01;
        assert!(matches!(
            Reader::open(&bytes).unwrap_err(),
            StoreError::Checksum { .. }
        ));
    }

    #[test]
    fn section_errors_name_the_section() {
        let mut w = Writer::new(1);
        w.section("alpha", |s| s.put_u64(1));
        let bytes = w.finish();
        let (_, mut r) = Reader::open(&bytes).unwrap();
        // Wrong expected name.
        assert!(matches!(
            r.section("beta").unwrap_err(),
            StoreError::Corrupt { section: "beta", .. }
        ));
        // Reading past a section end names it.
        let (_, mut r) = Reader::open(&bytes).unwrap();
        r.section("alpha").unwrap();
        r.get_u64().unwrap();
        assert_eq!(
            r.get_u64().unwrap_err(),
            StoreError::Truncated { section: "alpha" }
        );
    }

    #[test]
    fn oversize_vector_length_is_rejected_before_allocation() {
        let mut w = Writer::new(1);
        w.section("v", |s| s.put_u64(u64::MAX)); // claimed length, no data
        let bytes = w.finish();
        let (_, mut r) = Reader::open(&bytes).unwrap();
        r.section("v").unwrap();
        assert!(r.get_vec_f64().is_err());
    }
}
