//! Covariance functions (the paper's squared-exponential + ARD).

pub mod se;

pub use se::{FeatureMap, FeatureMapF32, FeatureScratch, SeArd, JITTER_SCALE};
