//! Covariance functions (the paper's squared-exponential + ARD).

pub mod se;

pub use se::{SeArd, JITTER_SCALE};
